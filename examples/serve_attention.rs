//! END-TO-END DRIVER (DESIGN.md §6): serve batched requests against the
//! real transformer-block artifacts through the PJRT CPU runtime, with
//! off-critical-path autotuning (paper Q4.4).
//!
//! The flow proves all three layers compose:
//!   L1 Pallas kernels -> L2 JAX block -> AOT HLO artifacts ->
//!   L3 router/batcher -> PJRT execution -> latency/throughput report.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_attention
//! ```
//!
//! Phase 1 serves a seeded variable-length trace with the default kernel
//! variant per (batch, seq) bucket; the background tuner then measures
//! every variant during idle time and hot-swaps the fastest; phase 2
//! replays the same trace and reports the improvement.  Results are
//! recorded in EXPERIMENTS.md §End-to-end.

use portatune::runtime::Manifest;
use portatune::serving::{router::synth_trace, Router, ServerConfig};

fn main() -> portatune::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);

    let manifest = Manifest::load_default()?;
    let model = &manifest.model;
    println!(
        "model: hidden={} heads={}/{} head_dim={} (~{:.1}M params/block), {} compiled shapes",
        model.hidden,
        model.n_q_heads,
        model.n_kv_heads,
        model.head_dim,
        model.params_per_block as f64 / 1e6,
        manifest.model_artifacts().len()
    );

    let cfg = ServerConfig {
        cache_path: Some("serving_cache.json".into()),
        ..Default::default()
    };
    let router = Router::pjrt(manifest, &cfg)?;
    let boot = router.executor().stats()?;
    if boot.warm_started > 0 {
        println!(
            "warm start: {} bucket winners restored from serving_cache.json (Q4.3) — no cold tuning needed",
            boot.warm_started
        );
    }
    let max_tokens = router.policy().seq_buckets.last().copied().unwrap_or(128);
    let trace = synth_trace(n_requests, max_tokens, 42);
    println!(
        "trace: {} requests, variable lengths {}..{} tokens (log-normal, seed 42)",
        trace.len(),
        trace.iter().map(|r| r.tokens).min().unwrap(),
        trace.iter().map(|r| r.tokens).max().unwrap()
    );

    println!("\n== phase 1: cold serve (default kernel variants) ==");
    let before = router.serve_trace(trace.clone())?;
    report("cold", &before);

    println!("\n== background tuning (idle-time, Q4.4) ==");
    router.finish_tuning()?;
    let stats = router.executor().stats()?;
    println!("variants measured: {} ({} compiles)", stats.variants_measured, stats.compiles);
    let mut active: Vec<_> = stats.active_us.iter().collect();
    active.sort_by(|a, b| a.0.cmp(b.0));
    for (shape, us) in active {
        println!("  {shape}: active {} @ {:.1} ms", stats.active[shape], us / 1e3);
    }
    for s in &stats.swaps {
        println!("  swap b{}s{}: -> {} ({:+.1}% faster)", s.shape.0, s.shape.1, s.to, (s.gain - 1.0) * 100.0);
    }

    println!("\n== phase 2: tuned serve (same trace) ==");
    let after = router.serve_trace(trace)?;
    report("tuned", &after);

    println!(
        "\nexec p50 improvement from autotuning: {:.2}x",
        before.exec_p50_us / after.exec_p50_us
    );
    Ok(())
}

fn report(tag: &str, r: &portatune::serving::ServeReport) {
    println!(
        "[{tag}] {} req served ({} rejected, {} batches) in {:.2} s -> {:.1} req/s, {:.0} tok/s",
        r.requests, r.rejected, r.batches, r.wall_seconds, r.throughput_rps, r.tokens_per_second
    );
    println!(
        "[{tag}] latency p50/p95/p99 = {:.1}/{:.1}/{:.1} ms | exec p50 {:.1} ms | batch occupancy {:.2}",
        r.latency_p50_us / 1e3,
        r.latency_p95_us / 1e3,
        r.latency_p99_us / 1e3,
        r.exec_p50_us / 1e3,
        r.mean_batch_occupancy
    );
}
