//! Fig. 5 as a runnable example: generated-code diversity analysis over
//! (a) the synthetic PTX corpus from the simulated 450-config sweep and
//! (b) the *real* HLO artifacts of every AOT-lowered Pallas config.
//!
//! ```bash
//! make artifacts && cargo run --release --example code_analysis
//! ```

use portatune::codegen::hlo;
use portatune::experiments::fig5;
use portatune::report::ascii_chart;
use portatune::runtime::Manifest;

fn main() -> portatune::Result<()> {
    // ---- synthetic PTX corpus (paper's exact setup) -------------------
    let (corpus, best) = fig5::triton_corpus();
    println!(
        "Triton sweep ({}): {} configurations analyzed",
        fig5::fig5_workload().key(),
        corpus.len()
    );
    let series: Vec<(f64, f64)> = corpus
        .iter()
        .enumerate()
        .map(|(i, (_, s))| (i as f64, s.unique_instructions as f64))
        .collect();
    let totals: Vec<(f64, f64)> = corpus
        .iter()
        .enumerate()
        .map(|(i, (_, s))| (i as f64, s.total_instructions as f64))
        .collect();
    println!(
        "{}",
        ascii_chart("unique (o) and total (log, *) instructions per config", &[("total", totals), ("unique", series)], true, 64, 14)
    );
    if let Some(bi) = best {
        let (cfg, stats) = &corpus[bi];
        println!(
            "autotuner winner: config #{bi} [{cfg}] — {} unique / {} total instructions",
            stats.unique_instructions, stats.total_instructions
        );
        println!("(neither the largest nor the most diverse — static metrics do not predict it)");
    }

    let cuda = fig5::cuda_corpus();
    let t_max = corpus.iter().map(|(_, s)| s.unique_instructions).max().unwrap_or(0);
    let c_max = cuda.iter().map(|(_, s)| s.unique_instructions).max().unwrap_or(0);
    println!("\nCUDA templates: {} applicable; max unique instrs {c_max} vs Triton {t_max}", cuda.len());

    // ---- real HLO corpus ----------------------------------------------
    println!("\n== real HLO artifacts (Pallas AOT) ==");
    let manifest = Manifest::load_default()?;
    for bucket in manifest.workload_buckets("attention") {
        println!("bucket {}:", bucket.key());
        let mut rows: Vec<(String, usize, usize, usize)> = Vec::new();
        for a in manifest.candidates_for(&bucket) {
            let s = hlo::analyze_file(manifest.root.join(&a.path))?;
            rows.push((a.config().key(), s.unique_instructions, s.total_instructions, s.bytes));
        }
        rows.sort_by_key(|r| r.2);
        for (cfg, uniq, total, bytes) in rows.iter().take(3) {
            println!("  smallest {cfg:<32} unique {uniq:>3} total {total:>5} ({bytes} B)");
        }
        for (cfg, uniq, total, bytes) in rows.iter().rev().take(3) {
            println!("  largest  {cfg:<32} unique {uniq:>3} total {total:>5} ({bytes} B)");
        }
    }
    Ok(())
}
