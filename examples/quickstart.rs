//! Quickstart: the `TuningSession` builder end to end — define a
//! configuration space, autotune the Listing-1 vector-add kernel on a
//! simulated GPU (streaming progress through an `Observer`, capping a
//! run with a `Budget`), and reuse the result through the persistent
//! cache.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! The default build runs entirely against the analytical platform
//! models (no GPU, no XLA toolchain — this is what CI executes).  With
//! `--features pjrt` and AOT artifacts (`make artifacts`), it
//! additionally autotunes for real by executing every artifact via
//! PJRT.

use portatune::autotuner::{
    Budget, Observer, SessionOutcome, SimEvaluator, Strategy, TuningSession,
};
use portatune::cache::TuningCache;
use portatune::config::{spaces, Config};
use portatune::kernels::baselines::triton_codegen;
use portatune::platform::SimGpu;
use portatune::workload::{DType, Workload};

/// Minimal observer: print each new best as the search finds it.
struct PrintBests;

impl Observer for PrintBests {
    fn on_new_best(&mut self, config: &Config, latency_us: f64) {
        println!("    new best {config} @ {latency_us:.2} us");
    }
}

fn main() -> portatune::Result<()> {
    // ----------------------------------------------------------------
    // 1. A workload and its configuration space (paper Q4.1).
    // ----------------------------------------------------------------
    let w = Workload::VectorAdd { n: 4096, dtype: DType::F32 };
    let space = spaces::vecadd_aot_space();
    println!("workload: {}", w.key());
    println!(
        "space {:?}: {} raw configurations, {} valid for this workload",
        space.name,
        space.cardinality(),
        space.enumerate(&w).count()
    );

    // ----------------------------------------------------------------
    // 2. Autotune on a simulated GPU (instant, deterministic), watching
    //    progress through an Observer.
    // ----------------------------------------------------------------
    let gpu = SimGpu::a100();
    let mut sim = SimEvaluator::new(gpu.clone(), w, triton_codegen(gpu.spec.vendor));
    let mut bests = PrintBests;
    println!("\n[sim-a100] exhaustive tune:");
    let out = TuningSession::new(&space, &w)
        .observe(&mut bests)
        .evaluator(&mut sim)
        .run()
        .and_then(SessionOutcome::into_solo)
        .expect("space is non-empty");
    println!(
        "[sim-a100] best {} @ {:.2} us ({} evaluated, {} invalid)",
        out.best, out.best_latency_us, out.evaluated, out.invalid
    );

    // ----------------------------------------------------------------
    // 3. Budgets are session options, not strategy knobs: cap ANY
    //    strategy — even exhaustive enumeration — at N evaluations.
    // ----------------------------------------------------------------
    if let Some(capped) = TuningSession::new(&space, &w)
        .budget(Budget::Evals(4))
        .evaluator(&mut sim)
        .run()
        .and_then(SessionOutcome::into_solo)
    {
        println!(
            "\n[sim-a100] budgeted to 4 evals: best {} @ {:.2} us ({} evaluated)",
            capped.best, capped.best_latency_us, capped.evaluated
        );
    }

    // ----------------------------------------------------------------
    // 4. Reuse: attach a cache and the second run is a hit (Q4.3).
    // ----------------------------------------------------------------
    let mut cache = TuningCache::ephemeral();
    for round in ["cold", "warm"] {
        let got = TuningSession::new(&space, &w)
            .strategy(Strategy::Random { budget: 16 })
            .seed(7)
            .cache(&mut cache)
            .evaluator(&mut sim)
            .run()
            .and_then(SessionOutcome::into_solo)
            .expect("random(16) finds a valid vecadd config");
        println!(
            "\n[{round}] best {} @ {:.2} us (from cache: {}, {} evaluations)",
            got.best, got.best_latency_us, got.from_cache, got.evaluated
        );
        if round == "warm" {
            assert!(got.from_cache && got.evaluated == 0);
        }
    }

    // ----------------------------------------------------------------
    // 5. The same session shape drives real PJRT execution (feature
    //    `pjrt` + `make artifacts`): only the evaluator changes.
    // ----------------------------------------------------------------
    #[cfg(feature = "pjrt")]
    pjrt_tune(&space, &w)?;

    Ok(())
}

/// Autotune for real: execute every AOT artifact via PJRT and measure
/// wall-clock (Python is nowhere in this process).
#[cfg(feature = "pjrt")]
fn pjrt_tune(space: &portatune::config::ConfigSpace, w: &Workload) -> portatune::Result<()> {
    use portatune::autotuner::PjrtEvaluator;
    use portatune::runtime::{Engine, Manifest};

    let engine = Engine::cpu()?;
    println!("\n[cpu-pjrt] platform: {}", engine.platform_name());
    let manifest = Manifest::load_default()?;
    let mut cache = TuningCache::ephemeral();
    let mut eval = PjrtEvaluator::new(&engine, &manifest, *w, 2, 7)?;
    let real = TuningSession::new(space, w)
        .cache(&mut cache)
        .evaluator(&mut eval)
        .run()
        .and_then(SessionOutcome::into_solo)
        .expect("artifacts present (run `make artifacts`)");
    println!(
        "[cpu-pjrt] best {} @ {:.1} us measured ({} artifacts compiled+timed)",
        real.best, real.best_latency_us, real.evaluated
    );
    for rec in &real.history {
        let fp = rec.fingerprint;
        match rec.latency_us {
            Some(us) => println!("    cfg#{fp:016x} {us:>8.1} us"),
            None => println!("    cfg#{fp:016x}  INVALID"),
        }
    }
    let again = TuningSession::new(space, w)
        .cache(&mut cache)
        .evaluator(&mut eval)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();
    assert!(again.from_cache && again.evaluated == 0);
    println!("\n[cpu-pjrt] second tune served from cache: {} (0 evaluations)", again.best);
    Ok(())
}
