//! Quickstart: define a configuration space, autotune the Listing-1
//! vector-add kernel on a simulated GPU *and* on the real PJRT CPU
//! backend, and reuse the result through the persistent cache.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use portatune::autotuner::{self, PjrtEvaluator, SimEvaluator, Strategy};
use portatune::cache::TuningCache;
use portatune::config::spaces;
use portatune::kernels::baselines::triton_codegen;
use portatune::platform::SimGpu;
use portatune::runtime::{Engine, Manifest};
use portatune::workload::{DType, Workload};

fn main() -> portatune::Result<()> {
    // ----------------------------------------------------------------
    // 1. A workload and its configuration space (paper Q4.1).
    // ----------------------------------------------------------------
    let w = Workload::VectorAdd { n: 4096, dtype: DType::F32 };
    let space = spaces::vecadd_aot_space();
    println!("workload: {}", w.key());
    println!(
        "space {:?}: {} raw configurations, {} valid for this workload",
        space.name,
        space.cardinality(),
        space.enumerate(&w).count()
    );

    // ----------------------------------------------------------------
    // 2. Autotune on a simulated GPU (instant, deterministic).
    // ----------------------------------------------------------------
    let gpu = SimGpu::a100();
    let mut sim = SimEvaluator::new(gpu.clone(), w, triton_codegen(gpu.spec.vendor));
    let out = autotuner::tune(&space, &w, &mut sim, &Strategy::Exhaustive, 0)
        .expect("space is non-empty");
    println!("\n[sim-a100] best {} @ {:.2} us ({} evaluated)", out.best, out.best_latency_us, out.evaluated);

    // ----------------------------------------------------------------
    // 3. Autotune for real: execute every AOT artifact via PJRT and
    //    measure wall-clock (Python is nowhere in this process).
    // ----------------------------------------------------------------
    let engine = Engine::cpu()?;
    println!("\n[cpu-pjrt] platform: {}", engine.platform_name());
    let manifest = Manifest::load_default()?;
    let mut cache = TuningCache::ephemeral();
    let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 2, 7)?;
    let real = autotuner::tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Exhaustive, 0)
        .expect("artifacts present (run `make artifacts`)");
    println!(
        "[cpu-pjrt] best {} @ {:.1} us measured ({} artifacts compiled+timed)",
        real.best, real.best_latency_us, real.evaluated
    );
    for rec in &real.history {
        let fp = rec.fingerprint;
        match rec.latency_us {
            Some(us) => println!("    cfg#{fp:016x} {us:>8.1} us"),
            None => println!("    cfg#{fp:016x}  INVALID"),
        }
    }

    // ----------------------------------------------------------------
    // 4. Reuse: the second tune is a cache hit (paper Q4.3).
    // ----------------------------------------------------------------
    let again = autotuner::tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
    assert!(again.from_cache && again.evaluated == 0);
    println!("\nsecond tune served from cache: {} (0 evaluations)", again.best);
    Ok(())
}
