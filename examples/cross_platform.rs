//! Cross-platform portability walkthrough — the paper's core argument
//! (§Q1/§Q2) as a runnable scenario:
//!
//! 1. one unchanged kernel source, autotuned per platform, is compared
//!    against each platform's vendor library;
//! 2. the tuned configurations are then swapped across platforms to show
//!    why config reuse is NOT a substitute for re-tuning (Fig. 4).
//!
//! ```bash
//! cargo run --release --example cross_platform
//! ```

use portatune::experiments::{fig4, tune_triton_attention};
use portatune::kernels::baselines::sota_attention_library;
use portatune::platform::SimGpu;
use portatune::report::ascii_chart;
use portatune::workload::Workload;

fn main() {
    let workloads = [
        Workload::llama3_attention(1, 512),
        Workload::llama3_attention(8, 1024),
        Workload::llama3_attention(64, 2048),
    ];

    println!("== one kernel, two platforms: autotuned vs vendor library ==\n");
    for gpu in [SimGpu::a100(), SimGpu::mi250()] {
        let lib = sota_attention_library(gpu.spec.vendor);
        println!("--- {} (vendor lib: {}) ---", gpu.spec.name, lib.name);
        for w in &workloads {
            let (lib_us, lib_cfg) = lib.latency_us(&gpu, w).expect("vendor lib runs at home");
            let (tuned_us, tuned_cfg, evaluated, invalid) =
                tune_triton_attention(&gpu, w).expect("space non-empty");
            println!(
                "  {:<28} vendor {:>9.1} us [{}]",
                w.key(),
                lib_us,
                lib_cfg
            );
            println!(
                "  {:<28} tuned  {:>9.1} us [{}] ({} cfgs, {} invalid) -> {:.2}x",
                "",
                tuned_us,
                tuned_cfg,
                evaluated,
                invalid,
                lib_us / tuned_us
            );
        }
        println!();
    }

    println!("== config reuse across platforms (Fig. 4) ==\n");
    let a100 = SimGpu::a100();
    let mi250 = SimGpu::mi250();
    let mut series_am = Vec::new();
    let mut series_ma = Vec::new();
    for (i, w) in workloads.iter().enumerate() {
        for (src, dst, label, series) in [
            (&a100, &mi250, "A100-opt -> MI250", &mut series_am),
            (&mi250, &a100, "MI250-opt -> A100", &mut series_ma),
        ] {
            match fig4::transplant(src, dst, w) {
                Some((fig4::ReuseOutcome::Retained(f), _)) => {
                    println!("  {label:<20} {:<28} retains {:>4.0}%", w.key(), f * 100.0);
                    series.push((i as f64, f * 100.0));
                }
                Some((fig4::ReuseOutcome::Invalid(reason), _)) => {
                    println!("  {label:<20} {:<28} INVALID: {reason}", w.key());
                }
                None => {}
            }
        }
    }
    println!(
        "\n{}",
        ascii_chart(
            "retained % of native tuned performance (x = workload index)",
            &[("A100->MI250", series_am), ("MI250->A100", series_ma)],
            false,
            48,
            12,
        )
    );
    println!("conclusion: configurations do not port; the *autotuner* does.");
}
