"""Pallas flash attention vs the pure-jnp oracle (the core L1 contract).

Every configuration the autotuner may select must produce the same
numerics as ``ref.attention`` — otherwise "autotuning" would be trading
correctness for speed.  Hypothesis sweeps shapes, GQA ratios, dtypes and
block configurations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention as fa
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_qkv(key, batch, hq, hkv, seq, dim, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (batch, hq, seq, dim), dtype)
    k = jax.random.normal(ks[1], (batch, hkv, seq, dim), dtype)
    v = jax.random.normal(ks[2], (batch, hkv, seq, dim), dtype)
    return q, k, v


def assert_matches_ref(q, k, v, causal=True, atol=2e-3, **cfg):
    out = fa.flash_attention(q, k, v, causal=causal, **cfg)
    expected = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32), atol=atol, rtol=atol
    )


class TestBasicConfigs:
    @pytest.mark.parametrize("block_q", [16, 32, 64])
    @pytest.mark.parametrize("block_k", [16, 32, 64])
    def test_block_shapes_causal(self, block_q, block_k):
        q, k, v = make_qkv(jax.random.PRNGKey(0), 1, 2, 2, 64, 32)
        assert_matches_ref(q, k, v, block_q=block_q, block_k=block_k)

    @pytest.mark.parametrize("unroll", [1, 2, 4])
    def test_unroll_factors(self, unroll):
        q, k, v = make_qkv(jax.random.PRNGKey(1), 1, 2, 2, 64, 16)
        assert_matches_ref(q, k, v, block_q=16, block_k=16, unroll=unroll)

    def test_non_causal(self):
        q, k, v = make_qkv(jax.random.PRNGKey(2), 2, 2, 2, 64, 16)
        assert_matches_ref(q, k, v, causal=False, block_q=32, block_k=16)

    def test_gqa_llama3_ratio(self):
        # Llama-3 GQA: 4 query heads per KV head.
        q, k, v = make_qkv(jax.random.PRNGKey(3), 1, 8, 2, 64, 16)
        assert_matches_ref(q, k, v, block_q=16, block_k=32)

    def test_single_kv_head_mqa(self):
        q, k, v = make_qkv(jax.random.PRNGKey(4), 1, 4, 1, 32, 16)
        assert_matches_ref(q, k, v, block_q=16, block_k=16)

    def test_block_equals_seq(self):
        q, k, v = make_qkv(jax.random.PRNGKey(5), 1, 2, 2, 32, 16)
        assert_matches_ref(q, k, v, block_q=32, block_k=32)

    def test_batch_dim(self):
        q, k, v = make_qkv(jax.random.PRNGKey(6), 4, 2, 1, 32, 16)
        assert_matches_ref(q, k, v, block_q=16, block_k=16)

    def test_bf16_inputs(self):
        q, k, v = make_qkv(jax.random.PRNGKey(7), 1, 2, 2, 32, 16, jnp.bfloat16)
        # bf16 storage, f32 accumulation: tolerance follows bf16 epsilon.
        assert_matches_ref(q, k, v, block_q=16, block_k=16, atol=3e-2)

    def test_custom_sm_scale(self):
        q, k, v = make_qkv(jax.random.PRNGKey(8), 1, 2, 2, 32, 16)
        out = fa.flash_attention(q, k, v, block_q=16, block_k=16, sm_scale=0.5)
        expected = ref.attention(q, k, v, sm_scale=0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected), atol=2e-3)


class TestValidity:
    def test_rejects_nondivisible_block_q(self):
        q, k, v = make_qkv(jax.random.PRNGKey(0), 1, 2, 2, 48, 16)
        with pytest.raises(ValueError, match="invalid attention config"):
            fa.flash_attention(q, k, v, block_q=32, block_k=16)

    def test_rejects_nondivisible_unroll(self):
        q, k, v = make_qkv(jax.random.PRNGKey(0), 1, 2, 2, 48, 16)
        with pytest.raises(ValueError, match="invalid attention config"):
            fa.flash_attention(q, k, v, block_q=16, block_k=16, unroll=2)

    def test_rejects_bad_gqa_ratio(self):
        q = jnp.zeros((1, 3, 32, 16))
        kv = jnp.zeros((1, 2, 32, 16))
        with pytest.raises(ValueError, match="not a multiple"):
            fa.flash_attention(q, kv, kv, block_q=16, block_k=16)

    def test_config_is_valid_matrix(self):
        assert fa.config_is_valid(128, 32, 32, 1)
        assert not fa.config_is_valid(128, 48, 32, 1)  # non-divisor
        assert not fa.config_is_valid(64, 128, 32, 1)  # block > seq
        assert not fa.config_is_valid(128, 32, 64, 4)  # nk=2 not multiple of 4
        assert fa.config_is_valid(128, 32, 32, 4)  # nk=4

    def test_enumerate_matches_validity(self):
        for s in (64, 128, 256):
            for cfg in fa.enumerate_aot_configs(s):
                assert fa.config_is_valid(s, cfg["block_q"], cfg["block_k"], cfg["unroll"])

    def test_enumerate_count_grows_with_seqlen(self):
        assert len(fa.enumerate_aot_configs(128)) >= len(fa.enumerate_aot_configs(16))


class TestNumericalEdges:
    def test_large_magnitude_logits_no_overflow(self):
        # Online softmax must be stable for large scores.
        q, k, v = make_qkv(jax.random.PRNGKey(9), 1, 1, 1, 32, 16)
        out = fa.flash_attention(q * 30.0, k * 30.0, v, block_q=16, block_k=16)
        assert np.isfinite(np.asarray(out)).all()
        expected = ref.attention(q * 30.0, k * 30.0, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected), atol=5e-3)

    def test_first_row_causal(self):
        # Row 0 attends only to itself: output == v[0].
        q, k, v = make_qkv(jax.random.PRNGKey(10), 1, 1, 1, 32, 16)
        out = fa.flash_attention(q, k, v, block_q=16, block_k=16, causal=True)
        np.testing.assert_allclose(np.asarray(out)[0, 0, 0], np.asarray(v)[0, 0, 0], atol=1e-5)

    def test_uniform_values(self):
        # Constant V -> output constant regardless of attention weights.
        q, k, _ = make_qkv(jax.random.PRNGKey(11), 1, 2, 1, 32, 16)
        v = jnp.full((1, 1, 32, 16), 3.5, jnp.float32)
        out = fa.flash_attention(q, k, v, block_q=16, block_k=16)
        np.testing.assert_allclose(np.asarray(out), 3.5, atol=1e-5)

    def test_vmem_bytes_monotone(self):
        assert fa.vmem_bytes(64, 64, 64) > fa.vmem_bytes(32, 32, 64)
        assert fa.vmem_bytes(32, 32, 128) > fa.vmem_bytes(32, 32, 64)

    def test_flops_causal_halves(self):
        assert fa.flops(1, 8, 128, 64, causal=True) * 2 == fa.flops(1, 8, 128, 64, causal=False)


@settings(max_examples=12, deadline=None)
@given(
    seq_pow=st.integers(5, 7),  # seq in {32, 64, 128}
    bq_pow=st.integers(4, 6),
    bk_pow=st.integers(4, 6),
    hq=st.sampled_from([1, 2, 4]),
    gqa=st.sampled_from([1, 2]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_config_sweep(seq_pow, bq_pow, bk_pow, hq, gqa, causal, seed):
    """Any valid (shape, config) pair matches the oracle."""
    seq, bq, bk = 2**seq_pow, 2**bq_pow, 2**bk_pow
    if not fa.config_is_valid(seq, bq, bk, 1) or hq % gqa != 0:
        return
    q, k, v = make_qkv(jax.random.PRNGKey(seed), 1, hq, hq // gqa, seq, 16)
    assert_matches_ref(q, k, v, causal=causal, block_q=bq, block_k=bk)
