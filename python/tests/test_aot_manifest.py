"""AOT manifest consistency: the compile-path contract the Rust side
relies on.  Skipped when `make artifacts` has not run."""

import json
import pathlib

import pytest

from compile.kernels import flash_attention as fa
from compile.kernels import rms_norm as rn
from compile.kernels import vector_add as va

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_every_artifact_file_exists(manifest):
    for a in manifest["artifacts"]:
        path = ART / a["path"]
        assert path.exists(), a["id"]
        assert path.stat().st_size == a["bytes"], f"{a['id']} size drift"


def test_attention_configs_are_valid(manifest):
    for a in manifest["artifacts"]:
        if a["kernel"] != "attention" or a.get("impl") != "pallas":
            continue
        w, c = a["workload"], a["config"]
        assert fa.config_is_valid(w["seq_len"], c["block_q"], c["block_k"], c["unroll"]), a["id"]


def test_rms_configs_are_valid(manifest):
    for a in manifest["artifacts"]:
        if a["kernel"] != "rms_norm" or a.get("impl") != "pallas":
            continue
        w, c = a["workload"], a["config"]
        assert rn.config_is_valid(w["n_rows"], w["hidden"], c["block_h"], c["rows_per_block"]), a["id"]


def test_vecadd_configs_are_valid(manifest):
    for a in manifest["artifacts"]:
        if a["kernel"] != "vector_add" or a.get("impl") != "pallas":
            continue
        assert va.config_is_valid(a["workload"]["n_elements"], a["config"]["block_size"]), a["id"]


def test_input_specs_match_workloads(manifest):
    for a in manifest["artifacts"]:
        if a["kernel"] != "attention" or a.get("impl") != "pallas":
            continue
        w = a["workload"]
        q, k, v = a["inputs"]
        assert q["shape"] == [w["batch"], w["q_heads"], w["seq_len"], w["head_dim"]]
        assert k["shape"] == [w["batch"], w["kv_heads"], w["seq_len"], w["head_dim"]]
        assert v["shape"] == k["shape"]
        assert a["output"]["shape"] == q["shape"]


def test_ids_are_unique(manifest):
    ids = [a["id"] for a in manifest["artifacts"]]
    assert len(ids) == len(set(ids))


def test_env_fingerprint_present(manifest):
    env = manifest["env"]
    assert env["interchange"] == "hlo-text-v1"
    assert env["jax"]


def test_model_params_cover_declared_order(manifest):
    m = manifest["model"]
    assert set(m["param_order"]) == set(m["param_shapes"].keys())
    total = sum(
        int(__import__("numpy").prod(s)) for s in m["param_shapes"].values()
    )
    assert total == m["params_per_block"]
