"""Pallas RMS norm vs the pure-jnp oracle, across the config space."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import rms_norm as rn

jax.config.update("jax_platform_name", "cpu")


def assert_matches_ref(x, w, atol=1e-4, **cfg):
    out = rn.rms_norm(x, w, **cfg)
    expected = ref.rms_norm(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32), atol=atol, rtol=atol
    )


class TestConfigs:
    @pytest.mark.parametrize("block_h", [128, 256, 512, 1024])
    def test_block_h(self, block_h):
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 1024))
        w = jax.random.normal(jax.random.PRNGKey(1), (1024,)) * 0.1 + 1.0
        assert_matches_ref(x, w, block_h=block_h)

    @pytest.mark.parametrize("rows_per_block", [1, 2, 4])
    def test_rows_per_block(self, rows_per_block):
        x = jax.random.normal(jax.random.PRNGKey(2), (16, 256))
        w = jnp.ones((256,))
        assert_matches_ref(x, w, block_h=128, rows_per_block=rows_per_block)

    def test_block_equals_hidden(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 512))
        w = jnp.ones((512,))
        assert_matches_ref(x, w, block_h=512)

    def test_3d_input_flattened(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 256))
        w = jnp.ones((256,))
        assert_matches_ref(x, w, block_h=128)

    def test_bf16(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (8, 256), jnp.bfloat16)
        w = jnp.ones((256,), jnp.bfloat16)
        assert_matches_ref(x, w, block_h=128, atol=3e-2)

    def test_weight_scaling(self):
        x = jax.random.normal(jax.random.PRNGKey(6), (4, 256))
        w = jnp.full((256,), 2.0)
        out = rn.rms_norm(x, w, block_h=256)
        out1 = rn.rms_norm(x, jnp.ones((256,)), block_h=256)
        np.testing.assert_allclose(np.asarray(out), 2 * np.asarray(out1), atol=1e-5)


class TestValidity:
    def test_rejects_nondivisible_block(self):
        x = jnp.zeros((4, 300))
        with pytest.raises(ValueError, match="invalid rms config"):
            rn.rms_norm(x, jnp.ones((300,)), block_h=128)

    def test_rejects_nondivisible_rows(self):
        x = jnp.zeros((3, 256))
        with pytest.raises(ValueError, match="invalid rms config"):
            rn.rms_norm(x, jnp.ones((256,)), block_h=128, rows_per_block=2)

    def test_enumerate_matches_validity(self):
        for cfg in rn.enumerate_aot_configs(64, 1024):
            assert rn.config_is_valid(64, 1024, cfg["block_h"], cfg["rows_per_block"])

    def test_bytes_moved_model(self):
        # read + write of x dominates; weight read amortized.
        assert rn.bytes_moved(100, 1000) == 100 * 1000 * 4 * 2 + 1000 * 4


class TestNumericalEdges:
    def test_rsqrt_stability_tiny_values(self):
        x = jnp.full((4, 256), 1e-20, jnp.float32)
        out = rn.rms_norm(x, jnp.ones((256,)), block_h=128)
        assert np.isfinite(np.asarray(out)).all()

    def test_large_values_no_overflow(self):
        x = jnp.full((4, 256), 1e18, jnp.float32)
        out = rn.rms_norm(x, jnp.ones((256,)), block_h=256)
        # f32 accumulation of squares overflows at ~1e19; 1e18 must survive.
        assert np.isfinite(np.asarray(out)).all()

    def test_scale_invariance(self):
        # rms_norm(c*x) == rms_norm(x) for c > 0 (with eps negligible).
        x = jax.random.normal(jax.random.PRNGKey(7), (4, 256)) + 1.0
        w = jnp.ones((256,))
        a = rn.rms_norm(x, w, block_h=128)
        b = rn.rms_norm(x * 7.0, w, block_h=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 4, 8, 16]),
    hidden_pow=st.integers(7, 11),
    bh_pow=st.integers(6, 11),
    rpb=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_config_sweep(rows, hidden_pow, bh_pow, rpb, seed):
    hidden, bh = 2**hidden_pow, 2**bh_pow
    if not rn.config_is_valid(rows, hidden, bh, rpb):
        return
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, hidden))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (hidden,)) * 0.1 + 1.0
    assert_matches_ref(x, w, block_h=bh, rows_per_block=rpb)
