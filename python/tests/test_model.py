"""Layer-2 model tests: Pallas-kerneled block vs pure-reference block."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m

jax.config.update("jax_platform_name", "cpu")

SMALL = m.ModelConfig(hidden=128, n_q_heads=4, n_kv_heads=2, head_dim=32, mlp_hidden=256)


@pytest.fixture(scope="module")
def params():
    return m.init_params(SMALL, jax.random.PRNGKey(0))


def test_attention_layer_matches_ref(params):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, SMALL.hidden))
    got = m.attention_layer(x, params, SMALL, block_q=16, block_k=16, use_pallas=True)
    want = m.attention_layer(x, params, SMALL, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3)


def test_block_matches_ref(params):
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, SMALL.hidden))
    got = m.transformer_block(x, params, SMALL, block_q=32, block_k=16, use_pallas=True)
    want = m.transformer_block(x, params, SMALL, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-3, rtol=5e-3)


def test_block_config_invariance(params):
    """Different kernel configs must give identical model outputs."""
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, SMALL.hidden))
    a = m.transformer_block(x, params, SMALL, block_q=16, block_k=16, unroll=1)
    b = m.transformer_block(x, params, SMALL, block_q=32, block_k=32, unroll=2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3)


def test_flat_entry_point_matches(params):
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 64, SMALL.hidden))
    flat = m.transformer_block_flat(SMALL, block_q=16, block_k=16)
    weights = [params[k] for k in m.param_order(SMALL)]
    (got,) = flat(x, *weights)
    want = m.transformer_block(x, params, SMALL, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_param_count_formula():
    cfg = m.ModelConfig()
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(p.shape)) for p in params.values())
    assert actual == cfg.param_count()


def test_llama3_8b_geometry():
    cfg = m.LLAMA3_8B
    assert cfg.q_dim == 4096 and cfg.kv_dim == 1024
    # one block of Llama-3-8B is ~218M params; 32 blocks ~7B (plus embeddings)
    assert 150e6 < cfg.param_count() < 250e6


def test_block_flops_positive_and_monotone():
    cfg = m.ModelConfig()
    assert m.block_flops(cfg, 1, 128) > 0
    assert m.block_flops(cfg, 2, 128) == 2 * m.block_flops(cfg, 1, 128)
    assert m.block_flops(cfg, 1, 256) > m.block_flops(cfg, 1, 128)


def test_residual_stream_preserved(params):
    """Zero-weight projections ⇒ block ≈ identity (residual path)."""
    zp = {k: jnp.zeros_like(v) for k, v in params.items()}
    zp["attn_norm_w"] = params["attn_norm_w"]
    zp["mlp_norm_w"] = params["mlp_norm_w"]
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 64, SMALL.hidden))
    out = m.transformer_block(x, zp, SMALL, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)
