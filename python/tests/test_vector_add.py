"""Listing-1 vector add: config sweep + validity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import vector_add as va

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("block_size", [64, 128, 256, 512, 1024])
def test_all_blocks(block_size):
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
    y = jax.random.normal(jax.random.PRNGKey(1), (1024,))
    out = va.vector_add(x, y, block_size=block_size)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x + y), atol=1e-6)


def test_rejects_nondivisible():
    x = jnp.zeros((1000,))
    with pytest.raises(ValueError, match="invalid vector_add config"):
        va.vector_add(x, x, block_size=256)


def test_enumerate():
    cfgs = va.enumerate_aot_configs(1024)
    assert {c["block_size"] for c in cfgs} == {64, 128, 256, 512, 1024}
    assert va.enumerate_aot_configs(128) == [{"block_size": 64}, {"block_size": 128}]


@settings(max_examples=10, deadline=None)
@given(n_pow=st.integers(6, 12), bs=st.sampled_from(va.BLOCK_SIZE_CHOICES), seed=st.integers(0, 100))
def test_hypothesis_sweep(n_pow, bs, seed):
    n = 2**n_pow
    if not va.config_is_valid(n, bs):
        return
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    y = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    out = va.vector_add(x, y, block_size=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x + y), atol=1e-6)
