import os
import sys

# Make `import compile...` work when pytest is invoked from python/ or repo root.
sys.path.insert(0, os.path.dirname(__file__))
