"""Causal flash attention as a configurable Pallas kernel (Layer 1).

This is the portatune analog of the paper's autotuned Triton flash
attention (Table I, row "Triton w/ autotuning"): a single,
platform-independent source whose performance-relevant decisions are all
expressed as *kernel configuration parameters*:

  - ``block_q``  — query-tile rows per grid step   (Triton BLOCK_M)
  - ``block_k``  — key/value-tile rows per inner step (Triton BLOCK_N)
  - ``unroll``   — k-loop unroll factor, the software-pipelining /
                   num_stages analog (see DESIGN.md §Hardware-Adaptation)

The kernel implements the online-softmax recurrence of FlashAttention-2
(Dao 2023): one pass over K/V per query tile, keeping the running max
``m``, normalizer ``l`` and accumulator ``acc`` in registers/VMEM.

Grouped-query attention (Llama-3: 32 query heads, 8 KV heads) is handled
in the BlockSpec index map: query head ``h`` reads KV head ``h // rep``.

TPU adaptation notes (vs. the Triton/CUDA original):
  - the K/V panel staged per inner step lives in VMEM, not CUDA shared
    memory; the VMEM footprint is ``vmem_bytes(...)`` below and is the
    validity constraint the Rust platform models enforce;
  - the (block_q x block_k) score matmul targets the MXU with f32
    accumulation (``preferred_element_type``), replacing tensor-core WMMA;
  - there is no thread/warp dimension: ``unroll`` expresses the ILP /
    pipelining trade that ``num_warps``/``num_stages`` express in Triton.

``interpret=True`` is mandatory: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")

#: The AOT configuration space (kept small enough to lower every variant;
#: the Rust simulator explores the full Triton-sized space analytically).
BLOCK_Q_CHOICES = (16, 32, 64, 128)
BLOCK_K_CHOICES = (16, 32, 64, 128)
UNROLL_CHOICES = (1, 2, 4)


def config_is_valid(seq_len: int, block_q: int, block_k: int, unroll: int) -> bool:
    """Static validity rules for an attention kernel configuration.

    Mirrors `rust/src/config/spaces.rs::attention_aot_space`; keep in sync.
    """
    if seq_len % block_q != 0 or seq_len % block_k != 0:
        return False
    nk = seq_len // block_k
    if unroll > 1 and nk % unroll != 0:
        return False
    return block_q <= seq_len and block_k <= seq_len


def vmem_bytes(block_q: int, block_k: int, head_dim: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working-set of one grid step.

    q tile + k panel + v panel + scores + accumulator (f32) + output tile.
    Used by the Rust perf models and by the §Perf L1 report.
    """
    q = block_q * head_dim * dtype_bytes
    kv = 2 * block_k * head_dim * dtype_bytes
    scores = block_q * block_k * 4
    acc = block_q * head_dim * 4
    out = block_q * head_dim * dtype_bytes
    return q + kv + scores + acc + out


def flops(batch: int, heads: int, seq_len: int, head_dim: int, causal: bool = True) -> int:
    """Model FLOPs of the attention computation (for MXU-utilization est.)."""
    full = 4 * batch * heads * seq_len * seq_len * head_dim
    return full // 2 if causal else full


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    block_q: int,
    block_k: int,
    unroll: int,
    sm_scale: float,
    causal: bool,
    seq_len: int,
):
    """One grid step: one (batch, head, query-tile) program instance."""
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32)  # [block_q, D]
    head_dim = q.shape[-1]

    acc = jnp.zeros((block_q, head_dim), jnp.float32)
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)

    def step(j, carry):
        """Process k/v panel j (statically unrolled ``unroll`` times)."""
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None))).astype(jnp.float32)
        # MXU: [block_q, D] x [D, block_k] with f32 accumulation.
        s = jax.lax.dot_general(
            q,
            k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        s = s * sm_scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        # Causal tiling guarantees panel 0 has an unmasked element per row
        # (qpos >= 0 == first kpos), so m_new is finite after the first
        # step and the exp() arguments never see (-inf) - (-inf).
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return acc_new, m_new, l_new

    nk_total = seq_len // block_k
    if causal:
        # Only panels that intersect the causal triangle of this query tile.
        # Last intersecting panel index: floor(((qi+1)*block_q - 1)/block_k).
        nk = ((qi + 1) * block_q - 1) // block_k + 1
    else:
        nk = nk_total

    if unroll <= 1:
        acc, m, l = jax.lax.fori_loop(0, nk, step, (acc, m, l))
    else:
        # Software pipelining analog: statically unroll the k-loop by
        # ``unroll``; the epilogue handles the causal remainder.
        def unrolled(jj, carry):
            for u in range(unroll):
                carry = step(jj * unroll + u, carry)
            return carry

        n_major = nk // unroll
        acc, m, l = jax.lax.fori_loop(0, n_major, unrolled, (acc, m, l))

        def epilogue(j, carry):
            return step(j, carry)

        acc, m, l = jax.lax.fori_loop(n_major * unroll, nk, epilogue, (acc, m, l))

    # Rows with l == 0 can only occur for non-causal fully-masked tiles,
    # which we never generate; still, guard the division.
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q,
    k,
    v,
    *,
    block_q: int = 32,
    block_k: int = 32,
    unroll: int = 1,
    causal: bool = True,
    sm_scale: float | None = None,
    interpret: bool = True,
):
    """Flash attention over ``q``[B,Hq,S,D], ``k``/``v``[B,Hkv,S,D].

    Grouped-query attention: Hq must be a multiple of Hkv; query head h
    attends with KV head ``h // (Hq // Hkv)`` via the BlockSpec index map.
    """
    batch, hq, seq_len, head_dim = q.shape
    hkv = k.shape[1]
    if hq % hkv != 0:
        raise ValueError(f"query heads {hq} not a multiple of kv heads {hkv}")
    if not config_is_valid(seq_len, block_q, block_k, unroll):
        raise ValueError(
            f"invalid attention config block_q={block_q} block_k={block_k} "
            f"unroll={unroll} for seq_len={seq_len}"
        )
    rep = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(head_dim)

    kern = functools.partial(
        _attn_kernel,
        block_q=block_q,
        block_k=block_k,
        unroll=unroll,
        sm_scale=sm_scale,
        causal=causal,
        seq_len=seq_len,
    )
    grid = (batch, hq, seq_len // block_q)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, head_dim), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, seq_len, head_dim), lambda b, h, i: (b, h // rep, 0, 0)),
            pl.BlockSpec((None, None, seq_len, head_dim), lambda b, h, i: (b, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, head_dim), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)


def enumerate_aot_configs(seq_len: int) -> list[dict[str, Any]]:
    """All valid AOT configurations for a given sequence length.

    The Rust coordinator's "AOT space"; every entry is lowered to its own
    HLO artifact by aot.py and empirically timed by the autotuner.
    """
    out = []
    for bq in BLOCK_Q_CHOICES:
        for bk in BLOCK_K_CHOICES:
            for u in UNROLL_CHOICES:
                if config_is_valid(seq_len, bq, bk, u):
                    out.append({"block_q": bq, "block_k": bk, "unroll": u})
    return out
