"""Layer-1 Pallas kernels for portatune.

Each kernel is written once, platform-independently, with its tunable
parameters (block shapes, unroll depth) exposed as keyword arguments —
the Pallas analog of Triton kernel configurations.  All kernels run under
``interpret=True`` so that the lowered HLO executes on any PJRT backend
(the Rust coordinator runs them on the CPU client).

Kernels:
  - :mod:`flash_attention` — causal/non-causal flash attention (the paper's
    primary investigation vehicle).
  - :mod:`rms_norm` — RMS layer normalization (the paper's secondary
    kernel).
  - :mod:`vector_add` — the Listing-1 pedagogical kernel.
  - :mod:`ref` — pure-jnp oracles used by pytest and by the Rust golden
    tests.
"""

from . import flash_attention, ref, rms_norm, vector_add  # noqa: F401

__all__ = ["flash_attention", "rms_norm", "vector_add", "ref"]
