"""Listing 1 of the paper: a one-dimensional vector add in a tiling DSL.

Used by the quickstart example and by the autotuner's unit tests — it is
the smallest kernel with a real configuration parameter (``block_size``,
the paper's ``BLOCK_SIZE``), so it exercises the full
space → search → artifact → execute pipeline cheaply.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_SIZE_CHOICES = (64, 128, 256, 512, 1024)


def config_is_valid(n_elements: int, block_size: int) -> bool:
    return n_elements % block_size == 0 and block_size <= n_elements


def _add_kernel(x_ref, y_ref, o_ref):
    # Straight port of the paper's Listing 1: the masked tail load is
    # unnecessary here because config_is_valid enforces divisibility,
    # which also keeps every lowered variant mask-free (cleaner Fig 5
    # opcode statistics).
    o_ref[...] = x_ref[...] + y_ref[...]


def vector_add(x, y, *, block_size: int = 256, interpret: bool = True):
    """Element-wise x + y over 1-D arrays, tiled by ``block_size``."""
    (n,) = x.shape
    if not config_is_valid(n, block_size):
        raise ValueError(f"invalid vector_add config block_size={block_size} for n={n}")
    return pl.pallas_call(
        _add_kernel,
        grid=(n // block_size,),
        in_specs=[
            pl.BlockSpec((block_size,), lambda i: (i,)),
            pl.BlockSpec((block_size,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_size,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, y)


def enumerate_aot_configs(n_elements: int) -> list[dict[str, Any]]:
    return [
        {"block_size": bs}
        for bs in BLOCK_SIZE_CHOICES
        if config_is_valid(n_elements, bs)
    ]
