"""RMS layer normalization as a configurable Pallas kernel (Layer 1).

The paper's second investigation vehicle (Table I row "RMS / Triton w/
autotuning", 96 LoC vs vLLM's 159-LoC CUDA kernel).  One row of the
hidden-states matrix is normalized per grid step; the tunable parameters
are:

  - ``block_h``   — how many hidden elements are processed per vector step
                    (the Triton BLOCK_SIZE analog); the row is streamed
                    through VMEM in ``hidden // block_h`` chunks.
  - ``rows_per_block`` — how many rows one grid step handles (grid
                    coarsening; trades launch overhead against parallelism,
                    the ``num_warps`` analog for this memory-bound kernel).

Accumulation is always f32 regardless of input dtype, matching
layernorm_kernels.cu semantics.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: AOT configuration space; mirrored in rust/src/config/spaces.rs.
BLOCK_H_CHOICES = (128, 256, 512, 1024, 2048, 4096)
ROWS_PER_BLOCK_CHOICES = (1, 2, 4)


def config_is_valid(n_rows: int, hidden: int, block_h: int, rows_per_block: int) -> bool:
    """Static validity rules; keep in sync with spaces.rs."""
    if hidden % block_h != 0:
        return False
    if n_rows % rows_per_block != 0:
        return False
    return block_h <= hidden


def vmem_bytes(block_h: int, rows_per_block: int, dtype_bytes: int = 4) -> int:
    """VMEM working set of one grid step (input chunk + f32 accum + out)."""
    return rows_per_block * (2 * block_h * dtype_bytes + block_h * 4) + block_h * dtype_bytes


def bytes_moved(n_rows: int, hidden: int, dtype_bytes: int = 4) -> int:
    """HBM traffic model: read x, read weight once, write out."""
    return n_rows * hidden * dtype_bytes * 2 + hidden * dtype_bytes


def _rms_kernel(x_ref, w_ref, o_ref, *, block_h: int, rows_per_block: int, hidden: int, eps: float):
    """Normalize ``rows_per_block`` rows, streaming ``block_h`` chunks."""
    n_chunks = hidden // block_h

    # Pass 1: accumulate sum of squares per row, chunk by chunk.
    def ss_step(c, ss):
        chunk = x_ref[:, pl.dslice(c * block_h, block_h)].astype(jnp.float32)
        return ss + jnp.sum(chunk * chunk, axis=-1)

    ss = jax.lax.fori_loop(0, n_chunks, ss_step, jnp.zeros((rows_per_block,), jnp.float32))
    rrms = jax.lax.rsqrt(ss / hidden + eps)  # [rows_per_block]

    # Pass 2: scale and write back, chunk by chunk.
    def write_step(c, _):
        chunk = x_ref[:, pl.dslice(c * block_h, block_h)].astype(jnp.float32)
        w = w_ref[pl.dslice(c * block_h, block_h)].astype(jnp.float32)
        normed = chunk * rrms[:, None] * w[None, :]
        o_ref[:, pl.dslice(c * block_h, block_h)] = normed.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, n_chunks, write_step, 0)


def rms_norm(
    x,
    weight,
    *,
    block_h: int = 512,
    rows_per_block: int = 1,
    eps: float = 1e-6,
    interpret: bool = True,
):
    """RMS-normalize ``x`` [N, H] by ``weight`` [H].

    Higher-rank inputs are flattened to [N, H] and restored on return.
    """
    orig_shape = x.shape
    hidden = orig_shape[-1]
    x2 = x.reshape(-1, hidden)
    n_rows = x2.shape[0]
    if not config_is_valid(n_rows, hidden, block_h, rows_per_block):
        raise ValueError(
            f"invalid rms config block_h={block_h} rows_per_block={rows_per_block} "
            f"for shape [{n_rows}, {hidden}]"
        )
    kern = functools.partial(
        _rms_kernel,
        block_h=block_h,
        rows_per_block=rows_per_block,
        hidden=hidden,
        eps=eps,
    )
    out = pl.pallas_call(
        kern,
        grid=(n_rows // rows_per_block,),
        in_specs=[
            pl.BlockSpec((rows_per_block, hidden), lambda r: (r, 0)),
            pl.BlockSpec((hidden,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_per_block, hidden), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, hidden), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out.reshape(orig_shape)


def enumerate_aot_configs(n_rows: int, hidden: int) -> list[dict[str, Any]]:
    """All valid AOT configurations for a workload shape."""
    out = []
    for bh in BLOCK_H_CHOICES:
        for rpb in ROWS_PER_BLOCK_CHOICES:
            if config_is_valid(n_rows, hidden, bh, rpb):
                out.append({"block_h": bh, "rows_per_block": rpb})
    return out
