"""Pure-jnp reference oracles for every Layer-1 kernel.

These are the ``pytorch native``-style implementations from the paper's
Table I: short, obviously correct, and the ground truth that every Pallas
kernel configuration must match within tolerance.  They are also lowered
to HLO by ``aot.py`` to serve as the *native baseline* artifacts that the
Rust experiments execute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")


def attention(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    """Naive materialized attention: O = softmax(Q K^T / sqrt(d)) V.

    Shapes: q ``[B, Hq, S, D]``; k, v ``[B, Hkv, S, D]`` with
    ``Hq % Hkv == 0`` (grouped-query attention, as in Llama-3).
    This is the 29-LoC "pytorch native" baseline of the paper: it
    materializes the full S x S score matrix, which is exactly why it is
    6-13x slower than flash attention on real hardware.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rms_norm(x, weight, *, eps: float = 1e-6):
    """RMS layer normalization [Zhang & Sennrich 2019].

    ``x``: [..., H]; ``weight``: [H].  Matches vLLM's
    layernorm_kernels.cu semantics (f32 accumulation, cast back).
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * weight.astype(jnp.float32)).astype(x.dtype)


def vector_add(x, y):
    """Listing 1: element-wise vector addition."""
    return x + y


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP used by the Llama-3 block in model.py."""
    g = jax.nn.silu(x @ w_gate)
    u = x @ w_up
    return (g * u) @ w_down


def rope(x, *, base: float = 500000.0):
    """Rotary position embedding (Llama-3 uses base 500000).

    ``x``: [B, H, S, D] with even D.  Returns same shape.
    """
    b, h, s, d = x.shape
    half = d // 2
    freqs = 1.0 / (base ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    t = jnp.arange(s, dtype=jnp.float32)
    angles = jnp.outer(t, freqs)  # [S, half]
    cos = jnp.cos(angles)[None, None, :, :]
    sin = jnp.sin(angles)[None, None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf1 * sin + xf2 * cos], axis=-1)
    return out.astype(x.dtype)
