"""Layer-2 JAX model: a Llama-3-style transformer block built on the
Layer-1 Pallas kernels.

The paper bases all kernel parameters on the Llama-3-8B architecture
(head size 128, 32 query heads, 8 KV heads).  This module assembles the
same attention layer — RMSNorm -> QKV projection -> RoPE -> flash
attention -> output projection — plus the SwiGLU MLP, entirely in JAX,
calling ``kernels.flash_attention`` and ``kernels.rms_norm`` for the two
performance-critical operators the paper studies.

``aot.py`` lowers :func:`transformer_block` (and the individual kernel
wrappers) to HLO text once; the Rust serving layer then executes the
artifacts with real weights streamed in as PJRT literals.  Python is never
on the request path.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import flash_attention as fa
from .kernels import ref
from .kernels import rms_norm as rn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Llama-3-8B-proportioned architecture (scaled down by default).

    The default is the "~100M-parameter-class" validation model used by
    the end-to-end serving example: same head geometry as Llama-3-8B
    (GQA 4:1, head_dim 128) with fewer heads and a narrower MLP so that a
    CPU PJRT backend can serve it interactively.
    """

    hidden: int = 1024
    n_q_heads: int = 8
    n_kv_heads: int = 2
    head_dim: int = 128
    mlp_hidden: int = 2816
    rope_base: float = 500000.0
    rms_eps: float = 1e-6
    dtype: Any = jnp.float32

    @property
    def q_dim(self) -> int:
        return self.n_q_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Parameters of ONE block (the serving example stacks several)."""
        attn = self.hidden * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.hidden
        mlp = 3 * self.hidden * self.mlp_hidden
        norms = 2 * self.hidden
        return attn + mlp + norms


#: Full Llama-3-8B head geometry, used for workload/shape accounting in
#: the experiments (the perf models need the real proportions).
LLAMA3_8B = ModelConfig(
    hidden=4096,
    n_q_heads=32,
    n_kv_heads=8,
    head_dim=128,
    mlp_hidden=14336,
)


def init_params(cfg: ModelConfig, key) -> dict[str, jax.Array]:
    """Xavier-ish init for one transformer block."""
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(cfg.hidden)
    return {
        "attn_norm_w": jnp.ones((cfg.hidden,), cfg.dtype),
        "mlp_norm_w": jnp.ones((cfg.hidden,), cfg.dtype),
        "wq": (jax.random.normal(ks[0], (cfg.hidden, cfg.q_dim)) * s).astype(cfg.dtype),
        "wk": (jax.random.normal(ks[1], (cfg.hidden, cfg.kv_dim)) * s).astype(cfg.dtype),
        "wv": (jax.random.normal(ks[2], (cfg.hidden, cfg.kv_dim)) * s).astype(cfg.dtype),
        "wo": (jax.random.normal(ks[3], (cfg.q_dim, cfg.hidden)) * s).astype(cfg.dtype),
        "w_gate": (jax.random.normal(ks[4], (cfg.hidden, cfg.mlp_hidden)) * s).astype(cfg.dtype),
        "w_up": (jax.random.normal(ks[5], (cfg.hidden, cfg.mlp_hidden)) * s).astype(cfg.dtype),
        "w_down": (jax.random.normal(ks[0], (cfg.mlp_hidden, cfg.hidden)) * s).astype(cfg.dtype),
    }


def param_order(cfg: ModelConfig) -> list[str]:
    """Deterministic argument order for the flat-arg AOT entry point.

    The Rust runtime feeds weights positionally; this list is written into
    the artifact manifest so both sides agree.
    """
    return [
        "attn_norm_w",
        "mlp_norm_w",
        "wq",
        "wk",
        "wv",
        "wo",
        "w_gate",
        "w_up",
        "w_down",
    ]


def attention_layer(
    x,
    params,
    cfg: ModelConfig,
    *,
    block_q: int = 32,
    block_k: int = 32,
    unroll: int = 1,
    use_pallas: bool = True,
):
    """The paper's unit of study: norm -> QKV -> RoPE -> attention -> out."""
    batch, seq, _ = x.shape
    if use_pallas:
        h = rn.rms_norm(x, params["attn_norm_w"], block_h=min(512, cfg.hidden), eps=cfg.rms_eps)
    else:
        h = ref.rms_norm(x, params["attn_norm_w"], eps=cfg.rms_eps)
    q = (h @ params["wq"]).reshape(batch, seq, cfg.n_q_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = (h @ params["wk"]).reshape(batch, seq, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = (h @ params["wv"]).reshape(batch, seq, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    q = ref.rope(q, base=cfg.rope_base)
    k = ref.rope(k, base=cfg.rope_base)
    if use_pallas:
        o = fa.flash_attention(q, k, v, block_q=block_q, block_k=block_k, unroll=unroll, causal=True)
    else:
        o = ref.attention(q, k, v, causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(batch, seq, cfg.q_dim)
    return x + o @ params["wo"]


def mlp_layer(x, params, cfg: ModelConfig, *, use_pallas: bool = True):
    """SwiGLU MLP with pre-RMSNorm."""
    if use_pallas:
        h = rn.rms_norm(x, params["mlp_norm_w"], block_h=min(512, cfg.hidden), eps=cfg.rms_eps)
    else:
        h = ref.rms_norm(x, params["mlp_norm_w"], eps=cfg.rms_eps)
    return x + ref.swiglu(h, params["w_gate"], params["w_up"], params["w_down"])


def transformer_block(
    x,
    params,
    cfg: ModelConfig,
    *,
    block_q: int = 32,
    block_k: int = 32,
    unroll: int = 1,
    use_pallas: bool = True,
):
    """One full pre-norm transformer block (attention + MLP)."""
    x = attention_layer(x, params, cfg, block_q=block_q, block_k=block_k, unroll=unroll, use_pallas=use_pallas)
    return mlp_layer(x, params, cfg, use_pallas=use_pallas)


def transformer_block_flat(cfg: ModelConfig, **kernel_cfg):
    """Flat-argument entry point for AOT lowering.

    Returns ``fn(x, *weights)`` with weights in :func:`param_order` order —
    the signature the Rust runtime calls.
    """
    order = param_order(cfg)

    def fn(x, *weights):
        params = dict(zip(order, weights))
        return (transformer_block(x, params, cfg, **kernel_cfg),)

    return fn


def block_flops(cfg: ModelConfig, batch: int, seq: int) -> int:
    """Model FLOPs of one block forward (for throughput accounting)."""
    proj = 2 * batch * seq * cfg.hidden * (cfg.q_dim + 2 * cfg.kv_dim + cfg.q_dim)
    attn = fa.flops(batch, cfg.n_q_heads, seq, cfg.head_dim, causal=True)
    mlp = 2 * batch * seq * 3 * cfg.hidden * cfg.mlp_hidden
    return proj + attn + mlp
