"""AOT compilation driver: lower every kernel configuration to HLO text.

This is the *entire* Python footprint of portatune at deployment time:
``make artifacts`` runs this module once, producing

    artifacts/
      manifest.json               index of every artifact (see below)
      attn/<bucket>/<cfg>.hlo.txt one per valid attention config per bucket
      attn/<bucket>/native.hlo.txt    the materialized-softmax baseline
      rms/<bucket>/<cfg>.hlo.txt  one per valid RMS-norm config per bucket
      rms/<bucket>/native.hlo.txt
      vecadd/<bucket>/<cfg>.hlo.txt
      model/<bucket>/<cfg>.hlo.txt    full transformer block for serving
      golden/*.json               tiny input/output vectors for Rust tests

after which the Rust coordinator is self-contained: it compiles the HLO
text with the PJRT CPU client and never touches Python again.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

The manifest records, for every artifact: the kernel, the workload
descriptor, the configuration dictionary, positional input specs, and an
environment fingerprint — everything the Rust cache needs to decide
whether a tuning result is reusable (paper §Q4.3, "reusable autotuning").
"""

from __future__ import annotations

import argparse
import hashlib
import json
import platform as _platform
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import flash_attention as fa
from .kernels import ref
from .kernels import rms_norm as rn
from .kernels import vector_add as va

DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.bfloat16.dtype: "bf16"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(x) -> dict:
    dtype = x.dtype if hasattr(x, "dtype") else jnp.asarray(x).dtype
    return {"shape": list(x.shape), "dtype": DTYPE_NAMES[jnp.dtype(dtype)]}


def env_fingerprint() -> dict:
    """Environment facts a cached tuning result depends on (Q4.3)."""
    return {
        "jax": jax.__version__,
        "python": sys.version.split()[0],
        "machine": _platform.machine(),
        "interchange": "hlo-text-v1",
    }


def _write(out_dir: Path, rel: str, text: str) -> dict:
    path = out_dir / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    return {"path": rel, "sha256_16": digest, "bytes": len(text)}


# ---------------------------------------------------------------------------
# Attention buckets
# ---------------------------------------------------------------------------


def attention_buckets(quick: bool) -> list[dict]:
    """Workload buckets for the attention kernel's AOT space.

    Geometry follows Llama-3 proportions (GQA 4:1) scaled so the CPU PJRT
    backend can execute a tuning sweep in seconds.  The first bucket gets
    the full configuration space (it feeds the Fig-5 real-HLO analysis);
    later buckets use a reduced space to bound `make artifacts` time.
    """
    buckets = [
        # bucket name pieces: batch, q_heads, kv_heads, seq_len, head_dim
        {"batch": 1, "q_heads": 8, "kv_heads": 2, "seq_len": 128, "head_dim": 64, "full": True},
        {"batch": 4, "q_heads": 8, "kv_heads": 2, "seq_len": 128, "head_dim": 64, "full": False},
        {"batch": 2, "q_heads": 8, "kv_heads": 2, "seq_len": 256, "head_dim": 64, "full": False},
    ]
    if quick:
        buckets = buckets[:1]
    return buckets


def attn_bucket_name(b: dict) -> str:
    return f"b{b['batch']}_h{b['q_heads']}kv{b['kv_heads']}_s{b['seq_len']}_d{b['head_dim']}"


def attn_configs_for(bucket: dict, quick: bool) -> list[dict]:
    cfgs = fa.enumerate_aot_configs(bucket["seq_len"])
    if not bucket.get("full", False):
        cfgs = [
            c
            for c in cfgs
            if c["block_q"] in (32, 64, 128) and c["block_k"] in (32, 64, 128) and c["unroll"] <= 2
        ]
    if quick:
        cfgs = cfgs[:4]
    return cfgs


def gen_attention(out_dir: Path, quick: bool) -> list[dict]:
    entries = []
    for bucket in attention_buckets(quick):
        name = attn_bucket_name(bucket)
        b, hq, hkv, s, d = (
            bucket["batch"],
            bucket["q_heads"],
            bucket["kv_heads"],
            bucket["seq_len"],
            bucket["head_dim"],
        )
        q = jax.ShapeDtypeStruct((b, hq, s, d), jnp.float32)
        kv = jax.ShapeDtypeStruct((b, hkv, s, d), jnp.float32)
        workload = {
            "batch": b,
            "q_heads": hq,
            "kv_heads": hkv,
            "seq_len": s,
            "head_dim": d,
            "dtype": "f32",
            "causal": True,
        }

        for cfg in attn_configs_for(bucket, quick):
            fn = lambda q, k, v: (
                fa.flash_attention(q, k, v, causal=True, **cfg),
            )
            text = to_hlo_text(jax.jit(fn).lower(q, kv, kv))
            rel = f"attn/{name}/bq{cfg['block_q']}_bk{cfg['block_k']}_u{cfg['unroll']}.hlo.txt"
            meta = _write(out_dir, rel, text)
            entries.append(
                {
                    "id": f"attn/{name}/bq{cfg['block_q']}_bk{cfg['block_k']}_u{cfg['unroll']}",
                    "kernel": "attention",
                    "impl": "pallas",
                    "workload": workload,
                    "config": cfg,
                    "inputs": [spec_of(q), spec_of(kv), spec_of(kv)],
                    "output": spec_of(q),
                    **meta,
                }
            )

        # Native (materialized-softmax) baseline for the same bucket.
        fn = lambda q, k, v: (ref.attention(q, k, v, causal=True),)
        text = to_hlo_text(jax.jit(fn).lower(q, kv, kv))
        meta = _write(out_dir, f"attn/{name}/native.hlo.txt", text)
        entries.append(
            {
                "id": f"attn/{name}/native",
                "kernel": "attention",
                "impl": "native",
                "workload": workload,
                "config": {},
                "inputs": [spec_of(q), spec_of(kv), spec_of(kv)],
                "output": spec_of(q),
                **meta,
            }
        )
        print(f"  attn bucket {name}: done")
    return entries


# ---------------------------------------------------------------------------
# RMS norm buckets
# ---------------------------------------------------------------------------


def rms_buckets(quick: bool) -> list[dict]:
    buckets = [
        {"n_rows": 64, "hidden": 1024},
        {"n_rows": 512, "hidden": 1024},
        {"n_rows": 256, "hidden": 4096},
    ]
    return buckets[:1] if quick else buckets


def gen_rms(out_dir: Path, quick: bool) -> list[dict]:
    entries = []
    for bucket in rms_buckets(quick):
        n, h = bucket["n_rows"], bucket["hidden"]
        name = f"n{n}_h{h}"
        x = jax.ShapeDtypeStruct((n, h), jnp.float32)
        w = jax.ShapeDtypeStruct((h,), jnp.float32)
        workload = {"n_rows": n, "hidden": h, "dtype": "f32"}
        cfgs = rn.enumerate_aot_configs(n, h)
        if quick:
            cfgs = cfgs[:3]
        for cfg in cfgs:
            fn = lambda x, w: (rn.rms_norm(x, w, **cfg),)
            text = to_hlo_text(jax.jit(fn).lower(x, w))
            rel = f"rms/{name}/bh{cfg['block_h']}_r{cfg['rows_per_block']}.hlo.txt"
            meta = _write(out_dir, rel, text)
            entries.append(
                {
                    "id": f"rms/{name}/bh{cfg['block_h']}_r{cfg['rows_per_block']}",
                    "kernel": "rms_norm",
                    "impl": "pallas",
                    "workload": workload,
                    "config": cfg,
                    "inputs": [spec_of(x), spec_of(w)],
                    "output": spec_of(x),
                    **meta,
                }
            )
        fn = lambda x, w: (ref.rms_norm(x, w),)
        text = to_hlo_text(jax.jit(fn).lower(x, w))
        meta = _write(out_dir, f"rms/{name}/native.hlo.txt", text)
        entries.append(
            {
                "id": f"rms/{name}/native",
                "kernel": "rms_norm",
                "impl": "native",
                "workload": workload,
                "config": {},
                "inputs": [spec_of(x), spec_of(w)],
                "output": spec_of(x),
                **meta,
            }
        )
        print(f"  rms bucket {name}: done")
    return entries


# ---------------------------------------------------------------------------
# Vector add (quickstart kernel)
# ---------------------------------------------------------------------------


def gen_vecadd(out_dir: Path, quick: bool) -> list[dict]:
    entries = []
    n = 4096
    x = jax.ShapeDtypeStruct((n,), jnp.float32)
    workload = {"n_elements": n, "dtype": "f32"}
    for cfg in va.enumerate_aot_configs(n):
        fn = lambda x, y: (va.vector_add(x, y, **cfg),)
        text = to_hlo_text(jax.jit(fn).lower(x, x))
        rel = f"vecadd/n{n}/bs{cfg['block_size']}.hlo.txt"
        meta = _write(out_dir, rel, text)
        entries.append(
            {
                "id": f"vecadd/n{n}/bs{cfg['block_size']}",
                "kernel": "vector_add",
                "impl": "pallas",
                "workload": workload,
                "config": cfg,
                "inputs": [spec_of(x), spec_of(x)],
                "output": spec_of(x),
                **meta,
            }
        )
    print(f"  vecadd bucket n{n}: done")
    return entries


# ---------------------------------------------------------------------------
# Full transformer block (the end-to-end serving model)
# ---------------------------------------------------------------------------


def model_buckets(quick: bool) -> list[dict]:
    buckets = [
        {"batch": 1, "seq_len": 128},
        {"batch": 2, "seq_len": 128},
        {"batch": 4, "seq_len": 128},
        {"batch": 1, "seq_len": 256},
        {"batch": 2, "seq_len": 256},
    ]
    return buckets[:1] if quick else buckets


def gen_model(out_dir: Path, quick: bool) -> tuple[list[dict], dict]:
    cfg = model_mod.ModelConfig()
    entries = []
    kernel_cfgs = [
        {"block_q": 32, "block_k": 32, "unroll": 1},
        {"block_q": 64, "block_k": 64, "unroll": 1},
        {"block_q": 32, "block_k": 64, "unroll": 2},
    ]
    if quick:
        kernel_cfgs = kernel_cfgs[:1]
    order = model_mod.param_order(cfg)
    shapes = {
        "attn_norm_w": (cfg.hidden,),
        "mlp_norm_w": (cfg.hidden,),
        "wq": (cfg.hidden, cfg.q_dim),
        "wk": (cfg.hidden, cfg.kv_dim),
        "wv": (cfg.hidden, cfg.kv_dim),
        "wo": (cfg.q_dim, cfg.hidden),
        "w_gate": (cfg.hidden, cfg.mlp_hidden),
        "w_up": (cfg.hidden, cfg.mlp_hidden),
        "w_down": (cfg.mlp_hidden, cfg.hidden),
    }
    weight_specs = [jax.ShapeDtypeStruct(shapes[k], jnp.float32) for k in order]
    for bucket in model_buckets(quick):
        b, s = bucket["batch"], bucket["seq_len"]
        name = f"b{b}_s{s}"
        x = jax.ShapeDtypeStruct((b, s, cfg.hidden), jnp.float32)
        for kc in kernel_cfgs:
            fn = model_mod.transformer_block_flat(cfg, **kc)
            text = to_hlo_text(jax.jit(fn).lower(x, *weight_specs))
            rel = f"model/{name}/bq{kc['block_q']}_bk{kc['block_k']}_u{kc['unroll']}.hlo.txt"
            meta = _write(out_dir, rel, text)
            entries.append(
                {
                    "id": f"model/{name}/bq{kc['block_q']}_bk{kc['block_k']}_u{kc['unroll']}",
                    "kernel": "transformer_block",
                    "impl": "pallas",
                    "workload": {"batch": b, "seq_len": s, "hidden": cfg.hidden, "dtype": "f32"},
                    "config": kc,
                    "inputs": [spec_of(x)] + [spec_of(wspec) for wspec in weight_specs],
                    "output": spec_of(x),
                    **meta,
                }
            )
        print(f"  model bucket {name}: done")
    model_desc = {
        "hidden": cfg.hidden,
        "n_q_heads": cfg.n_q_heads,
        "n_kv_heads": cfg.n_kv_heads,
        "head_dim": cfg.head_dim,
        "mlp_hidden": cfg.mlp_hidden,
        "param_order": order,
        "param_shapes": {k: list(shapes[k]) for k in order},
        "params_per_block": cfg.param_count(),
    }
    return entries, model_desc


# ---------------------------------------------------------------------------
# Golden vectors for the Rust integration tests
# ---------------------------------------------------------------------------


def _np_list(a) -> list:
    return np.asarray(a, dtype=np.float32).reshape(-1).tolist()


def gen_golden(out_dir: Path) -> list[dict]:
    """Tiny deterministic workloads with python-computed expected outputs.

    Rust integration tests load the HLO artifact, run it on the PJRT CPU
    client with these inputs, and assert allclose against the expected
    outputs — the cross-language numerical contract.
    """
    entries = []
    key = jax.random.PRNGKey(42)

    # Attention golden: B=1, Hq=2, Hkv=1, S=32, D=16.
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 1, 32, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1, 32, 16), jnp.float32)
    fn = lambda q, k, v: (fa.flash_attention(q, k, v, block_q=16, block_k=16, causal=True),)
    text = to_hlo_text(jax.jit(fn).lower(q, k, v))
    meta = _write(out_dir, "golden/attn_tiny.hlo.txt", text)
    expected = fa.flash_attention(q, k, v, block_q=16, block_k=16, causal=True)
    golden = {
        "artifact": meta["path"],
        "inputs": [
            {"shape": [1, 2, 32, 16], "data": _np_list(q)},
            {"shape": [1, 1, 32, 16], "data": _np_list(k)},
            {"shape": [1, 1, 32, 16], "data": _np_list(v)},
        ],
        "expected": {"shape": [1, 2, 32, 16], "data": _np_list(expected)},
        "atol": 2e-4,
        "rtol": 2e-4,
    }
    (out_dir / "golden/attn_tiny.json").write_text(json.dumps(golden))
    entries.append({"id": "golden/attn_tiny", "kernel": "attention", **meta})

    # RMS golden: [8, 512].
    x = jax.random.normal(ks[0], (8, 512), jnp.float32)
    w = jax.random.normal(ks[1], (512,), jnp.float32) * 0.1 + 1.0
    fn = lambda x, w: (rn.rms_norm(x, w, block_h=128, rows_per_block=2),)
    text = to_hlo_text(jax.jit(fn).lower(x, w))
    meta = _write(out_dir, "golden/rms_tiny.hlo.txt", text)
    expected = rn.rms_norm(x, w, block_h=128, rows_per_block=2)
    golden = {
        "artifact": meta["path"],
        "inputs": [
            {"shape": [8, 512], "data": _np_list(x)},
            {"shape": [512], "data": _np_list(w)},
        ],
        "expected": {"shape": [8, 512], "data": _np_list(expected)},
        "atol": 1e-4,
        "rtol": 1e-4,
    }
    (out_dir / "golden/rms_tiny.json").write_text(json.dumps(golden))
    entries.append({"id": "golden/rms_tiny", "kernel": "rms_norm", **meta})

    # Vector-add golden: [1024].
    x = jax.random.normal(ks[0], (1024,), jnp.float32)
    y = jax.random.normal(ks[1], (1024,), jnp.float32)
    fn = lambda x, y: (va.vector_add(x, y, block_size=256),)
    text = to_hlo_text(jax.jit(fn).lower(x, y))
    meta = _write(out_dir, "golden/vecadd_tiny.hlo.txt", text)
    golden = {
        "artifact": meta["path"],
        "inputs": [
            {"shape": [1024], "data": _np_list(x)},
            {"shape": [1024], "data": _np_list(y)},
        ],
        "expected": {"shape": [1024], "data": _np_list(x + y)},
        "atol": 1e-6,
        "rtol": 1e-6,
    }
    (out_dir / "golden/vecadd_tiny.json").write_text(json.dumps(golden))
    entries.append({"id": "golden/vecadd_tiny", "kernel": "vector_add", **meta})
    print("  golden vectors: done")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--quick", action="store_true", help="reduced set (CI smoke)")
    args = ap.parse_args()
    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    print("portatune AOT: lowering kernels to HLO text ...")
    artifacts = []
    artifacts += gen_vecadd(out_dir, args.quick)
    artifacts += gen_rms(out_dir, args.quick)
    artifacts += gen_attention(out_dir, args.quick)
    model_entries, model_desc = gen_model(out_dir, args.quick)
    artifacts += model_entries
    artifacts += gen_golden(out_dir)

    manifest = {
        "version": 1,
        "quick": args.quick,
        "env": env_fingerprint(),
        "model": model_desc,
        "artifacts": artifacts,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(artifacts)} artifacts + manifest to {out_dir}")


if __name__ == "__main__":
    main()
