//! Integration tests across module boundaries: real PJRT autotuning,
//! cache persistence through the full tune path, cross-platform
//! tune/transplant pipeline, and the serving router end to end.

#[cfg(feature = "pjrt")]
use portatune::autotuner::PjrtEvaluator;
use portatune::autotuner::{SessionOutcome, SimEvaluator, TuningSession};
#[cfg(feature = "pjrt")]
use portatune::cache::TuningCache;
use portatune::config::spaces;
use portatune::experiments;
use portatune::kernels::baselines::{triton_codegen, TemplateLibrary};
use portatune::platform::{PlatformId, SimGpu};
#[cfg(feature = "pjrt")]
use portatune::runtime::{Engine, Manifest};
use portatune::serving::{
    router::synth_trace, BucketPolicy, ChaosBackend, DynamicBatcher, FaultPlan, Request, Router,
    ServerConfig, SimBackend, VerbRates,
};
use portatune::util::tmp::TempDir;
use portatune::workload::Workload;
use std::time::{Duration, Instant};

#[cfg(feature = "pjrt")]
fn artifacts_present() -> bool {
    portatune::artifact_dir().join("manifest.json").exists()
}

#[cfg(feature = "pjrt")]
#[test]
fn real_pjrt_autotune_vecadd() {
    // The full empirical loop on real artifacts: enumerate -> compile ->
    // measure -> pick. Uses vector-add (cheapest kernel).
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let engine = Engine::cpu().unwrap();
    let w = manifest.workload_buckets("vector_add")[0];
    let space = spaces::aot_space_for(&w);
    let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 1, 3).unwrap();
    let out = TuningSession::new(&space, &w)
        .evaluator(&mut eval)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();
    assert!(out.best_latency_us > 0.0);
    assert_eq!(out.evaluated, space.enumerate(&w).count());
    assert!(space.contains(&out.best, &w));
}

#[cfg(feature = "pjrt")]
#[test]
fn real_pjrt_autotune_rms_with_persistent_cache() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let engine = Engine::cpu().unwrap();
    let w = manifest.workload_buckets("rms_norm")[0];
    let space = spaces::aot_space_for(&w);
    // The AOT space enumerates more configs than were lowered for this
    // bucket; missing artifacts must surface as invalid, not errors.
    let dir = TempDir::new("pipeline-cache").unwrap();
    let cache_path = dir.join("cache.json");
    let best_first;
    {
        let mut cache = TuningCache::open(&cache_path).unwrap();
        let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 1, 3).unwrap();
        let out = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert!(!out.from_cache);
        best_first = out.best.clone();
        cache.save().unwrap();
    }
    // Re-open: the déjà-vu path (paper Q4.3) must serve from disk.
    {
        let mut cache = TuningCache::open(&cache_path).unwrap();
        assert_eq!(cache.len(), 1);
        let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 1, 3).unwrap();
        let out = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert!(out.from_cache);
        assert_eq!(out.best, best_first);
        assert_eq!(out.evaluated, 0);
    }
}

#[test]
fn cross_platform_tune_then_transplant_pipeline() {
    // Sim pipeline mirroring the paper's Q2 experiment end to end.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let a100 = SimGpu::a100();
    let mi250 = SimGpu::mi250();

    let mut ea = SimEvaluator::new(a100.clone(), w, triton_codegen(a100.spec.vendor));
    let oa = TuningSession::new(&space, &w)
        .evaluator(&mut ea)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();
    let mut em = SimEvaluator::new(mi250.clone(), w, triton_codegen(mi250.spec.vendor));
    let om = TuningSession::new(&space, &w)
        .evaluator(&mut em)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();

    // Native optima differ and transplants lose (or are invalid).
    assert_ne!(oa.best, om.best);
    match mi250.attention_latency_us(&oa.best, &w, &triton_codegen(mi250.spec.vendor)) {
        Ok(us) => assert!(us >= om.best_latency_us),
        Err(_) => {} // invalid on MI250: also a paper outcome
    }
    let back = a100
        .attention_latency_us(&om.best, &w, &triton_codegen(a100.spec.vendor))
        .expect("MI250 optima are small-staging; they run on A100");
    assert!(back > oa.best_latency_us, "transplant cannot beat native tuning");
}

// ---------------------------------------------------------------------
// Serving core, default features: the backend-agnostic executor/router
// driven end to end by the SimBackend — no artifacts, no toolchain.
// ---------------------------------------------------------------------

#[test]
fn sim_serve_smoke_cold_then_tuned_is_no_slower() {
    // The acceptance contract of the backend split: a seeded trace
    // replayed cold and then tuned on the deterministic sim backend
    // completes every request, and tuning can only help (the tuned
    // variant is the per-bucket argmin over the same analytical model).
    // A huge flush deadline makes batching a pure function of the
    // request order, so both replays see identical batch shapes.
    let cfg = ServerConfig { max_wait_us: 10_000_000, idle_tuning: true, ..Default::default() };
    let router = Router::sim(SimBackend::new(portatune::platform::SimGpu::a100(), 11), &cfg).unwrap();
    let max_tokens = router.policy().seq_buckets.last().copied().unwrap();
    let trace = synth_trace(64, max_tokens, 42);

    let cold = router.serve_trace(trace.clone()).unwrap();
    assert_eq!(cold.requests, 64, "every request must complete");
    assert_eq!(cold.rejected, 0);
    assert!(cold.exec_mean_us > 0.0);

    router.finish_tuning().unwrap();
    let stats = router.executor().stats().unwrap();
    assert!(stats.variants_measured > 0, "idle tuning must have measured variants");
    assert!(!stats.active_us.is_empty(), "every tuned bucket reports its winner's latency");
    for s in &stats.swaps {
        assert!(s.gain > 1.0, "swap without improvement: {s:?}");
    }

    let tuned = router.serve_trace(trace).unwrap();
    assert_eq!(tuned.requests, 64);
    assert!(
        tuned.exec_mean_us <= cold.exec_mean_us,
        "tuned mean exec {} us must not exceed cold {} us",
        tuned.exec_mean_us,
        cold.exec_mean_us
    );
}

#[test]
fn sim_serving_winners_survive_restart_via_cache() {
    // Q4.3 x Q4.4 on the default build: tune once, persist, restart
    // the server -> warm start with zero re-tuning.
    let dir = TempDir::new("sim-serve-cache").unwrap();
    let cfg = ServerConfig {
        max_wait_us: 500,
        idle_tuning: true,
        cache_path: Some(dir.join("serving_cache.json")),
        ..Default::default()
    };
    let backend = || SimBackend::new(portatune::platform::SimGpu::mi250(), 3);
    let (actives, measured);
    {
        let router = Router::sim(backend(), &cfg).unwrap();
        router.finish_tuning().unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, 0, "first boot is cold");
        measured = stats.variants_measured;
        assert!(measured > 0);
        actives = stats.active.clone();
    }
    {
        let router = Router::sim(backend(), &cfg).unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, actives.len(), "all buckets warm-started");
        assert_eq!(stats.variants_measured, 0, "no re-tuning on restart");
        assert_eq!(stats.active, actives, "cached winners adopted");
        // finish_tuning is now a no-op (queue emptied by warm start).
        router.finish_tuning().unwrap();
        assert_eq!(router.executor().stats().unwrap().variants_measured, 0);
    }
}

#[test]
fn sim_serve_platforms_have_disjoint_cache_namespaces() {
    // An a100 server and an mi250 server sharing one cache file must
    // never adopt each other's winners (the platform fingerprint is
    // part of the key).
    let dir = TempDir::new("sim-serve-cross").unwrap();
    let cfg = ServerConfig {
        max_wait_us: 500,
        idle_tuning: true,
        cache_path: Some(dir.join("shared_cache.json")),
        ..Default::default()
    };
    {
        let router = Router::sim(SimBackend::new(portatune::platform::SimGpu::a100(), 5), &cfg).unwrap();
        router.finish_tuning().unwrap();
        assert!(router.executor().stats().unwrap().variants_measured > 0);
    }
    {
        // Different platform, same cache file: must boot cold.
        let router = Router::sim(SimBackend::new(portatune::platform::SimGpu::mi250(), 5), &cfg).unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, 0, "mi250 must not adopt a100 winners");
        router.finish_tuning().unwrap();
        assert!(router.executor().stats().unwrap().variants_measured > 0);
    }
}

#[test]
fn bucket_policy_edge_cases() {
    // Empty grid: nothing fits, nothing panics.
    let empty = BucketPolicy::new(vec![], 1_000);
    assert!(empty.seq_buckets.is_empty());
    assert_eq!(empty.bucket_for(1), None);
    assert_eq!(empty.bucket_for(usize::MAX), None);
    let mut b = DynamicBatcher::new(empty);
    let now = Instant::now();
    assert!(b.push(Request { id: 1, tokens: 8 }, now).is_none());
    assert_eq!(b.rejected.len(), 1);
    assert!(b.next_batch(now, true).is_none());

    // Exact fit routes to the boundary bucket; one past it spills to
    // the next; past the largest is rejected.
    let p = BucketPolicy::new(vec![(128, 2), (256, 4)], 1_000);
    assert_eq!(p.bucket_for(128), Some(0), "exact fit stays in the small bucket");
    assert_eq!(p.bucket_for(129), Some(1));
    assert_eq!(p.bucket_for(256), Some(1), "exact fit in the largest bucket");
    assert_eq!(p.bucket_for(257), None, "oversize requests have no bucket");
    assert_eq!(p.max_batch(0), 2);
    assert_eq!(p.max_batch(1), 4);
    assert_eq!(p.batch_shape_for(1, 3), 4, "partial batches pad up to a compiled size");
    assert_eq!(p.batch_shape_for(1, 5), 4, "over-full requests clamp to the largest batch");
}

#[test]
fn batcher_bucket_overflow_splits_into_full_batches() {
    // 10 requests into a bucket compiled for at most 4: two full
    // batches flush immediately, the remainder waits for the deadline.
    let p = BucketPolicy::new(vec![(128, 4)], 10_000);
    let mut b = DynamicBatcher::new(p);
    let t0 = Instant::now();
    for i in 0..10 {
        b.push(Request { id: i, tokens: 100 }, t0);
    }
    let first = b.next_batch(t0, false).expect("full batch ready");
    assert_eq!(first.requests.len(), 4);
    assert_eq!(first.batch_shape, 4);
    let second = b.next_batch(t0, false).expect("second full batch ready");
    assert_eq!(second.requests.len(), 4);
    assert!(b.next_batch(t0, false).is_none(), "2 leftovers must wait for the deadline");
    assert_eq!(b.pending(), 2);
    // Deadline flush: once the oldest leftover has waited max_wait_us,
    // the partial batch goes out padded to a compiled shape.
    let later = t0 + Duration::from_micros(10_001);
    let tail = b.next_batch(later, false).expect("deadline flush");
    assert_eq!(tail.requests.len(), 2);
    assert_eq!(tail.batch_shape, 4, "partial flush pads up to a compiled size");
    assert_eq!(b.pending(), 0);
    // FIFO preserved across the splits.
    let ids: Vec<u64> = first.requests.iter().chain(&second.requests).chain(&tail.requests).map(|r| r.id).collect();
    assert_eq!(ids, (0..10).collect::<Vec<u64>>());
}

#[cfg(feature = "pjrt")]
#[test]
fn serving_router_end_to_end_smoke() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let router = Router::pjrt(
        manifest,
        &ServerConfig { max_wait_us: 500, idle_tuning: false, ..Default::default() },
    )
    .unwrap();
    let trace = synth_trace(6, router.policy().seq_buckets.last().copied().unwrap(), 9);
    let report = router.serve_trace(trace).unwrap();
    assert_eq!(report.requests, 6);
    assert_eq!(report.rejected, 0);
    assert!(report.throughput_rps > 0.0);
    assert!(report.latency_p50_us > 0.0);
    assert!(report.latency_p99_us >= report.latency_p50_us);
}

#[cfg(feature = "pjrt")]
#[test]
fn serving_background_tuning_improves_or_keeps_active_variants() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let router = Router::pjrt(
        manifest,
        &ServerConfig { max_wait_us: 500, idle_tuning: true, ..Default::default() },
    )
    .unwrap();
    router.finish_tuning().unwrap();
    let stats = router.executor().stats().unwrap();
    assert!(stats.variants_measured >= stats.active.len());
    // Every swap must claim a strict improvement.
    for s in &stats.swaps {
        assert!(s.gain > 1.0, "swap {:?} without improvement", s.shape);
    }
    // After tuning, the active variant of each shape is its measured argmin.
    assert!(!stats.active_us.is_empty());
}

#[cfg(feature = "pjrt")]
#[test]
fn serving_winners_survive_restart_via_cache() {
    // Q4.3 x Q4.4: tune once, persist, restart the server -> warm start
    // with zero re-tuning.
    if !artifacts_present() {
        return;
    }
    let dir = TempDir::new("serve-cache").unwrap();
    let cache_path = dir.join("serving_cache.json");
    let cfg = ServerConfig {
        max_wait_us: 500,
        idle_tuning: true,
        cache_path: Some(cache_path.clone()),
        ..Default::default()
    };
    let (actives, measured);
    {
        let router = Router::pjrt(Manifest::load_default().unwrap(), &cfg).unwrap();
        router.finish_tuning().unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, 0, "first boot is cold");
        measured = stats.variants_measured;
        assert!(measured > 0);
        actives = stats.active.clone();
    }
    assert!(cache_path.exists(), "winners persisted");
    {
        let router = Router::pjrt(Manifest::load_default().unwrap(), &cfg).unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, actives.len(), "all buckets warm-started");
        assert_eq!(stats.variants_measured, 0, "no re-tuning on restart");
        assert_eq!(stats.active, actives, "cached winners adopted");
        // finish_tuning is now a no-op (queue emptied by warm start).
        router.finish_tuning().unwrap();
        assert_eq!(router.executor().stats().unwrap().variants_measured, 0);
    }
}

// ---------------------------------------------------------------------
// Chaos: deterministic fault injection through the full serving stack.
// Same seed => same faults => bit-identical reports; the executor's
// retry / circuit-breaker / fallback machinery absorbs the rest.
// ---------------------------------------------------------------------

/// A huge flush deadline + no idle tuning makes the backend call
/// sequence a pure function of the trace, so fault fates line up
/// across runs.
fn chaos_cfg() -> ServerConfig {
    ServerConfig { max_wait_us: 10_000_000, idle_tuning: false, ..Default::default() }
}

#[test]
fn chaos_serve_is_bit_reproducible_per_seed() {
    let run = || {
        let router = Router::with_backend(
            move || {
                Ok(ChaosBackend::new(SimBackend::new(SimGpu::a100(), 11), FaultPlan::uniform(7, 0.1)))
            },
            &chaos_cfg(),
        )
        .unwrap();
        let max_tokens = router.policy().seq_buckets.last().copied().unwrap();
        let trace = synth_trace(48, max_tokens, 42);
        let cold = router.serve_trace(trace.clone()).unwrap();
        router.finish_tuning().unwrap();
        let tuned = router.serve_trace(trace).unwrap();
        (cold.replay_digest(), tuned.replay_digest(), tuned.faults.injected)
    };
    let (cold1, tuned1, injected1) = run();
    let (cold2, tuned2, injected2) = run();
    assert!(injected1 > 0, "rate 0.1 over a 48-request serve + tuning must inject faults");
    assert_eq!(cold1, cold2, "cold replay digest must be bit-identical across runs");
    assert_eq!(tuned1, tuned2, "tuned replay digest must be bit-identical across runs");
    assert_eq!(injected1, injected2);
}

#[test]
fn chaos_transient_faults_converge_to_the_fault_free_winner() {
    // Measure-only transients: retries re-draw the fate per attempt, so
    // tuning eventually records the exact fault-free latencies and the
    // per-bucket argmin lands on the same winners, bit for bit.
    let cfg = chaos_cfg();
    let plan = FaultPlan {
        seed: 3,
        transient: VerbRates { measure: 0.3, ..VerbRates::default() },
        ..FaultPlan::default()
    };
    let chaos = Router::with_backend(
        move || Ok(ChaosBackend::new(SimBackend::new(SimGpu::mi250(), 9), plan)),
        &cfg,
    )
    .unwrap();
    let clean = Router::sim(SimBackend::new(SimGpu::mi250(), 9), &cfg).unwrap();
    chaos.finish_tuning().unwrap();
    clean.finish_tuning().unwrap();
    let cs = chaos.executor().stats().unwrap();
    let ks = clean.executor().stats().unwrap();
    assert!(cs.faults.injected > 0, "rate 0.3 across tuning measurements must inject");
    assert_eq!(cs.active, ks.active, "chaos tuning must land on the fault-free winners");
    assert_eq!(cs.active_us.len(), ks.active_us.len());
    for (bucket, want) in &ks.active_us {
        let got = cs.active_us.get(bucket).expect("bucket missing under chaos");
        assert_eq!(got.to_bits(), want.to_bits(), "winner latency differs in bucket {bucket}");
    }
    // The tuned replay is equally untouched: faults only hit `measure`.
    let max_tokens = clean.policy().seq_buckets.last().copied().unwrap();
    let trace = synth_trace(32, max_tokens, 5);
    let a = chaos.serve_trace(trace.clone()).unwrap();
    let b = clean.serve_trace(trace).unwrap();
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.exec_mean_us.to_bits(), b.exec_mean_us.to_bits());
}

#[test]
fn quarantine_reprobe_lifecycle_writes_off_persistently_failing_variants() {
    // Measure always faults: every variant climbs the full breaker
    // ladder (K consecutive failures -> quarantine -> cooldown ->
    // re-probe -> written off) while the serving path stays healthy.
    let plan = FaultPlan {
        seed: 5,
        transient: VerbRates { measure: 1.0, ..VerbRates::default() },
        ..FaultPlan::default()
    };
    let router = Router::with_backend(
        move || {
            Ok(ChaosBackend::new(
                SimBackend::new(SimGpu::a100(), 5)
                    .with_shapes(&[(1, 128)])
                    .with_variants_per_bucket(3),
                plan,
            ))
        },
        &chaos_cfg(),
    )
    .unwrap();
    router.finish_tuning().unwrap();
    let stats = router.executor().stats().unwrap();
    assert_eq!(stats.variants_measured, 0, "measure always faults: nothing can be measured");
    assert_eq!(stats.faults.quarantined, 3, "each variant trips its breaker once");
    assert_eq!(stats.faults.reprobed, 3, "each quarantined variant gets one re-probe");
    assert_eq!(stats.faults.gave_up, 3, "failed re-probes write the variants off");
    assert!(stats.swaps.is_empty(), "no measurements, no swaps");
    // Execution is untouched (only measure faults): requests still serve.
    let trace = synth_trace(8, 128, 1);
    let report = router.serve_trace(trace).unwrap();
    assert_eq!(report.requests, 8);
    assert_eq!(report.shed, 0);
}

#[test]
fn quarantined_variant_recovers_after_brownout_heals() {
    // An injection budget models a brown-out: 3 hard-fail rounds of 4
    // attempts exhaust the 12 injections while the variant sits
    // quarantined; the post-cooldown re-probe then hits a healed
    // backend and the variant returns to service.
    let plan = FaultPlan {
        seed: 5,
        transient: VerbRates { measure: 1.0, ..VerbRates::default() },
        max_injected: Some(12),
        ..FaultPlan::default()
    };
    let router = Router::with_backend(
        move || {
            Ok(ChaosBackend::new(
                SimBackend::new(SimGpu::a100(), 5)
                    .with_shapes(&[(1, 128)])
                    .with_variants_per_bucket(1),
                plan,
            ))
        },
        &chaos_cfg(),
    )
    .unwrap();
    router.finish_tuning().unwrap();
    let stats = router.executor().stats().unwrap();
    assert_eq!(stats.faults.injected, 12, "the injection budget is exhausted exactly");
    assert_eq!(stats.faults.failures, 12);
    assert_eq!(stats.faults.retries, 9, "three retries per hard-fail round");
    assert_eq!(stats.faults.quarantined, 1);
    assert_eq!(stats.faults.reprobed, 1, "the post-cooldown re-probe hits the healed backend");
    assert_eq!(stats.faults.gave_up, 0, "the healed variant is not written off");
    assert_eq!(stats.variants_measured, 1, "the healed variant is finally measured");
}

#[test]
fn chaos_serve_completes_and_tuned_still_improves_on_cold() {
    // The PR's acceptance contract: a chaos serve at rate 0.1 panics
    // nowhere, accounts for every request (served or shed with a typed
    // error), reports its fault counters, and background tuning still
    // helps.
    let cfg = ServerConfig { max_wait_us: 10_000_000, idle_tuning: true, ..Default::default() };
    let router = Router::with_backend(
        move || {
            Ok(ChaosBackend::new(SimBackend::new(SimGpu::a100(), 11), FaultPlan::uniform(7, 0.1)))
        },
        &cfg,
    )
    .unwrap();
    let max_tokens = router.policy().seq_buckets.last().copied().unwrap();
    let trace = synth_trace(64, max_tokens, 42);

    let cold = router.serve_trace(trace.clone()).unwrap();
    assert_eq!(cold.requests + cold.shed, 64, "every request is served or shed, never lost");
    assert_eq!(cold.rejected, 0);

    router.finish_tuning().unwrap();
    let tuned = router.serve_trace(trace).unwrap();
    assert_eq!(tuned.requests + tuned.shed, 64);
    assert!(tuned.faults.injected > 0, "rate 0.1 must inject faults somewhere");
    assert!(
        tuned.exec_mean_us <= cold.exec_mean_us,
        "tuned mean exec {} us must not exceed cold {} us even under chaos",
        tuned.exec_mean_us,
        cold.exec_mean_us
    );
}

#[test]
fn experiments_run_all_produces_every_report() {
    let reports = experiments::run_all();
    let slugs: Vec<&str> = reports.iter().map(|(s, _)| s.as_str()).collect();
    for expected in [
        "fig1a", "fig1b", "fig1c", "fig2a", "fig2b", "fig2_summary", "fig3", "fig4", "fig5a",
        "fig5b", "fig5_real_hlo", "table1", "table2",
    ] {
        assert!(slugs.contains(&expected), "missing report {expected}");
    }
    for (slug, rep) in &reports {
        assert!(!rep.columns.is_empty(), "{slug} has no columns");
        if slug != "fig5_real_hlo" {
            assert!(!rep.rows.is_empty(), "{slug} has no rows");
        }
        // TSV render includes every row.
        let tsv = rep.to_tsv();
        assert_eq!(
            tsv.lines().filter(|l| !l.starts_with('#')).count(),
            rep.rows.len() + 1,
            "{slug} TSV row count"
        );
    }
}

#[test]
fn reports_save_to_disk() {
    let dir = TempDir::new("reports").unwrap();
    let rep = experiments::tables::table2();
    rep.save_tsv(dir.path(), "table2").unwrap();
    let text = std::fs::read_to_string(dir.join("table2.tsv")).unwrap();
    assert!(text.contains("sglang"));
}

#[test]
fn platform_fingerprints_are_distinct_and_stable() {
    let a = PlatformId::SimA100.fingerprint();
    let b = PlatformId::SimMi250.fingerprint();
    let c = PlatformId::CpuPjrt.fingerprint();
    assert_ne!(a, b);
    assert_ne!(a, c);
    assert_eq!(a, PlatformId::SimA100.fingerprint());
}

#[test]
fn vendor_library_never_serves_foreign_platform() {
    let lib = TemplateLibrary::flash_attn();
    assert!(lib.latency_us(&SimGpu::mi250(), &Workload::llama3_attention(4, 512)).is_err());
    let rocm = TemplateLibrary::rocm_flash_attn();
    assert!(rocm.latency_us(&SimGpu::a100(), &Workload::llama3_attention(4, 512)).is_err());
}
