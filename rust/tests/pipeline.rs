//! Integration tests across module boundaries: real PJRT autotuning,
//! cache persistence through the full tune path, cross-platform
//! tune/transplant pipeline, and the serving router end to end.

#[cfg(feature = "pjrt")]
use portatune::autotuner::PjrtEvaluator;
use portatune::autotuner::{SessionOutcome, SimEvaluator, TuningSession};
#[cfg(feature = "pjrt")]
use portatune::cache::TuningCache;
use portatune::config::spaces;
use portatune::experiments;
use portatune::kernels::baselines::{triton_codegen, TemplateLibrary};
use portatune::platform::{PlatformId, SimGpu};
#[cfg(feature = "pjrt")]
use portatune::runtime::{Engine, Manifest};
#[cfg(feature = "pjrt")]
use portatune::serving::{router::synth_trace, Router, ServerConfig};
use portatune::util::tmp::TempDir;
use portatune::workload::Workload;

#[cfg(feature = "pjrt")]
fn artifacts_present() -> bool {
    portatune::artifact_dir().join("manifest.json").exists()
}

#[cfg(feature = "pjrt")]
#[test]
fn real_pjrt_autotune_vecadd() {
    // The full empirical loop on real artifacts: enumerate -> compile ->
    // measure -> pick. Uses vector-add (cheapest kernel).
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let engine = Engine::cpu().unwrap();
    let w = manifest.workload_buckets("vector_add")[0];
    let space = spaces::aot_space_for(&w);
    let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 1, 3).unwrap();
    let out = TuningSession::new(&space, &w)
        .evaluator(&mut eval)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();
    assert!(out.best_latency_us > 0.0);
    assert_eq!(out.evaluated, space.enumerate(&w).count());
    assert!(space.contains(&out.best, &w));
}

#[cfg(feature = "pjrt")]
#[test]
fn real_pjrt_autotune_rms_with_persistent_cache() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let engine = Engine::cpu().unwrap();
    let w = manifest.workload_buckets("rms_norm")[0];
    let space = spaces::aot_space_for(&w);
    // The AOT space enumerates more configs than were lowered for this
    // bucket; missing artifacts must surface as invalid, not errors.
    let dir = TempDir::new("pipeline-cache").unwrap();
    let cache_path = dir.join("cache.json");
    let best_first;
    {
        let mut cache = TuningCache::open(&cache_path).unwrap();
        let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 1, 3).unwrap();
        let out = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert!(!out.from_cache);
        best_first = out.best.clone();
        cache.save().unwrap();
    }
    // Re-open: the déjà-vu path (paper Q4.3) must serve from disk.
    {
        let mut cache = TuningCache::open(&cache_path).unwrap();
        assert_eq!(cache.len(), 1);
        let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 1, 3).unwrap();
        let out = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert!(out.from_cache);
        assert_eq!(out.best, best_first);
        assert_eq!(out.evaluated, 0);
    }
}

#[test]
fn cross_platform_tune_then_transplant_pipeline() {
    // Sim pipeline mirroring the paper's Q2 experiment end to end.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let a100 = SimGpu::a100();
    let mi250 = SimGpu::mi250();

    let mut ea = SimEvaluator::new(a100.clone(), w, triton_codegen(a100.spec.vendor));
    let oa = TuningSession::new(&space, &w)
        .evaluator(&mut ea)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();
    let mut em = SimEvaluator::new(mi250.clone(), w, triton_codegen(mi250.spec.vendor));
    let om = TuningSession::new(&space, &w)
        .evaluator(&mut em)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();

    // Native optima differ and transplants lose (or are invalid).
    assert_ne!(oa.best, om.best);
    match mi250.attention_latency_us(&oa.best, &w, &triton_codegen(mi250.spec.vendor)) {
        Ok(us) => assert!(us >= om.best_latency_us),
        Err(_) => {} // invalid on MI250: also a paper outcome
    }
    let back = a100
        .attention_latency_us(&om.best, &w, &triton_codegen(a100.spec.vendor))
        .expect("MI250 optima are small-staging; they run on A100");
    assert!(back > oa.best_latency_us, "transplant cannot beat native tuning");
}

#[cfg(feature = "pjrt")]
#[test]
fn serving_router_end_to_end_smoke() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let router = Router::new(
        manifest,
        &ServerConfig { max_wait_us: 500, idle_tuning: false, cache_path: None },
    )
    .unwrap();
    let trace = synth_trace(6, router.policy().seq_buckets.last().copied().unwrap(), 9);
    let report = router.serve_trace(trace).unwrap();
    assert_eq!(report.requests, 6);
    assert_eq!(report.rejected, 0);
    assert!(report.throughput_rps > 0.0);
    assert!(report.latency_p50_us > 0.0);
    assert!(report.latency_p99_us >= report.latency_p50_us);
}

#[cfg(feature = "pjrt")]
#[test]
fn serving_background_tuning_improves_or_keeps_active_variants() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let router = Router::new(
        manifest,
        &ServerConfig { max_wait_us: 500, idle_tuning: true, cache_path: None },
    )
    .unwrap();
    router.finish_tuning().unwrap();
    let stats = router.executor().stats().unwrap();
    assert!(stats.variants_measured >= stats.active.len());
    // Every swap must claim a strict improvement.
    for s in &stats.swaps {
        assert!(s.gain > 1.0, "swap {:?} without improvement", s.shape);
    }
    // After tuning, the active variant of each shape is its measured argmin.
    assert!(!stats.active_us.is_empty());
}

#[cfg(feature = "pjrt")]
#[test]
fn serving_winners_survive_restart_via_cache() {
    // Q4.3 x Q4.4: tune once, persist, restart the server -> warm start
    // with zero re-tuning.
    if !artifacts_present() {
        return;
    }
    let dir = TempDir::new("serve-cache").unwrap();
    let cache_path = dir.join("serving_cache.json");
    let cfg = ServerConfig {
        max_wait_us: 500,
        idle_tuning: true,
        cache_path: Some(cache_path.clone()),
    };
    let (actives, measured);
    {
        let router = Router::new(Manifest::load_default().unwrap(), &cfg).unwrap();
        router.finish_tuning().unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, 0, "first boot is cold");
        measured = stats.variants_measured;
        assert!(measured > 0);
        actives = stats.active.clone();
    }
    assert!(cache_path.exists(), "winners persisted");
    {
        let router = Router::new(Manifest::load_default().unwrap(), &cfg).unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, actives.len(), "all buckets warm-started");
        assert_eq!(stats.variants_measured, 0, "no re-tuning on restart");
        assert_eq!(stats.active, actives, "cached winners adopted");
        // finish_tuning is now a no-op (queue emptied by warm start).
        router.finish_tuning().unwrap();
        assert_eq!(router.executor().stats().unwrap().variants_measured, 0);
    }
}

#[test]
fn experiments_run_all_produces_every_report() {
    let reports = experiments::run_all();
    let slugs: Vec<&str> = reports.iter().map(|(s, _)| s.as_str()).collect();
    for expected in [
        "fig1a", "fig1b", "fig1c", "fig2a", "fig2b", "fig2_summary", "fig3", "fig4", "fig5a",
        "fig5b", "fig5_real_hlo", "table1", "table2",
    ] {
        assert!(slugs.contains(&expected), "missing report {expected}");
    }
    for (slug, rep) in &reports {
        assert!(!rep.columns.is_empty(), "{slug} has no columns");
        if slug != "fig5_real_hlo" {
            assert!(!rep.rows.is_empty(), "{slug} has no rows");
        }
        // TSV render includes every row.
        let tsv = rep.to_tsv();
        assert_eq!(
            tsv.lines().filter(|l| !l.starts_with('#')).count(),
            rep.rows.len() + 1,
            "{slug} TSV row count"
        );
    }
}

#[test]
fn reports_save_to_disk() {
    let dir = TempDir::new("reports").unwrap();
    let rep = experiments::tables::table2();
    rep.save_tsv(dir.path(), "table2").unwrap();
    let text = std::fs::read_to_string(dir.join("table2.tsv")).unwrap();
    assert!(text.contains("sglang"));
}

#[test]
fn platform_fingerprints_are_distinct_and_stable() {
    let a = PlatformId::SimA100.fingerprint();
    let b = PlatformId::SimMi250.fingerprint();
    let c = PlatformId::CpuPjrt.fingerprint();
    assert_ne!(a, b);
    assert_ne!(a, c);
    assert_eq!(a, PlatformId::SimA100.fingerprint());
}

#[test]
fn vendor_library_never_serves_foreign_platform() {
    let lib = TemplateLibrary::flash_attn();
    assert!(lib.latency_us(&SimGpu::mi250(), &Workload::llama3_attention(4, 512)).is_err());
    let rocm = TemplateLibrary::rocm_flash_attn();
    assert!(rocm.latency_us(&SimGpu::a100(), &Workload::llama3_attention(4, 512)).is_err());
}
