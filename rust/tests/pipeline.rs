//! Integration tests across module boundaries: real PJRT autotuning,
//! cache persistence through the full tune path, cross-platform
//! tune/transplant pipeline, and the serving router end to end.

#[cfg(feature = "pjrt")]
use portatune::autotuner::PjrtEvaluator;
use portatune::autotuner::{SessionOutcome, SimEvaluator, TuningSession};
#[cfg(feature = "pjrt")]
use portatune::cache::TuningCache;
use portatune::config::spaces;
use portatune::experiments;
use portatune::kernels::baselines::{triton_codegen, TemplateLibrary};
use portatune::platform::{PlatformId, SimGpu};
#[cfg(feature = "pjrt")]
use portatune::runtime::{Engine, Manifest};
use portatune::serving::backend::{ExecHandle, ShapeKey, VariantDesc};
use portatune::serving::{
    router::synth_trace, BucketPolicy, ChaosBackend, DynamicBatcher, ExecBackend, FaultPlan,
    PlacementPolicy, Request, Router, Scenario, ServerConfig, SimBackend, VerbRates,
};
use portatune::util::tmp::TempDir;
use portatune::workload::Workload;
use std::time::{Duration, Instant};

#[cfg(feature = "pjrt")]
fn artifacts_present() -> bool {
    portatune::artifact_dir().join("manifest.json").exists()
}

#[cfg(feature = "pjrt")]
#[test]
fn real_pjrt_autotune_vecadd() {
    // The full empirical loop on real artifacts: enumerate -> compile ->
    // measure -> pick. Uses vector-add (cheapest kernel).
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let engine = Engine::cpu().unwrap();
    let w = manifest.workload_buckets("vector_add")[0];
    let space = spaces::aot_space_for(&w);
    let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 1, 3).unwrap();
    let out = TuningSession::new(&space, &w)
        .evaluator(&mut eval)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();
    assert!(out.best_latency_us > 0.0);
    assert_eq!(out.evaluated, space.enumerate(&w).count());
    assert!(space.contains(&out.best, &w));
}

#[cfg(feature = "pjrt")]
#[test]
fn real_pjrt_autotune_rms_with_persistent_cache() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let engine = Engine::cpu().unwrap();
    let w = manifest.workload_buckets("rms_norm")[0];
    let space = spaces::aot_space_for(&w);
    // The AOT space enumerates more configs than were lowered for this
    // bucket; missing artifacts must surface as invalid, not errors.
    let dir = TempDir::new("pipeline-cache").unwrap();
    let cache_path = dir.join("cache.json");
    let best_first;
    {
        let mut cache = TuningCache::open(&cache_path).unwrap();
        let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 1, 3).unwrap();
        let out = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert!(!out.from_cache);
        best_first = out.best.clone();
        cache.save().unwrap();
    }
    // Re-open: the déjà-vu path (paper Q4.3) must serve from disk.
    {
        let mut cache = TuningCache::open(&cache_path).unwrap();
        assert_eq!(cache.len(), 1);
        let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 1, 3).unwrap();
        let out = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert!(out.from_cache);
        assert_eq!(out.best, best_first);
        assert_eq!(out.evaluated, 0);
    }
}

#[test]
fn cross_platform_tune_then_transplant_pipeline() {
    // Sim pipeline mirroring the paper's Q2 experiment end to end.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let a100 = SimGpu::a100();
    let mi250 = SimGpu::mi250();

    let mut ea = SimEvaluator::new(a100.clone(), w, triton_codegen(a100.spec.vendor));
    let oa = TuningSession::new(&space, &w)
        .evaluator(&mut ea)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();
    let mut em = SimEvaluator::new(mi250.clone(), w, triton_codegen(mi250.spec.vendor));
    let om = TuningSession::new(&space, &w)
        .evaluator(&mut em)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();

    // Native optima differ and transplants lose (or are invalid).
    assert_ne!(oa.best, om.best);
    match mi250.attention_latency_us(&oa.best, &w, &triton_codegen(mi250.spec.vendor)) {
        Ok(us) => assert!(us >= om.best_latency_us),
        Err(_) => {} // invalid on MI250: also a paper outcome
    }
    let back = a100
        .attention_latency_us(&om.best, &w, &triton_codegen(a100.spec.vendor))
        .expect("MI250 optima are small-staging; they run on A100");
    assert!(back > oa.best_latency_us, "transplant cannot beat native tuning");
}

// ---------------------------------------------------------------------
// Serving core, default features: the backend-agnostic executor/router
// driven end to end by the SimBackend — no artifacts, no toolchain.
// ---------------------------------------------------------------------

#[test]
fn sim_serve_smoke_cold_then_tuned_is_no_slower() {
    // The acceptance contract of the backend split: a seeded trace
    // replayed cold and then tuned on the deterministic sim backend
    // completes every request, and tuning can only help (the tuned
    // variant is the per-bucket argmin over the same analytical model).
    // A huge flush deadline makes batching a pure function of the
    // request order, so both replays see identical batch shapes.
    let cfg = ServerConfig { max_wait_us: 10_000_000, idle_tuning: true, ..Default::default() };
    let router = Router::sim(SimBackend::new(portatune::platform::SimGpu::a100(), 11), &cfg).unwrap();
    let max_tokens = router.policy().seq_buckets.last().copied().unwrap();
    let trace = synth_trace(64, max_tokens, 42);

    let cold = router.serve_trace(trace.clone()).unwrap();
    assert_eq!(cold.requests, 64, "every request must complete");
    assert_eq!(cold.rejected, 0);
    assert!(cold.exec_mean_us > 0.0);

    router.finish_tuning().unwrap();
    let stats = router.executor().stats().unwrap();
    assert!(stats.variants_measured > 0, "idle tuning must have measured variants");
    assert!(!stats.active_us.is_empty(), "every tuned bucket reports its winner's latency");
    for s in &stats.swaps {
        assert!(s.gain > 1.0, "swap without improvement: {s:?}");
    }

    let tuned = router.serve_trace(trace).unwrap();
    assert_eq!(tuned.requests, 64);
    assert!(
        tuned.exec_mean_us <= cold.exec_mean_us,
        "tuned mean exec {} us must not exceed cold {} us",
        tuned.exec_mean_us,
        cold.exec_mean_us
    );
}

#[test]
fn sim_serving_winners_survive_restart_via_cache() {
    // Q4.3 x Q4.4 on the default build: tune once, persist, restart
    // the server -> warm start with zero re-tuning.
    let dir = TempDir::new("sim-serve-cache").unwrap();
    let cfg = ServerConfig {
        max_wait_us: 500,
        idle_tuning: true,
        cache_path: Some(dir.join("serving_cache.json")),
        ..Default::default()
    };
    let backend = || SimBackend::new(portatune::platform::SimGpu::mi250(), 3);
    let (actives, measured);
    {
        let router = Router::sim(backend(), &cfg).unwrap();
        router.finish_tuning().unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, 0, "first boot is cold");
        measured = stats.variants_measured;
        assert!(measured > 0);
        actives = stats.active.clone();
    }
    {
        let router = Router::sim(backend(), &cfg).unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, actives.len(), "all buckets warm-started");
        assert_eq!(stats.variants_measured, 0, "no re-tuning on restart");
        assert_eq!(stats.active, actives, "cached winners adopted");
        // finish_tuning is now a no-op (queue emptied by warm start).
        router.finish_tuning().unwrap();
        assert_eq!(router.executor().stats().unwrap().variants_measured, 0);
    }
}

#[test]
fn sim_serve_platforms_have_disjoint_cache_namespaces() {
    // An a100 server and an mi250 server sharing one cache file must
    // never adopt each other's winners (the platform fingerprint is
    // part of the key).
    let dir = TempDir::new("sim-serve-cross").unwrap();
    let cfg = ServerConfig {
        max_wait_us: 500,
        idle_tuning: true,
        cache_path: Some(dir.join("shared_cache.json")),
        ..Default::default()
    };
    {
        let router = Router::sim(SimBackend::new(portatune::platform::SimGpu::a100(), 5), &cfg).unwrap();
        router.finish_tuning().unwrap();
        assert!(router.executor().stats().unwrap().variants_measured > 0);
    }
    {
        // Different platform, same cache file: must boot cold.
        let router = Router::sim(SimBackend::new(portatune::platform::SimGpu::mi250(), 5), &cfg).unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, 0, "mi250 must not adopt a100 winners");
        router.finish_tuning().unwrap();
        assert!(router.executor().stats().unwrap().variants_measured > 0);
    }
}

#[test]
fn bucket_policy_edge_cases() {
    // Empty grid: nothing fits, nothing panics.
    let empty = BucketPolicy::new(vec![], 1_000);
    assert!(empty.seq_buckets.is_empty());
    assert_eq!(empty.bucket_for(1), None);
    assert_eq!(empty.bucket_for(usize::MAX), None);
    let mut b = DynamicBatcher::new(empty);
    let now = Instant::now();
    assert!(b.push(Request { id: 1, tokens: 8 }, now).is_none());
    assert_eq!(b.rejected.len(), 1);
    assert!(b.next_batch(now, true).is_none());

    // Exact fit routes to the boundary bucket; one past it spills to
    // the next; past the largest is rejected.
    let p = BucketPolicy::new(vec![(128, 2), (256, 4)], 1_000);
    assert_eq!(p.bucket_for(128), Some(0), "exact fit stays in the small bucket");
    assert_eq!(p.bucket_for(129), Some(1));
    assert_eq!(p.bucket_for(256), Some(1), "exact fit in the largest bucket");
    assert_eq!(p.bucket_for(257), None, "oversize requests have no bucket");
    assert_eq!(p.max_batch(0), 2);
    assert_eq!(p.max_batch(1), 4);
    assert_eq!(p.batch_shape_for(1, 3), 4, "partial batches pad up to a compiled size");
    assert_eq!(p.batch_shape_for(1, 5), 4, "over-full requests clamp to the largest batch");
}

#[test]
fn batcher_bucket_overflow_splits_into_full_batches() {
    // 10 requests into a bucket compiled for at most 4: two full
    // batches flush immediately, the remainder waits for the deadline.
    let p = BucketPolicy::new(vec![(128, 4)], 10_000);
    let mut b = DynamicBatcher::new(p);
    let t0 = Instant::now();
    for i in 0..10 {
        b.push(Request { id: i, tokens: 100 }, t0);
    }
    let first = b.next_batch(t0, false).expect("full batch ready");
    assert_eq!(first.requests.len(), 4);
    assert_eq!(first.batch_shape, 4);
    let second = b.next_batch(t0, false).expect("second full batch ready");
    assert_eq!(second.requests.len(), 4);
    assert!(b.next_batch(t0, false).is_none(), "2 leftovers must wait for the deadline");
    assert_eq!(b.pending(), 2);
    // Deadline flush: once the oldest leftover has waited max_wait_us,
    // the partial batch goes out padded to a compiled shape.
    let later = t0 + Duration::from_micros(10_001);
    let tail = b.next_batch(later, false).expect("deadline flush");
    assert_eq!(tail.requests.len(), 2);
    assert_eq!(tail.batch_shape, 4, "partial flush pads up to a compiled size");
    assert_eq!(b.pending(), 0);
    // FIFO preserved across the splits.
    let ids: Vec<u64> = first.requests.iter().chain(&second.requests).chain(&tail.requests).map(|r| r.id).collect();
    assert_eq!(ids, (0..10).collect::<Vec<u64>>());
}

#[cfg(feature = "pjrt")]
#[test]
fn serving_router_end_to_end_smoke() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let router = Router::pjrt(
        manifest,
        &ServerConfig { max_wait_us: 500, idle_tuning: false, ..Default::default() },
    )
    .unwrap();
    let trace = synth_trace(6, router.policy().seq_buckets.last().copied().unwrap(), 9);
    let report = router.serve_trace(trace).unwrap();
    assert_eq!(report.requests, 6);
    assert_eq!(report.rejected, 0);
    assert!(report.throughput_rps > 0.0);
    assert!(report.latency_p50_us > 0.0);
    assert!(report.latency_p99_us >= report.latency_p50_us);
}

#[cfg(feature = "pjrt")]
#[test]
fn serving_background_tuning_improves_or_keeps_active_variants() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load_default().unwrap();
    let router = Router::pjrt(
        manifest,
        &ServerConfig { max_wait_us: 500, idle_tuning: true, ..Default::default() },
    )
    .unwrap();
    router.finish_tuning().unwrap();
    let stats = router.executor().stats().unwrap();
    assert!(stats.variants_measured >= stats.active.len());
    // Every swap must claim a strict improvement.
    for s in &stats.swaps {
        assert!(s.gain > 1.0, "swap {:?} without improvement", s.shape);
    }
    // After tuning, the active variant of each shape is its measured argmin.
    assert!(!stats.active_us.is_empty());
}

#[cfg(feature = "pjrt")]
#[test]
fn serving_winners_survive_restart_via_cache() {
    // Q4.3 x Q4.4: tune once, persist, restart the server -> warm start
    // with zero re-tuning.
    if !artifacts_present() {
        return;
    }
    let dir = TempDir::new("serve-cache").unwrap();
    let cache_path = dir.join("serving_cache.json");
    let cfg = ServerConfig {
        max_wait_us: 500,
        idle_tuning: true,
        cache_path: Some(cache_path.clone()),
        ..Default::default()
    };
    let (actives, measured);
    {
        let router = Router::pjrt(Manifest::load_default().unwrap(), &cfg).unwrap();
        router.finish_tuning().unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, 0, "first boot is cold");
        measured = stats.variants_measured;
        assert!(measured > 0);
        actives = stats.active.clone();
    }
    assert!(cache_path.exists(), "winners persisted");
    {
        let router = Router::pjrt(Manifest::load_default().unwrap(), &cfg).unwrap();
        let stats = router.executor().stats().unwrap();
        assert_eq!(stats.warm_started, actives.len(), "all buckets warm-started");
        assert_eq!(stats.variants_measured, 0, "no re-tuning on restart");
        assert_eq!(stats.active, actives, "cached winners adopted");
        // finish_tuning is now a no-op (queue emptied by warm start).
        router.finish_tuning().unwrap();
        assert_eq!(router.executor().stats().unwrap().variants_measured, 0);
    }
}

// ---------------------------------------------------------------------
// Chaos: deterministic fault injection through the full serving stack.
// Same seed => same faults => bit-identical reports; the executor's
// retry / circuit-breaker / fallback machinery absorbs the rest.
// ---------------------------------------------------------------------

/// A huge flush deadline + no idle tuning makes the backend call
/// sequence a pure function of the trace, so fault fates line up
/// across runs.
fn chaos_cfg() -> ServerConfig {
    ServerConfig { max_wait_us: 10_000_000, idle_tuning: false, ..Default::default() }
}

#[test]
fn chaos_serve_is_bit_reproducible_per_seed() {
    let run = || {
        let router = Router::with_backend(
            move || {
                Ok(ChaosBackend::new(SimBackend::new(SimGpu::a100(), 11), FaultPlan::uniform(7, 0.1)))
            },
            &chaos_cfg(),
        )
        .unwrap();
        let max_tokens = router.policy().seq_buckets.last().copied().unwrap();
        let trace = synth_trace(48, max_tokens, 42);
        let cold = router.serve_trace(trace.clone()).unwrap();
        router.finish_tuning().unwrap();
        let tuned = router.serve_trace(trace).unwrap();
        (cold.replay_digest(), tuned.replay_digest(), tuned.faults.injected)
    };
    let (cold1, tuned1, injected1) = run();
    let (cold2, tuned2, injected2) = run();
    assert!(injected1 > 0, "rate 0.1 over a 48-request serve + tuning must inject faults");
    assert_eq!(cold1, cold2, "cold replay digest must be bit-identical across runs");
    assert_eq!(tuned1, tuned2, "tuned replay digest must be bit-identical across runs");
    assert_eq!(injected1, injected2);
}

#[test]
fn chaos_transient_faults_converge_to_the_fault_free_winner() {
    // Measure-only transients: retries re-draw the fate per attempt, so
    // tuning eventually records the exact fault-free latencies and the
    // per-bucket argmin lands on the same winners, bit for bit.
    let cfg = chaos_cfg();
    let plan = FaultPlan {
        seed: 3,
        transient: VerbRates { measure: 0.3, ..VerbRates::default() },
        ..FaultPlan::default()
    };
    let chaos = Router::with_backend(
        move || Ok(ChaosBackend::new(SimBackend::new(SimGpu::mi250(), 9), plan)),
        &cfg,
    )
    .unwrap();
    let clean = Router::sim(SimBackend::new(SimGpu::mi250(), 9), &cfg).unwrap();
    chaos.finish_tuning().unwrap();
    clean.finish_tuning().unwrap();
    let cs = chaos.executor().stats().unwrap();
    let ks = clean.executor().stats().unwrap();
    assert!(cs.faults.injected > 0, "rate 0.3 across tuning measurements must inject");
    assert_eq!(cs.active, ks.active, "chaos tuning must land on the fault-free winners");
    assert_eq!(cs.active_us.len(), ks.active_us.len());
    for (bucket, want) in &ks.active_us {
        let got = cs.active_us.get(bucket).expect("bucket missing under chaos");
        assert_eq!(got.to_bits(), want.to_bits(), "winner latency differs in bucket {bucket}");
    }
    // The tuned replay is equally untouched: faults only hit `measure`.
    let max_tokens = clean.policy().seq_buckets.last().copied().unwrap();
    let trace = synth_trace(32, max_tokens, 5);
    let a = chaos.serve_trace(trace.clone()).unwrap();
    let b = clean.serve_trace(trace).unwrap();
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.exec_mean_us.to_bits(), b.exec_mean_us.to_bits());
}

#[test]
fn quarantine_reprobe_lifecycle_writes_off_persistently_failing_variants() {
    // Measure always faults: every variant climbs the full breaker
    // ladder (K consecutive failures -> quarantine -> cooldown ->
    // re-probe -> written off) while the serving path stays healthy.
    let plan = FaultPlan {
        seed: 5,
        transient: VerbRates { measure: 1.0, ..VerbRates::default() },
        ..FaultPlan::default()
    };
    let router = Router::with_backend(
        move || {
            Ok(ChaosBackend::new(
                SimBackend::new(SimGpu::a100(), 5)
                    .with_shapes(&[(1, 128)])
                    .with_variants_per_bucket(3),
                plan,
            ))
        },
        &chaos_cfg(),
    )
    .unwrap();
    router.finish_tuning().unwrap();
    let stats = router.executor().stats().unwrap();
    assert_eq!(stats.variants_measured, 0, "measure always faults: nothing can be measured");
    assert_eq!(stats.faults.quarantined, 3, "each variant trips its breaker once");
    assert_eq!(stats.faults.reprobed, 3, "each quarantined variant gets one re-probe");
    assert_eq!(stats.faults.gave_up, 3, "failed re-probes write the variants off");
    assert!(stats.swaps.is_empty(), "no measurements, no swaps");
    // Execution is untouched (only measure faults): requests still serve.
    let trace = synth_trace(8, 128, 1);
    let report = router.serve_trace(trace).unwrap();
    assert_eq!(report.requests, 8);
    assert_eq!(report.shed, 0);
}

#[test]
fn quarantined_variant_recovers_after_brownout_heals() {
    // An injection budget models a brown-out: 3 hard-fail rounds of 4
    // attempts exhaust the 12 injections while the variant sits
    // quarantined; the post-cooldown re-probe then hits a healed
    // backend and the variant returns to service.
    let plan = FaultPlan {
        seed: 5,
        transient: VerbRates { measure: 1.0, ..VerbRates::default() },
        max_injected: Some(12),
        ..FaultPlan::default()
    };
    let router = Router::with_backend(
        move || {
            Ok(ChaosBackend::new(
                SimBackend::new(SimGpu::a100(), 5)
                    .with_shapes(&[(1, 128)])
                    .with_variants_per_bucket(1),
                plan,
            ))
        },
        &chaos_cfg(),
    )
    .unwrap();
    router.finish_tuning().unwrap();
    let stats = router.executor().stats().unwrap();
    assert_eq!(stats.faults.injected, 12, "the injection budget is exhausted exactly");
    assert_eq!(stats.faults.failures, 12);
    assert_eq!(stats.faults.retries, 9, "three retries per hard-fail round");
    assert_eq!(stats.faults.quarantined, 1);
    assert_eq!(stats.faults.reprobed, 1, "the post-cooldown re-probe hits the healed backend");
    assert_eq!(stats.faults.gave_up, 0, "the healed variant is not written off");
    assert_eq!(stats.variants_measured, 1, "the healed variant is finally measured");
}

#[test]
fn chaos_serve_completes_and_tuned_still_improves_on_cold() {
    // The PR's acceptance contract: a chaos serve at rate 0.1 panics
    // nowhere, accounts for every request (served or shed with a typed
    // error), reports its fault counters, and background tuning still
    // helps.
    let cfg = ServerConfig { max_wait_us: 10_000_000, idle_tuning: true, ..Default::default() };
    let router = Router::with_backend(
        move || {
            Ok(ChaosBackend::new(SimBackend::new(SimGpu::a100(), 11), FaultPlan::uniform(7, 0.1)))
        },
        &cfg,
    )
    .unwrap();
    let max_tokens = router.policy().seq_buckets.last().copied().unwrap();
    let trace = synth_trace(64, max_tokens, 42);

    let cold = router.serve_trace(trace.clone()).unwrap();
    assert_eq!(cold.requests + cold.shed, 64, "every request is served or shed, never lost");
    assert_eq!(cold.rejected, 0);

    router.finish_tuning().unwrap();
    let tuned = router.serve_trace(trace).unwrap();
    assert_eq!(tuned.requests + tuned.shed, 64);
    assert!(tuned.faults.injected > 0, "rate 0.1 must inject faults somewhere");
    assert!(
        tuned.exec_mean_us <= cold.exec_mean_us,
        "tuned mean exec {} us must not exceed cold {} us even under chaos",
        tuned.exec_mean_us,
        cold.exec_mean_us
    );
}

#[test]
fn experiments_run_all_produces_every_report() {
    let reports = experiments::run_all();
    let slugs: Vec<&str> = reports.iter().map(|(s, _)| s.as_str()).collect();
    for expected in [
        "fig1a", "fig1b", "fig1c", "fig2a", "fig2b", "fig2_summary", "fig3", "fig4", "fig5a",
        "fig5b", "fig5_real_hlo", "table1", "table2",
    ] {
        assert!(slugs.contains(&expected), "missing report {expected}");
    }
    for (slug, rep) in &reports {
        assert!(!rep.columns.is_empty(), "{slug} has no columns");
        if slug != "fig5_real_hlo" {
            assert!(!rep.rows.is_empty(), "{slug} has no rows");
        }
        // TSV render includes every row.
        let tsv = rep.to_tsv();
        assert_eq!(
            tsv.lines().filter(|l| !l.starts_with('#')).count(),
            rep.rows.len() + 1,
            "{slug} TSV row count"
        );
    }
}

#[test]
fn reports_save_to_disk() {
    let dir = TempDir::new("reports").unwrap();
    let rep = experiments::tables::table2();
    rep.save_tsv(dir.path(), "table2").unwrap();
    let text = std::fs::read_to_string(dir.join("table2.tsv")).unwrap();
    assert!(text.contains("sglang"));
}

#[test]
fn platform_fingerprints_are_distinct_and_stable() {
    let a = PlatformId::SimA100.fingerprint();
    let b = PlatformId::SimMi250.fingerprint();
    let c = PlatformId::CpuPjrt.fingerprint();
    assert_ne!(a, b);
    assert_ne!(a, c);
    assert_eq!(a, PlatformId::SimA100.fingerprint());
}

#[test]
fn vendor_library_never_serves_foreign_platform() {
    let lib = TemplateLibrary::flash_attn();
    assert!(lib.latency_us(&SimGpu::mi250(), &Workload::llama3_attention(4, 512)).is_err());
    let rocm = TemplateLibrary::rocm_flash_attn();
    assert!(rocm.latency_us(&SimGpu::a100(), &Workload::llama3_attention(4, 512)).is_err());
}

// ---------------------------------------------------------------------------
// Sharded serving: scaling, saturation, replay determinism, and chaos
// isolation across executor shards.
// ---------------------------------------------------------------------------

#[test]
fn sharded_throughput_scales_with_shard_count_on_bursty_scenario() {
    // ISSUE acceptance: on the deterministic virtual clock, 4 tuned
    // shards must serve the bursty scenario at >= 2x the 1-shard
    // modeled throughput.  The single shared batcher forms the
    // identical batch sequence for both runs (batch composition is
    // shard-count-independent), so the comparison is apples-to-apples.
    let run = |shards: usize| {
        let cfg = ServerConfig::default();
        let router = Router::with_shards(
            move |_| Ok(SimBackend::new(SimGpu::a100(), 11)),
            shards,
            PlacementPolicy::LeastLoaded,
            &cfg,
        )
        .unwrap();
        // Tune first so both runs serve the same per-bucket winners and
        // no compile time lands on the request path.
        router.finish_tuning().unwrap();
        let max_tokens = *router.policy().seq_buckets.last().unwrap();
        let trace = Scenario::by_name("burst").unwrap().generate(480, max_tokens, 7);
        let rep = router.serve_trace_timed(&trace).unwrap();
        assert_eq!(rep.requests + rep.shed + rep.rejected + rep.lost, 480, "{shards}-shard accounting");
        assert_eq!(rep.lost, 0, "{shards}-shard run must lose nothing");
        assert_eq!(rep.shards, shards);
        assert!(rep.sim_makespan_us > 0.0, "sim backend must model a makespan");
        rep
    };
    let r1 = run(1);
    let r4 = run(4);
    assert_eq!(r1.requests, r4.requests, "shard count must not change what completes");
    assert_eq!(r1.batches, r4.batches, "shared batcher must form the same batches");
    assert!(
        r4.sim_throughput_rps >= 2.0 * r1.sim_throughput_rps,
        "4 shards at {:.1} req/s must be >= 2x 1 shard at {:.1} req/s",
        r4.sim_throughput_rps,
        r1.sim_throughput_rps
    );
    // The balancer actually spread the work: no single shard carried
    // more than half the modeled busy time.
    let busy: Vec<f64> = r4.shard_util.iter().map(|u| u.busy_us).collect();
    let total: f64 = busy.iter().sum();
    let max_busy = busy.iter().cloned().fold(0.0, f64::max);
    assert!(
        max_busy <= 0.5 * total,
        "least-loaded left one shard with {max_busy} of {total} us busy"
    );
}

#[test]
fn sharded_replays_are_bit_reproducible_across_shards_scenarios_and_placements() {
    // Same seed, same scenario => bit-identical replay digest, for
    // every (shard count, scenario, placement policy) combination.
    const SHAPES: &[(usize, usize)] = &[(1, 128), (4, 128), (2, 256), (8, 256), (4, 512)];
    for scenario in Scenario::catalog() {
        for shards in [1usize, 2, 4] {
            for placement in [PlacementPolicy::BucketAffinity, PlacementPolicy::LeastLoaded] {
                let digest = || {
                    let cfg = ServerConfig { idle_tuning: false, ..Default::default() };
                    let router = Router::with_shards(
                        move |_| {
                            Ok(SimBackend::new(SimGpu::mi250(), 3)
                                .with_shapes(SHAPES)
                                .with_variants_per_bucket(2))
                        },
                        shards,
                        placement,
                        &cfg,
                    )
                    .unwrap();
                    let max_tokens = *router.policy().seq_buckets.last().unwrap();
                    let trace = scenario.generate(90, max_tokens, 13);
                    router.serve_trace_timed(&trace).unwrap().replay_digest()
                };
                assert_eq!(
                    digest(),
                    digest(),
                    "digest must be bit-identical: scenario={} shards={} placement={}",
                    scenario.name,
                    shards,
                    placement.name()
                );
            }
        }
    }
}

#[test]
fn sharded_serve_sheds_not_panics_past_saturation() {
    // A 2000 rps burst into a max_pending=8 admission bound: the router
    // must shed (typed and counted) instead of panicking or queueing
    // without bound, and the request accounting must still balance.
    let cfg = ServerConfig { max_pending: 8, idle_tuning: false, ..Default::default() };
    let router = Router::with_shards(
        move |_| Ok(SimBackend::new(SimGpu::a100(), 11)),
        2,
        PlacementPolicy::LeastLoaded,
        &cfg,
    )
    .unwrap();
    let max_tokens = *router.policy().seq_buckets.last().unwrap();
    let trace = Scenario::by_name("burst").unwrap().generate(300, max_tokens, 7);
    let rep = router.serve_trace_timed(&trace).unwrap();
    assert!(rep.shed > 0, "a 2000 rps burst into max_pending=8 must shed");
    assert!(rep.requests > 0, "shedding must not starve admitted requests");
    assert_eq!(rep.lost, 0, "saturation sheds; it never loses requests");
    assert_eq!(rep.requests + rep.shed + rep.rejected + rep.lost, 300);
    // Admission sheds surface through the same fault counters the CLI
    // prints, so saturation is observable, not silent.
    assert_eq!(rep.faults.shed, rep.shed);
}

#[test]
fn quarantined_variant_on_one_shard_does_not_poison_siblings() {
    // Shard 0's measure path always faults: its 3 variants climb the
    // full breaker ladder (quarantine -> re-probe -> written off) and
    // it measures nothing.  Its siblings run a disabled fault plan and
    // must tune to exactly the winners a clean single-shard router
    // finds — shard-local chaos stays shard-local.
    let cfg = ServerConfig { max_wait_us: 10_000_000, idle_tuning: true, ..Default::default() };
    let hostile = FaultPlan {
        seed: 5,
        transient: VerbRates { measure: 1.0, ..VerbRates::default() },
        ..FaultPlan::default()
    };
    let sim = || SimBackend::new(SimGpu::a100(), 5).with_shapes(&[(1, 128)]).with_variants_per_bucket(3);
    let router = Router::with_shards(
        move |i| {
            let plan = if i == 0 { hostile.clone() } else { FaultPlan::disabled() };
            Ok(ChaosBackend::new(
                SimBackend::new(SimGpu::a100(), 5).with_shapes(&[(1, 128)]).with_variants_per_bucket(3),
                plan,
            ))
        },
        3,
        PlacementPolicy::LeastLoaded,
        &cfg,
    )
    .unwrap();
    router.finish_tuning().unwrap();
    let stats = router.shard_set().stats();
    assert_eq!(stats.len(), 3);
    // Shard 0: every variant breaker-laddered to written-off.
    assert!(stats[0].faults.injected > 0, "the hostile plan must actually fire");
    assert_eq!(stats[0].faults.gave_up, 3, "shard 0 writes all 3 variants off");
    assert_eq!(stats[0].variants_measured, 0, "shard 0 measures nothing");
    // Clean reference: what a fault-free router tunes to.
    let clean = Router::sim(sim(), &cfg).unwrap();
    clean.finish_tuning().unwrap();
    let want = clean.executor().stats().unwrap();
    assert!(want.variants_measured > 0);
    for (i, s) in stats.iter().enumerate().skip(1) {
        assert_eq!(s.faults.injected, 0, "shard {i} must see no injected faults");
        assert_eq!(s.faults.gave_up, 0, "shard {i} must quarantine nothing");
        assert_eq!(s.variants_measured, want.variants_measured, "shard {i} tunes fully");
        assert_eq!(s.active, want.active, "shard {i} must land on the clean winners");
        for (bucket, us) in &want.active_us {
            assert_eq!(
                s.active_us.get(bucket).map(|x| x.to_bits()),
                Some(us.to_bits()),
                "shard {i} bucket {bucket} winner latency must match the clean run bitwise"
            );
        }
    }
    // The fleet still serves: measure-path chaos never touches execute.
    let reqs: Vec<Request> = (0..9).map(|id| Request { id, tokens: 16 + id as usize }).collect();
    let rep = router.serve_trace(reqs).unwrap();
    assert_eq!(rep.requests, 9);
    assert_eq!(rep.shed + rep.lost, 0);
}

#[test]
fn whole_shard_brownout_degrades_throughput_without_losing_the_winner() {
    // Shard 0's execute path hard-fails under an injection budget of 8
    // — exactly one batch's retry ladder (4 active-variant attempts,
    // then 4 fallback attempts).  That batch is shed, the brown-out
    // heals, and the fault-free tuned winners survive on every shard
    // because execute-path failures never demote without a successful
    // fallback and never touch the tuning path at all.
    let cfg = ServerConfig { max_wait_us: 10_000_000, idle_tuning: true, ..Default::default() };
    let brownout = FaultPlan {
        seed: 9,
        transient: VerbRates { execute: 1.0, ..VerbRates::default() },
        max_injected: Some(8),
        ..FaultPlan::default()
    };
    let router = Router::with_shards(
        move |i| {
            let plan = if i == 0 { brownout.clone() } else { FaultPlan::disabled() };
            Ok(ChaosBackend::new(SimBackend::new(SimGpu::a100(), 11), plan))
        },
        2,
        PlacementPolicy::LeastLoaded,
        &cfg,
    )
    .unwrap();
    // Tuning completes everywhere: the brown-out only covers execute.
    router.finish_tuning().unwrap();
    let max_tokens = *router.policy().seq_buckets.last().unwrap();
    let rep = router.serve_trace(synth_trace(32, max_tokens, 3)).unwrap();
    // The first batch lands on shard 0 (least-loaded ties break to the
    // lowest index) and burns the whole budget on its retry ladder.
    assert_eq!(rep.faults.injected, 8, "4 active + 4 fallback attempts consume the budget");
    assert!(rep.shed > 0, "the browned-out batch is shed, not lost");
    assert_eq!(rep.lost, 0);
    assert_eq!(rep.requests + rep.shed, 32);
    assert!(rep.requests > 0, "the fleet keeps serving through the brown-out");
    assert_eq!(
        rep.shard_stats[0].faults.shed,
        rep.shed,
        "every shed request is shard 0's"
    );
    // The winners survived: a clean single-shard reference tunes to the
    // same active variants the browned-out fleet still holds.
    let clean = Router::sim(SimBackend::new(SimGpu::a100(), 11), &cfg).unwrap();
    clean.finish_tuning().unwrap();
    let want = clean.executor().stats().unwrap();
    for (i, s) in rep.shard_stats.iter().enumerate() {
        assert_eq!(s.active, want.active, "shard {i} must keep the fault-free winners");
    }
}

/// A backend whose executor thread dies (panics) on the Nth execute —
/// the "shard process dies mid-batch" failure sharding must survive.
struct DyingBackend {
    inner: SimBackend,
    executes_left: usize,
}

impl ExecBackend for DyingBackend {
    fn platform(&self) -> String {
        self.inner.platform()
    }
    fn discover(&mut self) -> portatune::Result<Vec<(ShapeKey, Vec<VariantDesc>)>> {
        self.inner.discover()
    }
    fn bucket_workload(&self, shape: ShapeKey) -> Workload {
        self.inner.bucket_workload(shape)
    }
    fn compile(&mut self, shape: ShapeKey, variant: &VariantDesc) -> portatune::Result<ExecHandle> {
        self.inner.compile(shape, variant)
    }
    fn execute(&mut self, handle: ExecHandle, shape: ShapeKey) -> portatune::Result<f64> {
        if self.executes_left == 0 {
            panic!("injected shard death");
        }
        self.executes_left -= 1;
        self.inner.execute(handle, shape)
    }
    fn measure(
        &mut self,
        handle: ExecHandle,
        shape: ShapeKey,
        warmup: usize,
        iters: usize,
    ) -> portatune::Result<f64> {
        self.inner.measure(handle, shape, warmup, iters)
    }
    fn backoff(&mut self, us: f64) {
        self.inner.backoff(us)
    }
    fn virtual_clock_us(&self) -> f64 {
        self.inner.virtual_clock_us()
    }
}

#[test]
fn dying_shard_loses_only_its_in_flight_batches_never_the_replay() {
    // Shard 0's thread panics on its first execute.  The router must
    // finish the replay on the surviving shard, count (not drop) the
    // dead shard's in-flight requests as lost, and keep the accounting
    // identity intact.
    let cfg = ServerConfig { max_wait_us: 10_000_000, idle_tuning: false, ..Default::default() };
    let router = Router::with_shards(
        move |i| {
            Ok(DyingBackend {
                inner: SimBackend::new(SimGpu::a100(), 7),
                executes_left: if i == 0 { 0 } else { usize::MAX },
            })
        },
        2,
        PlacementPolicy::LeastLoaded,
        &cfg,
    )
    .unwrap();
    let max_tokens = *router.policy().seq_buckets.last().unwrap();
    let n = 24;
    let rep = router.serve_trace(synth_trace(n, max_tokens, 3)).unwrap();
    assert!(rep.lost > 0, "shard 0 dies on its first execute; its batch is lost");
    assert!(rep.requests > 0, "shard 1 must keep serving after its sibling dies");
    assert_eq!(rep.requests + rep.shed + rep.rejected + rep.lost, n);
    assert_eq!(rep.shards, 2);
    assert!(rep.shard_util[1].requests > 0, "the survivor did real work");
}
