//! Cross-language numerical contract: HLO artifacts produced by the
//! Python AOT path must reproduce the Python-computed golden outputs
//! when executed from Rust via PJRT.
//!
//! This is THE correctness link between Layer 1/2 (JAX/Pallas) and
//! Layer 3 (Rust): if it holds, the autotuner is choosing among
//! *numerically identical* kernels, exactly as the paper requires.

#![cfg(feature = "pjrt")]

use portatune::json;
use portatune::runtime::{allclose, Engine, Manifest, TensorF32};

struct Golden {
    artifact: String,
    inputs: Vec<TensorF32>,
    expected: Vec<f32>,
    atol: f32,
    rtol: f32,
}

fn load_golden(name: &str) -> Option<Golden> {
    let dir = portatune::artifact_dir();
    let path = dir.join("golden").join(name);
    let text = std::fs::read_to_string(path).ok()?;
    let v = json::parse(&text).ok()?;
    let tensor = |t: &json::Value| -> Option<TensorF32> {
        let shape: Vec<usize> = t.req_arr("shape").ok()?.iter().map(|d| d.as_usize().unwrap()).collect();
        let data: Vec<f32> = t
            .req_arr("data")
            .ok()?
            .iter()
            .map(|x| x.as_f64().unwrap() as f32)
            .collect();
        Some(TensorF32::new(data, &shape))
    };
    Some(Golden {
        artifact: v.req_str("artifact").ok()?.to_string(),
        inputs: v.req_arr("inputs").ok()?.iter().map(|t| tensor(t).unwrap()).collect(),
        expected: tensor(v.req("expected").ok()?)?.data,
        atol: v.req_f64("atol").ok()? as f32,
        rtol: v.req_f64("rtol").ok()? as f32,
    })
}

fn check_golden(name: &str) {
    let dir = portatune::artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping {name}: run `make artifacts` first");
        return;
    }
    let g = load_golden(name).unwrap_or_else(|| panic!("golden file {name} unreadable"));
    let engine = Engine::cpu().expect("pjrt cpu client");
    let exe = engine.load_hlo_text(dir.join(&g.artifact)).expect("compile artifact");
    let out = exe.run_f32(&g.inputs).expect("execute artifact");
    assert_eq!(out.len(), g.expected.len(), "output arity");
    assert!(
        allclose(&out, &g.expected, g.atol, g.rtol),
        "{name}: rust PJRT output diverges from python golden (max diff {})",
        out.iter()
            .zip(&g.expected)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    );
}

#[test]
fn attention_matches_python_golden() {
    check_golden("attn_tiny.json");
}

#[test]
fn rms_norm_matches_python_golden() {
    check_golden("rms_tiny.json");
}

#[test]
fn vector_add_matches_python_golden() {
    check_golden("vecadd_tiny.json");
}

#[test]
fn buffer_path_matches_literal_path() {
    // run_f32 (literal args) and run_buffers (device-resident args) must
    // agree bit-for-bit — the serving fast path cannot change numerics.
    let dir = portatune::artifact_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let Some(g) = load_golden("vecadd_tiny.json") else { return };
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_hlo_text(dir.join(&g.artifact)).unwrap();
    let via_literals = exe.run_f32(&g.inputs).unwrap();
    let bufs: Vec<xla::PjRtBuffer> = g.inputs.iter().map(|t| engine.upload(t).unwrap()).collect();
    let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let via_buffers = exe.run_buffers(&refs).unwrap();
    assert_eq!(via_literals, via_buffers);
}

#[test]
fn every_attention_config_artifact_matches_native() {
    // Config invariance at the artifact level: for the smallest bucket,
    // every Pallas configuration must agree with the native-baseline
    // artifact on the same inputs (the real-system analogue of the
    // python `test_block_config_invariance`).
    let dir = portatune::artifact_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let bucket = manifest
        .workload_buckets("attention")
        .into_iter()
        .min_by_key(|w| match w {
            portatune::workload::Workload::Attention { batch, seq_len, .. } => batch * seq_len,
            _ => usize::MAX,
        })
        .expect("attention buckets exist");
    let native = manifest.native_for(&bucket).expect("native artifact");
    let native_exe = engine.load_artifact(&manifest.root, native).unwrap();
    let inputs: Vec<TensorF32> = native
        .inputs
        .iter()
        .enumerate()
        .map(|(i, s)| TensorF32::random(&s.shape, 7 + i as u64))
        .collect();
    let reference = native_exe.run_f32(&inputs).unwrap();

    let mut checked = 0;
    for a in manifest.candidates_for(&bucket).iter().take(6) {
        let exe = engine.load_artifact(&manifest.root, a).unwrap();
        let out = exe.run_f32(&inputs).unwrap();
        assert!(
            allclose(&out, &reference, 3e-3, 3e-3),
            "config {} diverges from native",
            a.config()
        );
        checked += 1;
    }
    assert!(checked > 0);
}
