//! Property-based tests (seeded randomized invariants; proptest is not
//! available in the offline build, so generation runs on the in-tree
//! deterministic RNG — failures always reproduce).

use std::collections::HashSet;
use std::time::Instant;

use portatune::cache::{entry_now, TuningCache};
use portatune::config::{spaces, Config, ConfigSpace};
use portatune::json::{self, Value};
use portatune::kernels::baselines::{triton_codegen, HAND_TUNED};
use portatune::platform::SimGpu;
use portatune::serving::batcher::{BucketPolicy, DynamicBatcher};
use portatune::serving::{Request, Scenario};
use portatune::surrogate::{features, ridge_fit, CostModel, RIDGE_LAMBDA};
use portatune::util::rng::Rng;
use portatune::workload::{DType, SeqLenMix, Workload};

const CASES: usize = 60;

fn random_attention_workload(rng: &mut Rng) -> Workload {
    let batches = [1usize, 2, 4, 8, 16, 32, 64, 128];
    let seqs = [128usize, 256, 512, 1024, 2048, 4096, 8192];
    Workload::Attention {
        batch: *rng.choose(&batches).unwrap(),
        q_heads: 32,
        kv_heads: *rng.choose(&[8usize, 32]).unwrap(),
        seq_len: *rng.choose(&seqs).unwrap(),
        head_dim: *rng.choose(&[64usize, 128]).unwrap(),
        dtype: if rng.f64() < 0.5 { DType::F16 } else { DType::BF16 },
        causal: rng.f64() < 0.8,
    }
}

// ---------------------------------------------------------------------
// Configuration-space invariants
// ---------------------------------------------------------------------

#[test]
fn prop_enumerated_configs_always_satisfy_contains() {
    let mut rng = Rng::seed_from(11);
    for _ in 0..CASES {
        let w = random_attention_workload(&mut rng);
        let space = spaces::attention_sim_space();
        for cfg in space.enumerate(&w) {
            assert!(space.contains(&cfg, &w), "{cfg} for {}", w.key());
        }
    }
}

#[test]
fn prop_samples_are_members_and_deterministic() {
    let mut rng = Rng::seed_from(12);
    for case in 0..CASES {
        let w = random_attention_workload(&mut rng);
        let space = spaces::attention_sim_space();
        let mut r1 = Rng::seed_from(case as u64);
        let mut r2 = Rng::seed_from(case as u64);
        let a = space.sample(&w, &mut r1, 100);
        let b = space.sample(&w, &mut r2, 100);
        assert_eq!(a, b, "sampling must be deterministic per seed");
        if let Some(cfg) = a {
            assert!(space.contains(&cfg, &w));
        }
    }
}

#[test]
fn prop_neighbors_are_valid_and_one_step() {
    let mut rng = Rng::seed_from(13);
    for _ in 0..CASES {
        let w = random_attention_workload(&mut rng);
        let space = spaces::attention_sim_space();
        let Some(cfg) = space.sample(&w, &mut rng, 100) else { continue };
        for n in space.neighbors(&cfg, &w) {
            assert!(space.contains(&n, &w));
            let diffs = n.0.iter().filter(|(k, v)| cfg.get(k) != Some(**v)).count();
            assert_eq!(diffs, 1);
        }
    }
}

#[test]
fn prop_config_key_roundtrips() {
    let mut rng = Rng::seed_from(14);
    let space = spaces::attention_sim_space();
    let w = Workload::llama3_attention(8, 1024);
    for _ in 0..CASES {
        let Some(cfg) = space.sample(&w, &mut rng, 100) else { continue };
        assert_eq!(Config::parse(&cfg.key()), Some(cfg));
    }
}

#[test]
fn prop_constraint_rejection_is_sound() {
    // A config violating a named constraint is never enumerated.
    let space = ConfigSpace::new("t")
        .param("x", &[1, 2, 3, 4])
        .param("y", &[1, 2, 3, 4])
        .constraint("x_le_y", |c, _| c.req("x") <= c.req("y"));
    let w = Workload::VectorAdd { n: 64, dtype: DType::F32 };
    let all: Vec<Config> = space.enumerate(&w).collect();
    assert_eq!(all.len(), 10); // upper triangle of 4x4
    for c in all {
        assert!(c.req("x") <= c.req("y"));
    }
}

// ---------------------------------------------------------------------
// Hierarchical-space invariants: the hierarchy is an enumeration
// optimisation, never a semantic change — every space must yield the
// bit-identical valid sequence its flattened (level-free, leaf-checked)
// equivalent yields, and the stats triple must partition the raw
// cartesian product exactly.
// ---------------------------------------------------------------------

fn random_rms_workload(rng: &mut Rng) -> Workload {
    Workload::RmsNorm {
        n_rows: *rng.choose(&[1usize, 64, 512, 4096, 16384]).unwrap(),
        hidden: *rng.choose(&[256usize, 1024, 4096, 8192]).unwrap(),
        dtype: if rng.f64() < 0.5 { DType::F16 } else { DType::BF16 },
    }
}

fn random_space_and_workload(case: usize, rng: &mut Rng) -> (ConfigSpace, Workload) {
    match case % 5 {
        0 => (spaces::attention_sim_space(), random_attention_workload(rng)),
        1 => (spaces::attention_aot_space(), random_attention_workload(rng)),
        2 => (spaces::rms_sim_space(), random_rms_workload(rng)),
        3 => (spaces::rms_aot_space(), random_rms_workload(rng)),
        _ => (
            spaces::vecadd_aot_space(),
            Workload::VectorAdd { n: 1 + rng.below(1 << 22), dtype: DType::F32 },
        ),
    }
}

#[test]
fn prop_hierarchy_enumerates_bit_identically_to_flat() {
    // Same configs, same fingerprints, same order — across all five
    // shipped spaces and randomized workloads.
    let mut rng = Rng::seed_from(71);
    for case in 0..CASES {
        let (space, w) = random_space_and_workload(case, &mut rng);
        let flat = space.flatten();
        let h: Vec<(String, u64)> =
            space.enumerate(&w).map(|c| (c.key(), c.fingerprint())).collect();
        let f: Vec<(String, u64)> =
            flat.enumerate(&w).map(|c| (c.key(), c.fingerprint())).collect();
        assert_eq!(h, f, "{}: hierarchy changed the valid set or its order", space.name);
    }
}

#[test]
fn prop_space_stats_partition_the_raw_product() {
    // valid + invalid + pruned-subtree leaves == cardinality, the valid
    // count agrees with enumeration, and flattening converts every
    // pruned leaf into an individually-rejected invalid one.
    let mut rng = Rng::seed_from(72);
    for case in 0..CASES {
        let (space, w) = random_space_and_workload(case, &mut rng);
        let s = space.count_valid(&w);
        assert_eq!(s.total(), space.cardinality(), "{}: stats must partition", space.name);
        assert_eq!(s.valid, space.enumerate(&w).count(), "{}", space.name);
        let fs = space.flatten().count_valid(&w);
        assert_eq!(fs.pruned, 0, "{}: a flat space cannot prune subtrees", space.name);
        assert_eq!(fs.valid, s.valid, "{}", space.name);
        assert_eq!(fs.total(), space.cardinality(), "{}", space.name);
    }
}

#[test]
fn prop_memory_rejection_edges_hold_on_every_platform() {
    // For any sampled config with footprint m on any platform sheet:
    // capacity m accepts (exact fit), capacity m-1 rejects (off by
    // one), capacity 0 rejects anything with a nonzero footprint — and
    // the rejection reason always names the shared-memory budget.
    let mut rng = Rng::seed_from(73);
    let space = spaces::attention_sim_space();
    for gpu in [SimGpu::a100(), SimGpu::mi250(), SimGpu::h100()] {
        for _ in 0..CASES / 3 {
            let w = random_attention_workload(&mut rng);
            let Some(cfg) = space.sample(&w, &mut rng, 100) else { continue };
            let mem = cfg.mem_bytes(&w);
            assert!(mem > 0, "attention configs always stage tiles");
            let at = |budget: usize| {
                let mut g = gpu.clone();
                g.spec.smem_per_block = budget;
                g.validate_memory(&cfg, &w)
            };
            assert!(at(mem).is_ok(), "exact fit must be accepted");
            assert!(at(mem + 1).is_ok(), "slack must be accepted");
            let off = at(mem - 1).expect_err("one byte short must reject");
            assert!(off.reason.contains("shared memory"), "reason: {}", off.reason);
            let zero = at(0).expect_err("zero capacity must reject");
            assert!(zero.reason.contains("shared memory"), "reason: {}", zero.reason);
        }
    }
}

// ---------------------------------------------------------------------
// Platform-model invariants
// ---------------------------------------------------------------------

#[test]
fn prop_model_latency_finite_positive_or_invalid() {
    let mut rng = Rng::seed_from(21);
    let space = spaces::attention_sim_space();
    for _ in 0..CASES {
        let w = random_attention_workload(&mut rng);
        let Some(cfg) = space.sample(&w, &mut rng, 100) else { continue };
        for gpu in [SimGpu::a100(), SimGpu::mi250()] {
            match gpu.attention_latency_us(&cfg, &w, &HAND_TUNED) {
                Ok(us) => assert!(us.is_finite() && us > 0.0, "{cfg} on {}", gpu.spec.name),
                Err(e) => assert!(!e.reason.is_empty()),
            }
        }
    }
}

#[test]
fn prop_model_monotone_in_batch() {
    // Fixed config, doubled batch => strictly more time.
    let mut rng = Rng::seed_from(22);
    let space = spaces::attention_sim_space();
    for _ in 0..CASES {
        let seq = *rng.choose(&[512usize, 1024, 2048]).unwrap();
        let b = *rng.choose(&[1usize, 2, 4, 8, 16]).unwrap();
        let w1 = Workload::llama3_attention(b, seq);
        let w2 = Workload::llama3_attention(b * 4, seq);
        let Some(cfg) = space.sample(&w1, &mut rng, 100) else { continue };
        let gpu = SimGpu::a100();
        let (Ok(t1), Ok(t2)) = (
            gpu.attention_latency_us(&cfg, &w1, &HAND_TUNED),
            gpu.attention_latency_us(&cfg, &w2, &HAND_TUNED),
        ) else {
            continue;
        };
        assert!(t2 > t1, "{cfg}: batch {b}x4 {t2:.1}us <= {t1:.1}us");
    }
}

#[test]
fn prop_codegen_efficiency_never_helps() {
    // Triton codegen (eff < 1) can never beat hand-tuned on the same
    // config — autotuning wins by config choice, not by magic.
    let mut rng = Rng::seed_from(23);
    let space = spaces::attention_sim_space();
    for _ in 0..CASES {
        let w = random_attention_workload(&mut rng);
        let Some(cfg) = space.sample(&w, &mut rng, 100) else { continue };
        for gpu in [SimGpu::a100(), SimGpu::mi250()] {
            let cg = triton_codegen(gpu.spec.vendor);
            if let (Ok(hand), Ok(triton)) = (
                gpu.attention_latency_us(&cfg, &w, &HAND_TUNED),
                gpu.attention_latency_us(&cfg, &w, &cg),
            ) {
                assert!(triton >= hand * 0.999, "{cfg}: triton {triton} < hand {hand}");
            }
        }
    }
}

#[test]
fn prop_validity_agrees_with_latency() {
    // latency_us errors iff validate_attention errors.
    let mut rng = Rng::seed_from(24);
    let space = spaces::attention_sim_space();
    for _ in 0..CASES {
        let w = random_attention_workload(&mut rng);
        let Some(cfg) = space.sample(&w, &mut rng, 100) else { continue };
        let gpu = SimGpu::mi250();
        assert_eq!(
            gpu.validate_attention(&cfg, &w).is_ok(),
            gpu.attention_latency_us(&cfg, &w, &HAND_TUNED).is_ok()
        );
    }
}

// ---------------------------------------------------------------------
// Cache invariants
// ---------------------------------------------------------------------

#[test]
fn prop_cache_put_get_identity() {
    let mut rng = Rng::seed_from(31);
    let mut cache = TuningCache::ephemeral();
    let mut inserted = Vec::new();
    for i in 0..CASES {
        let w = random_attention_workload(&mut rng);
        let platform = format!("p{}", rng.below(3));
        let space = format!("s{}", rng.below(2));
        let cfg = Config::new(&[("BLOCK_M", 16 << rng.below(4) as i64)]);
        let e = entry_now(&cfg, i as f64 + 1.0, 10, 1, &platform, &space, 0.1);
        cache.put(&w, e.clone());
        inserted.push((w, platform, space, e));
    }
    // Last write per key wins; every inserted key resolves consistently.
    for (w, platform, space, _) in &inserted {
        let got = cache.get(w, platform, space).expect("inserted key must hit");
        assert_eq!(&got.platform, platform);
        assert_eq!(&got.space, space);
    }
}

#[test]
fn prop_cache_disk_roundtrip_random() {
    let dir = portatune::util::tmp::TempDir::new("prop-cache").unwrap();
    let path = dir.join("c.json");
    let mut rng = Rng::seed_from(32);
    let mut entries = Vec::new();
    {
        let mut cache = TuningCache::open(&path).unwrap();
        for i in 0..30 {
            let w = random_attention_workload(&mut rng);
            let cfg = Config::new(&[("BLOCK_M", 32), ("num_warps", 1 << rng.below(4) as i64)]);
            let e = entry_now(&cfg, rng.range(1.0, 1e6), i, i / 2, "plat", "space", rng.f64());
            cache.put(&w, e.clone());
            entries.push((w, e));
        }
        cache.save().unwrap();
    }
    let cache = TuningCache::open(&path).unwrap();
    for (w, e) in &entries {
        let got = cache.get(w, "plat", "space").expect("persisted");
        // floats survive the JSON roundtrip to f64 precision
        if got.config == e.config {
            assert!((got.latency_us - e.latency_us).abs() < 1e-9 * e.latency_us.max(1.0));
        }
    }
}

// ---------------------------------------------------------------------
// Batcher invariants
// ---------------------------------------------------------------------

#[test]
fn prop_batcher_conserves_requests() {
    let mut rng = Rng::seed_from(41);
    for _ in 0..20 {
        let policy = BucketPolicy::new(
            vec![(128, 1), (128, 4), (256, 2), (512, 1)],
            rng.below(5000) as u64,
        );
        let mut b = DynamicBatcher::new(policy);
        let now = Instant::now();
        let n = 50 + rng.below(200);
        let mut pushed = HashSet::new();
        let mut popped = HashSet::new();
        for id in 0..n as u64 {
            let tokens = 1 + rng.below(700);
            b.push(Request { id, tokens }, now);
            pushed.insert(id);
            // Randomly interleave batch pops.
            if rng.f64() < 0.3 {
                while let Some(batch) = b.next_batch(now, false) {
                    for r in batch.requests {
                        assert!(popped.insert(r.id), "duplicate {}", r.id);
                    }
                }
            }
        }
        while let Some(batch) = b.next_batch(now, true) {
            assert!(batch.requests.len() <= batch.batch_shape);
            for r in &batch.requests {
                assert!(r.tokens <= batch.seq_len, "request overflows bucket");
                assert!(popped.insert(r.id), "duplicate {}", r.id);
            }
        }
        let rejected: HashSet<u64> = b.rejected.iter().map(|r| r.id).collect();
        assert_eq!(popped.len() + rejected.len(), pushed.len(), "requests lost");
        assert!(popped.is_disjoint(&rejected));
    }
}

#[test]
fn prop_batcher_batch_shape_is_compiled_shape() {
    let mut rng = Rng::seed_from(42);
    let policy = BucketPolicy::new(vec![(128, 1), (128, 2), (128, 4), (256, 2)], 0);
    let shapes: HashSet<(usize, usize)> =
        [(128, 1), (128, 2), (128, 4), (256, 2)].into_iter().collect();
    let mut b = DynamicBatcher::new(policy);
    let now = Instant::now();
    for id in 0..300u64 {
        b.push(Request { id, tokens: 1 + rng.below(256) }, now);
        while let Some(batch) = b.next_batch(now, false) {
            assert!(
                shapes.contains(&(batch.seq_len, batch.batch_shape)),
                "batch shape ({}, {}) was never compiled",
                batch.seq_len,
                batch.batch_shape
            );
        }
    }
}

// ---------------------------------------------------------------------
// JSON fuzz
// ---------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Value {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.f64() < 0.5),
        2 => Value::Num((rng.f64() * 2e6).round() / 8.0 - 1e5),
        3 => {
            let len = rng.below(12);
            Value::Str((0..len).map(|_| *rng.choose(&['a', 'β', '"', '\\', '\n', '😀', ' ']).unwrap()).collect())
        }
        4 => Value::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Value::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip_random_trees() {
    let mut rng = Rng::seed_from(51);
    for _ in 0..200 {
        let v = random_json(&mut rng, 4);
        let compact = json::parse(&v.dump()).unwrap_or_else(|e| panic!("{e}: {}", v.dump()));
        assert_eq!(compact, v);
        let pretty = json::parse(&v.pretty(2)).unwrap();
        assert_eq!(pretty, v);
    }
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    let mut rng = Rng::seed_from(52);
    let alphabet: Vec<char> = "{}[]\",:0123456789.eE+-truefalsn \\u\n".chars().collect();
    for _ in 0..500 {
        let len = rng.below(60);
        let s: String = (0..len).map(|_| *rng.choose(&alphabet).unwrap()).collect();
        let _ = json::parse(&s); // must return, never panic
    }
}

// ---------------------------------------------------------------------
// Surrogate-fitter invariants (ISSUE 9 satellite): exact recovery on
// synthetic linear data, bitwise determinism under history permutation,
// and graceful degradation when the history underdetermines the model.
// ---------------------------------------------------------------------

/// A full-fidelity training history from the analytical sim — the same
/// shape of data the surrogate mode and the serving refit hook feed the
/// fitter.
fn surrogate_history(w: &Workload, n: usize) -> Vec<(Config, Workload, f64)> {
    let gpu = SimGpu::a100();
    spaces::attention_sim_space()
        .equally_spaced(w, n)
        .into_iter()
        .filter_map(|c| {
            gpu.attention_latency_us(&c, w, &HAND_TUNED).ok().map(|us| (c, *w, us))
        })
        .collect()
}

#[test]
fn prop_ridge_fit_recovers_planted_coefficients() {
    // ys generated exactly linearly in the features => the ridge solve
    // (tiny lambda) must hand the planted coefficients back.
    let mut rng = Rng::seed_from(81);
    for case in 0..20 {
        let dim = 2 + rng.below(5);
        let n = dim * 6 + rng.below(20);
        let planted: Vec<f64> = (0..dim).map(|_| rng.range(-3.0, 3.0)).collect();
        let rows: Vec<Vec<f64>> =
            (0..n).map(|_| (0..dim).map(|_| rng.range(-2.0, 2.0)).collect()).collect();
        let ys: Vec<f64> = rows
            .iter()
            .map(|r| r.iter().zip(&planted).map(|(x, b)| x * b).sum())
            .collect();
        let coefs = ridge_fit(&rows, &ys, 1e-9).expect("well-conditioned system must fit");
        for (i, (got, want)) in coefs.iter().zip(&planted).enumerate() {
            assert!(
                (got - want).abs() < 1e-4,
                "case {case} coef {i}: fit {got} != planted {want}"
            );
        }
    }
}

#[test]
fn prop_costmodel_fit_is_bitwise_invariant_under_history_permutation() {
    // The fitter canonicalizes its history, so permuted-but-equal
    // histories (the online-refit case: records arrive in whatever
    // order buckets complete) must produce bit-identical coefficients.
    let mut rng = Rng::seed_from(82);
    let w = Workload::llama3_attention(1, 256);
    let samples = surrogate_history(&w, 48);
    let base = CostModel::fit("sim-a100/test", &samples, RIDGE_LAMBDA)
        .expect("48 seed samples must overdetermine the feature set");
    for round in 0..10 {
        let mut shuffled = samples.clone();
        for i in (1..shuffled.len()).rev() {
            shuffled.swap(i, rng.below(i + 1));
        }
        let refit = CostModel::fit("sim-a100/test", &shuffled, RIDGE_LAMBDA).unwrap();
        assert_eq!(base.coefs.len(), refit.coefs.len());
        for (j, (a, b)) in base.coefs.iter().zip(&refit.coefs).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "round {round}: permutation moved coef {j} bits"
            );
        }
        assert_eq!(base, refit, "round {round}: models must be equal");
    }
    // Duplicated records collapse to the same canonical set: same bits.
    let mut doubled = samples.clone();
    doubled.extend(samples.iter().cloned());
    let dedup = CostModel::fit("sim-a100/test", &doubled, RIDGE_LAMBDA).unwrap();
    assert_eq!(base, dedup, "duplicate records must not perturb the fit");
}

#[test]
fn prop_costmodel_fit_degrades_gracefully_instead_of_panicking() {
    let mut rng = Rng::seed_from(83);
    let w = Workload::llama3_attention(1, 256);
    let all = surrogate_history(&w, 48);
    let dim = features(&all[0].0, &w).len();
    assert!(all.len() > dim, "history must overdetermine for the positive cases below");
    // Fewer records than features: the fit declines (the callers then
    // fall back to unguided measurement) — it never panics.
    for n in 0..dim {
        let head: Vec<_> = all.iter().take(n).cloned().collect();
        assert!(
            CostModel::fit("p", &head, RIDGE_LAMBDA).is_none(),
            "{n} records cannot determine {dim} features"
        );
    }
    // One config duplicated past `dim` rows is still a single canonical
    // record — underdetermined, declined, no panic.
    let degenerate: Vec<_> = vec![all[0].clone(); dim + 5];
    assert!(CostModel::fit("p", &degenerate, RIDGE_LAMBDA).is_none());
    // Random multisets of real records never panic, and whenever the
    // fit succeeds its predictions are finite for in-schema configs.
    for _ in 0..CASES {
        let n = rng.below(all.len() + 1);
        let subset: Vec<_> = (0..n).map(|_| all[rng.below(all.len())].clone()).collect();
        if let Some(m) = CostModel::fit("p", &subset, RIDGE_LAMBDA) {
            let p = m.predict_us(&all[0].0, &w);
            assert!(p.is_finite(), "in-schema prediction must be finite, got {p}");
        }
    }
}

// ---------------------------------------------------------------------
// Scenario load-generator invariants
// ---------------------------------------------------------------------

#[test]
fn prop_scenario_traces_replay_per_seed_and_diverge_across_seeds() {
    // Same (scenario, n, max_tokens, seed) => identical trace, always;
    // a different seed must produce a different trace (arrival gaps
    // and/or token draws move).  This is the contract that makes
    // `serve --scenario` replays comparable across shard counts.
    for sc in Scenario::catalog() {
        for seed in [1u64, 7, 29, 1_000_003] {
            let a = sc.generate(150, 512, seed);
            let b = sc.generate(150, 512, seed);
            assert_eq!(a, b, "{} seed {seed} must replay bit-identically", sc.name);
            let c = sc.generate(150, 512, seed + 1);
            assert_ne!(a, c, "{} must diverge when the seed moves", sc.name);
        }
    }
}

#[test]
fn prop_scenario_traces_are_monotone_sequential_and_in_bounds() {
    // Randomized (seeded) structural invariants over the whole catalog:
    // trace length, nondecreasing timestamps, sequential ids, token
    // counts inside [MIN_TOKENS, max_tokens], class indices in range.
    let mut rng = Rng::seed_from(61);
    let catalog = Scenario::catalog();
    let max_tokens_choices = [8usize, 16, 64, 128, 512, 4096];
    for _ in 0..CASES {
        let sc = rng.choose(&catalog).unwrap();
        let n = 1 + rng.below(200);
        let max_tokens = *rng.choose(&max_tokens_choices).unwrap();
        let seed = rng.below(1 << 30) as u64;
        let trace = sc.generate(n, max_tokens, seed);
        assert_eq!(trace.len(), n, "{}", sc.name);
        for w in trace.windows(2) {
            assert!(w[0].at_us <= w[1].at_us, "{} timestamps must be nondecreasing", sc.name);
        }
        for (i, t) in trace.iter().enumerate() {
            assert_eq!(t.req.id, i as u64, "{} ids must be sequential", sc.name);
            assert!(
                (SeqLenMix::MIN_TOKENS..=max_tokens).contains(&t.req.tokens),
                "{}: {} tokens outside [{}, {max_tokens}]",
                sc.name,
                t.req.tokens,
                SeqLenMix::MIN_TOKENS
            );
            assert!(t.class < sc.classes.len(), "{} class index in range", sc.name);
        }
    }
}

#[test]
fn prop_scenario_class_mix_converges_to_declared_weights() {
    // Over a long trace, each traffic class's share must converge to
    // its normalized weight — multi-tenant scenarios really produce the
    // tenant mix they declare.
    for sc in Scenario::catalog() {
        let n = 4000usize;
        let trace = sc.generate(n, 512, 29);
        let total_weight: f64 = sc.classes.iter().map(|c| c.weight).sum();
        let mut counts = vec![0usize; sc.classes.len()];
        for t in &trace {
            counts[t.class] += 1;
        }
        for (i, c) in sc.classes.iter().enumerate() {
            let want = c.weight / total_weight;
            let got = counts[i] as f64 / n as f64;
            assert!(
                (got - want).abs() <= 0.05,
                "{} class {} share {got:.3} != declared {want:.3} (+/- 0.05)",
                sc.name,
                c.name
            );
        }
    }
}

#[test]
fn prop_seq_len_mixes_stay_in_bounds_and_order_by_intent() {
    // Every mix respects the clamp at every max_tokens, and the
    // prefill-heavy mix draws longer sequences on average than the
    // decode-heavy mix — the property that makes the burst scenario's
    // tenant split meaningful.
    let mixes = [
        SeqLenMix::PrefillHeavy,
        SeqLenMix::DecodeHeavy,
        SeqLenMix::Bimodal { short_frac: 0.6 },
        SeqLenMix::LogNormal { median: 48.0, sigma: 0.6 },
    ];
    for max_tokens in [64usize, 512, 4096] {
        let mean = |mix: &SeqLenMix, seed: u64| {
            let mut rng = Rng::seed_from(seed);
            let mut sum = 0usize;
            for _ in 0..2000 {
                let t = mix.sample(&mut rng, max_tokens);
                assert!(
                    (SeqLenMix::MIN_TOKENS..=max_tokens).contains(&t),
                    "{}: {t} outside [{}, {max_tokens}]",
                    mix.name(),
                    SeqLenMix::MIN_TOKENS
                );
                sum += t;
            }
            sum as f64 / 2000.0
        };
        let prefill = mean(&SeqLenMix::PrefillHeavy, 17);
        let decode = mean(&SeqLenMix::DecodeHeavy, 17);
        for mix in &mixes {
            mean(mix, 23); // bounds hold for every mix
        }
        assert!(
            prefill > decode,
            "at max_tokens={max_tokens}, prefill mean {prefill:.1} must exceed decode mean {decode:.1}"
        );
    }
}
