//! Equivalence contracts for the batched autotuner.
//!
//! Two families of guarantees are pinned here, both bit-exact (same
//! best config, same invalid count, same evaluation log — fingerprints,
//! latencies AND fidelities):
//!
//! 1. **Engine equivalence** (PR 1–3): every parallel evaluation path —
//!    per-batch scoped threads, both persistent worker pools (the v1
//!    mutex queue and the v2 work-stealing engine), and the sharded
//!    multi-device fleet — produces, for every strategy and seed,
//!    exactly the outcome the sequential evaluator produces.
//!    The fleet ("measure everywhere") mode extends this across
//!    platforms: tuning a heterogeneous fleet gives each platform
//!    exactly the outcome of tuning it alone.
//!
//! 2. **API equivalence** (the `TuningSession` surface): the builder's
//!    spellings coincide wherever the API promises they do — implicit
//!    defaults equal their explicit spelling, builder-option order is
//!    irrelevant, a cold cached run is bit-identical to an uncached
//!    one, and two independently-built caches behave identically cold
//!    and warm.  (These tests replaced the legacy-wrapper-vs-builder
//!    matrix when the five `#[deprecated]` `tune*` free functions were
//!    deleted after their one-release migration window.)
//!
//! Plus the [`Budget`] contract: `Budget::Evals` runs are deterministic
//! per seed and are exact prefixes of the uncapped history.

use portatune::autotuner::{
    Budget, Evaluator, FleetOutcome, MultiDeviceEvaluator, SessionOutcome, SimEvaluator,
    Strategy, TuneOutcome, TuningSession,
};
use portatune::cache::TuningCache;
use portatune::config::spaces;
use portatune::kernels::baselines::{HAND_TUNED, TRITON_NVIDIA};
use portatune::platform::SimGpu;
use portatune::util::tmp::TempDir;
use portatune::workload::Workload;

/// Which evaluation engine a run goes through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Sequential,
    ScopedThreads,
    /// The v1 mutex-queue pool baseline.
    PoolV1,
    /// The v2 work-stealing pool (the default engine).
    Pool,
    MultiDevice,
}

/// The canonical builder spelling of a plain solo tune.
fn builder_solo(
    space: &portatune::config::ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    strat: &Strategy,
    seed: u64,
) -> TuneOutcome {
    TuningSession::new(space, w)
        .strategy(strat.clone())
        .seed(seed)
        .evaluator(eval)
        .run()
        .and_then(SessionOutcome::into_solo)
        .expect("space is non-empty")
}

fn run(mode: Mode, strat: &Strategy, seed: u64) -> TuneOutcome {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let base = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let mut eval: Box<dyn Evaluator> = match mode {
        Mode::Sequential => Box::new(base.sequential()),
        Mode::ScopedThreads => Box::new(base.scoped_threads()),
        Mode::PoolV1 => Box::new(base.pool_v1()),
        Mode::Pool => Box::new(base),
        Mode::MultiDevice => Box::new(MultiDeviceEvaluator::replicate(&base, 3)),
    };
    builder_solo(&space, &w, eval.as_mut(), strat, seed)
}

fn all_strategies() -> Vec<Strategy> {
    vec![
        Strategy::Exhaustive,
        Strategy::Random { budget: 120 },
        Strategy::HillClimb { restarts: 3, budget: 200 },
        Strategy::Anneal { budget: 150, t0: 2.0, alpha: 0.95 },
        Strategy::SuccessiveHalving { initial: 32, eta: 2 },
    ]
}

/// Full-outcome equality: best config + latency bits, counters, and the
/// entire evaluation log entry for entry (fingerprint, latency bits,
/// and the fidelity each measurement was taken at).
fn assert_same_outcome(seq: &TuneOutcome, other: &TuneOutcome, label: &str) {
    assert_eq!(seq.best, other.best, "{label}: best config differs");
    assert_eq!(
        seq.best_latency_us.to_bits(),
        other.best_latency_us.to_bits(),
        "{label}: best latency differs"
    );
    assert_eq!(seq.invalid, other.invalid, "{label}: invalid count differs");
    assert_eq!(seq.evaluated, other.evaluated, "{label}: evaluated differs");
    assert_eq!(seq.history.len(), other.history.len(), "{label}: history length differs");
    for (i, (s, p)) in seq.history.iter().zip(&other.history).enumerate() {
        assert_eq!(s.fingerprint, p.fingerprint, "{label}: eval {i} config differs");
        assert_eq!(
            s.latency_us.map(f64::to_bits),
            p.latency_us.map(f64::to_bits),
            "{label}: eval {i} latency differs"
        );
        assert_eq!(
            s.fidelity.to_bits(),
            p.fidelity.to_bits(),
            "{label}: eval {i} fidelity differs"
        );
    }
}

/// Fleet-outcome equality: per-platform outcomes, winner count and the
/// portable pick.
fn assert_same_fleet(a: &FleetOutcome, b: &FleetOutcome, label: &str) {
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}: platform count differs");
    for ((p1, o1), (p2, o2)) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(p1, p2, "{label}: platform order differs");
        assert_same_outcome(o1, o2, &format!("{label} {p1}"));
    }
    assert_eq!(a.distinct_winners, b.distinct_winners, "{label}: winner count differs");
    match (&a.portable, &b.portable) {
        (Some(x), Some(y)) => {
            assert_eq!(x.config, y.config, "{label}: portable pick differs");
            assert_eq!(x.worst_slowdown.to_bits(), y.worst_slowdown.to_bits());
        }
        (None, None) => {}
        _ => panic!("{label}: portable-best presence differs"),
    }
}

#[test]
fn same_seed_same_outcome_for_every_strategy_and_engine() {
    for strat in all_strategies() {
        for seed in [0u64, 7, 42] {
            let seq = run(Mode::Sequential, &strat, seed);
            for mode in [Mode::ScopedThreads, Mode::PoolV1, Mode::Pool, Mode::MultiDevice] {
                let par = run(mode, &strat, seed);
                assert_same_outcome(&seq, &par, &format!("{strat:?} seed {seed} {mode:?}"));
            }
        }
    }
}

// ---------------------------------------------------------------------
// API equivalence: TuningSession spellings pinned against each other.
// ---------------------------------------------------------------------

#[test]
fn implicit_defaults_match_their_explicit_spelling() {
    // `TuningSession::new(..)` defaults to exhaustive search with seed
    // 0 — spelling the defaults out must change nothing, bit for bit.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let implicit = TuningSession::new(&space, &w)
        .evaluator(&mut eval)
        .run()
        .and_then(SessionOutcome::into_solo)
        .unwrap();
    let explicit = builder_solo(&space, &w, &mut eval, &Strategy::Exhaustive, 0);
    assert_same_outcome(&implicit, &explicit, "implicit vs explicit defaults");
}

#[test]
fn builder_option_order_is_irrelevant_for_every_strategy_and_seed() {
    // `.strategy().seed()` and `.seed().strategy()` are the same
    // session; the builder carries no order-dependent state.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    for strat in all_strategies() {
        for seed in [0u64, 7] {
            let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
            let a = builder_solo(&space, &w, &mut eval, &strat, seed);
            let b = TuningSession::new(&space, &w)
                .seed(seed)
                .strategy(strat.clone())
                .evaluator(&mut eval)
                .run()
                .and_then(SessionOutcome::into_solo)
                .unwrap();
            assert_same_outcome(&a, &b, &format!("option order {strat:?} seed {seed}"));
        }
    }
}

#[test]
fn cached_cold_run_is_bit_identical_to_an_uncached_run() {
    // Attaching a cold cache must not perturb the search; and two
    // independently-built caches must behave identically cold and warm.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    for strat in all_strategies() {
        let seed = 7;
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        let plain = builder_solo(&space, &w, &mut eval, &strat, seed);
        let mut cache_a = TuningCache::ephemeral();
        let mut cache_b = TuningCache::ephemeral();
        let cached = |cache: &mut TuningCache, eval: &mut dyn Evaluator| {
            TuningSession::new(&space, &w)
                .strategy(strat.clone())
                .seed(seed)
                .cache(cache)
                .evaluator(eval)
                .run()
                .and_then(SessionOutcome::into_solo)
                .unwrap()
        };
        let cold_a = cached(&mut cache_a, &mut eval);
        let cold_b = cached(&mut cache_b, &mut eval);
        assert!(!cold_a.from_cache && !cold_b.from_cache);
        assert_same_outcome(&plain, &cold_a, &format!("{strat:?}: cached cold vs plain"));
        assert_same_outcome(&cold_a, &cold_b, &format!("{strat:?}: two cold caches"));
        assert_eq!(cache_a.len(), cache_b.len(), "{strat:?}: cache sizes differ");
        // Warm: both caches hit, serving the same winner with zero
        // evaluations.
        let warm_a = cached(&mut cache_a, &mut eval);
        let warm_b = cached(&mut cache_b, &mut eval);
        assert!(warm_a.from_cache && warm_b.from_cache, "{strat:?}: warm run must hit");
        assert_eq!(warm_a.best, cold_a.best, "{strat:?}: cache hit serves the tuned winner");
        assert_eq!(warm_a.best, warm_b.best, "{strat:?}: cache hits differ");
        assert_eq!(warm_a.evaluated, 0);
    }
}

#[test]
fn guided_spelling_order_is_irrelevant_and_prunes() {
    // `.guided(prior, k).evaluator(t)` == `.evaluator(t).guided(prior, k)`,
    // and the measured set really is capped at k.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    for top_k in [5usize, 25, 100] {
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        let a = TuningSession::new(&space, &w)
            .guided(&mut prior, top_k)
            .evaluator(&mut target)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        let mut prior2 = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target2 = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        let b = TuningSession::new(&space, &w)
            .evaluator(&mut target2)
            .guided(&mut prior2, top_k)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert_same_outcome(&a, &b, &format!("guided spelling order k={top_k}"));
        assert!(a.evaluated <= top_k, "guided must measure at most k configs");
    }
}

/// A heterogeneous fleet for the measure-everywhere tests: two a100
/// replicas + one mi250, each with its vendor's codegen model.
fn het_fleet(w: Workload) -> MultiDeviceEvaluator {
    let a100 = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let mi250 = SimEvaluator::new(SimGpu::mi250(), w, portatune::kernels::baselines::TRITON_AMD);
    MultiDeviceEvaluator::new(vec![a100.clone(), mi250, a100])
}

/// The canonical builder spelling of a plain fleet tune.
fn builder_fleet(
    space: &portatune::config::ConfigSpace,
    w: &Workload,
    fleet: &mut MultiDeviceEvaluator,
    strat: &Strategy,
    seed: u64,
) -> FleetOutcome {
    TuningSession::new(space, w)
        .strategy(strat.clone())
        .seed(seed)
        .fleet(fleet)
        .run()
        .and_then(SessionOutcome::into_fleet)
        .expect("fleet tune must succeed")
}

#[test]
fn fleet_option_order_is_irrelevant_for_every_strategy_and_seed() {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    for strat in all_strategies() {
        for seed in [0u64, 7] {
            let mut fleet = het_fleet(w);
            let a = builder_fleet(&space, &w, &mut fleet, &strat, seed);
            let mut fleet = het_fleet(w);
            let b = TuningSession::new(&space, &w)
                .seed(seed)
                .fleet(&mut fleet)
                .strategy(strat.clone())
                .run()
                .and_then(SessionOutcome::into_fleet)
                .unwrap();
            assert_same_fleet(&a, &b, &format!("fleet option order {strat:?} {seed}"));
        }
    }
}

#[test]
fn fleet_cached_cold_run_matches_uncached_and_hits_warm() {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    for strat in [Strategy::Exhaustive, Strategy::SuccessiveHalving { initial: 32, eta: 2 }] {
        let seed = 3;
        let mut fleet = het_fleet(w);
        let plain = builder_fleet(&space, &w, &mut fleet, &strat, seed);
        let mut cache = TuningCache::ephemeral();
        let mut fleet = het_fleet(w);
        let cold = TuningSession::new(&space, &w)
            .strategy(strat.clone())
            .seed(seed)
            .cache(&mut cache)
            .fleet(&mut fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
            .unwrap();
        assert_same_fleet(&plain, &cold, &format!("fleet cached cold {strat:?}"));
        assert_eq!(cache.len(), cold.outcomes.len(), "one entry per platform");
        // Warm: the whole fleet is served from cache.
        let mut fleet = het_fleet(w);
        let warm = TuningSession::new(&space, &w)
            .strategy(strat.clone())
            .seed(seed)
            .cache(&mut cache)
            .fleet(&mut fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
            .unwrap();
        assert!(warm.from_cache, "{strat:?}: warm fleet run must hit");
        assert_eq!(warm.distinct_winners, cold.distinct_winners);
        for ((p1, o1), (p2, o2)) in cold.outcomes.iter().zip(&warm.outcomes) {
            assert_eq!(p1, p2);
            assert_eq!(o1.best, o2.best, "{strat:?} {p1}: cached winners differ");
            assert_eq!(o2.evaluated, 0);
        }
    }
}

// ---------------------------------------------------------------------
// Budget contract.
// ---------------------------------------------------------------------

#[test]
fn budget_evals_is_deterministic_per_seed() {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    for strat in all_strategies() {
        let capped = |seed: u64| {
            let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
            TuningSession::new(&space, &w)
                .strategy(strat.clone())
                .seed(seed)
                .budget(Budget::Evals(40))
                .evaluator(&mut eval)
                .run()
                .and_then(SessionOutcome::into_solo)
        };
        match (capped(7), capped(7)) {
            (Some(a), Some(b)) => {
                assert_same_outcome(&a, &b, &format!("budgeted {strat:?} reruns"));
                assert!(a.evaluated <= 40, "{strat:?}: budget exceeded ({})", a.evaluated);
                // And the capped history is an exact prefix of the
                // uncapped one for the batch-submitting strategies (the
                // adaptive strategies stop early, which can change
                // their *later* trajectory, but exhaustive/random order
                // is budget-independent).
                if matches!(strat, Strategy::Exhaustive | Strategy::Random { .. }) {
                    let uncapped = run(Mode::Pool, &strat, 7);
                    assert_eq!(
                        a.history[..],
                        uncapped.history[..a.evaluated],
                        "{strat:?}: not a prefix"
                    );
                }
            }
            // A cap can legitimately leave no confirmed full-fidelity
            // best (e.g. SHA truncated before its confirmation) — but
            // it must do so deterministically.
            (None, None) => {}
            _ => panic!("{strat:?}: budgeted reruns disagree about finding a best"),
        }
    }
}

#[test]
fn budget_applies_per_platform_on_fleets() {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let mut fleet = het_fleet(w);
    let out = TuningSession::new(&space, &w)
        .budget(Budget::Evals(200))
        .fleet(&mut fleet)
        .run()
        .and_then(SessionOutcome::into_fleet)
        .expect("200 evals find a valid config on both platforms");
    for (platform, o) in &out.outcomes {
        assert_eq!(o.evaluated, 200, "{platform}: the per-platform cap is the whole budget");
    }
}

// ---------------------------------------------------------------------
// Engine equivalence (pool / scoped / fleet), unchanged contracts.
// ---------------------------------------------------------------------

#[test]
fn pool_reuse_across_batches_matches_scoped_threads() {
    // One pooled evaluator reused across several batches must keep
    // producing exactly what a fresh scoped-thread evaluation produces:
    // the persistent pool carries no state between scopes.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let cfgs: Vec<portatune::config::Config> = space.enumerate(&w).collect();
    let mut pooled = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    for round in 0..3 {
        let mut scoped = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA).scoped_threads();
        let a = pooled.evaluate_batch(&cfgs, 1.0);
        let b = scoped.evaluate_batch(&cfgs, 1.0);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            match (x, y) {
                (Ok(p), Ok(q)) => {
                    assert_eq!(p.to_bits(), q.to_bits(), "round {round} cfg {i} differs")
                }
                (Err(_), Err(_)) => {}
                _ => panic!("round {round} cfg {i}: validity differs"),
            }
        }
    }
    assert_eq!(pooled.calls, 3 * cfgs.len());
}

#[test]
fn multi_device_fleet_spreads_work_without_changing_results() {
    // Equivalence is covered per-strategy above; this pins the sharding
    // itself: every device of the fleet participates in a large tune,
    // and the per-device counters account for every evaluation.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let base = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let mut fleet = MultiDeviceEvaluator::replicate(&base, 4);
    let out = builder_solo(&space, &w, &mut fleet, &Strategy::Exhaustive, 0);
    // `evaluated` counts valid + invalid submissions, exactly what the
    // per-device counters see.
    let counted: usize = fleet.utilization().iter().map(|u| u.evaluated).sum();
    assert_eq!(counted, out.evaluated, "counters must cover the whole run");
    assert_eq!(counted, out.history.len());
    for (i, u) in fleet.utilization().iter().enumerate() {
        assert!(u.evaluated > 0, "device {i} never saw work");
        assert!(u.shards > 0, "device {i} processed no shards");
    }
    assert!(fleet.wall_us() > 0.0);
}

/// Solo tuning of one fleet platform with a freshly built *sequential*
/// evaluator — ground truth constructed without any fleet machinery, so
/// the comparison cannot be circular.
fn solo_outcome(platform: &str, strat: &Strategy, seed: u64) -> TuneOutcome {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let a100 = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA).sequential();
    let mi250 = SimEvaluator::new(SimGpu::mi250(), w, portatune::kernels::baselines::TRITON_AMD)
        .sequential();
    let mut eval = if a100.name() == platform {
        a100
    } else {
        assert_eq!(mi250.name(), platform, "unknown fleet platform {platform}");
        mi250
    };
    builder_solo(&space, &w, &mut eval, strat, seed)
}

#[test]
fn fleet_measure_everywhere_is_bit_identical_to_solo_tuning_per_platform() {
    // The tentpole guarantee of fleet tuning: for every strategy and
    // seed, each platform's outcome — winner, latency bits, counters,
    // and the full (fingerprint, latency, fidelity) log — is exactly
    // what tuning that platform alone with a sequential evaluator
    // produces.  Exhaustive/random share one measure-everywhere
    // trajectory; the adaptive strategies run per platform; neither may
    // be observable in the result.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    for strat in all_strategies() {
        for seed in [0u64, 7] {
            let mut fleet = het_fleet(w);
            let out = builder_fleet(&space, &w, &mut fleet, &strat, seed);
            assert_eq!(out.outcomes.len(), 2, "two distinct platforms");
            for (platform, got) in &out.outcomes {
                let want = solo_outcome(platform, &strat, seed);
                assert_same_outcome(&want, got, &format!("{strat:?} seed {seed} {platform}"));
            }
        }
    }
}

#[test]
fn fleet_replicas_shard_platform_copies_without_changing_results() {
    // 1 vs 2 a100 replicas: the a100 copy of each batch is sharded
    // differently, but the a100 outcome must not change.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let a100 = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let mi250 = SimEvaluator::new(SimGpu::mi250(), w, portatune::kernels::baselines::TRITON_AMD);
    let mut small = MultiDeviceEvaluator::new(vec![a100.clone(), mi250.clone()]);
    let mut wide = MultiDeviceEvaluator::new(vec![a100.clone(), mi250, a100]);
    let a = builder_fleet(&space, &w, &mut small, &Strategy::Exhaustive, 0);
    let b = builder_fleet(&space, &w, &mut wide, &Strategy::Exhaustive, 0);
    assert_same_fleet(&a, &b, "replica widths");
}

#[test]
fn guided_tuning_parallel_prior_matches_sequential() {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let outcome = |parallel: bool| {
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        if !parallel {
            prior = prior.sequential();
            target = target.sequential();
        }
        TuningSession::new(&space, &w)
            .guided(&mut prior, 25)
            .evaluator(&mut target)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap()
    };
    let seq = outcome(false);
    let par = outcome(true);
    assert_eq!(seq.best, par.best);
    assert_eq!(seq.best_latency_us.to_bits(), par.best_latency_us.to_bits());
    assert_eq!(seq.evaluated, par.evaluated);
    assert_eq!(seq.invalid, par.invalid);
}

#[test]
fn raw_batch_api_is_order_preserving() {
    // evaluate_batch's contract: out[i] belongs to cfgs[i].
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let cfgs: Vec<portatune::config::Config> = space.enumerate(&w).collect();
    let mut par = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
    let batch = par.evaluate_batch(&cfgs, 1.0);
    let mut one_by_one = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
    for (cfg, from_batch) in cfgs.iter().zip(&batch) {
        let single = one_by_one.evaluate(cfg);
        match (from_batch, single) {
            (Ok(a), Ok(b)) => assert_eq!(a.to_bits(), b.to_bits(), "{cfg}"),
            (Err(_), Err(_)) => {}
            _ => panic!("validity mismatch for {cfg}"),
        }
    }
}

// ---------------------------------------------------------------------
// Surrogate mode: the self-priming spelling of guided tuning.
// ---------------------------------------------------------------------

#[test]
fn surrogate_with_k_covering_the_space_is_bit_identical_to_exhaustive() {
    // `.surrogate(k)` with k >= |valid space| cannot prune anything, so
    // the run must delegate to the exhaustive engine and reproduce its
    // outcome bit for bit — winner, counters, and the whole
    // (fingerprint, latency, fidelity) log.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let n_valid = space.enumerate(&w).count();
    let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let exhaustive = builder_solo(&space, &w, &mut eval, &Strategy::Exhaustive, 0);
    for k in [n_valid, n_valid + 1, 10 * n_valid] {
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        let surrogate = TuningSession::new(&space, &w)
            .surrogate(k)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert_same_outcome(&exhaustive, &surrogate, &format!("surrogate k={k} vs exhaustive"));
    }
}

#[test]
fn surrogate_mode_is_bit_identical_across_engines() {
    // The surrogate path (seed sample → fit → re-rank → top-k measure)
    // was never part of the engine-equivalence matrix above; pin it
    // here: for every evaluation engine, `.surrogate(k)` produces
    // exactly the sequential outcome — winner, counters, and the full
    // (fingerprint, latency, fidelity) log.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let surrogate_run = |eval: &mut dyn Evaluator| {
        TuningSession::new(&space, &w)
            .surrogate(32)
            .evaluator(eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .expect("surrogate run finds a best")
    };
    let base = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let seq = surrogate_run(&mut base.clone().sequential());
    for mode in [Mode::ScopedThreads, Mode::PoolV1, Mode::Pool, Mode::MultiDevice] {
        let mut eval: Box<dyn Evaluator> = match mode {
            Mode::Sequential => unreachable!("sequential is the baseline"),
            Mode::ScopedThreads => Box::new(base.clone().scoped_threads()),
            Mode::PoolV1 => Box::new(base.clone().pool_v1()),
            Mode::Pool => Box::new(base.clone()),
            Mode::MultiDevice => Box::new(MultiDeviceEvaluator::replicate(&base, 3)),
        };
        let par = surrogate_run(eval.as_mut());
        assert_same_outcome(&seq, &par, &format!("surrogate k=32 {mode:?}"));
    }
}

#[test]
fn surrogate_spelling_order_is_irrelevant_and_caps_measurements() {
    // `.surrogate(k).evaluator(t)` == `.evaluator(t).surrogate(k)`, and
    // the measured set is capped by the seed sample plus the top-k.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    for top_k in [5usize, 32, 100] {
        let mut target = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        let a = TuningSession::new(&space, &w)
            .surrogate(top_k)
            .evaluator(&mut target)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        let mut target2 = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        let b = TuningSession::new(&space, &w)
            .evaluator(&mut target2)
            .surrogate(top_k)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert_same_outcome(&a, &b, &format!("surrogate spelling order k={top_k}"));
        assert!(
            a.evaluated <= portatune::surrogate::SEED_SAMPLE + top_k,
            "surrogate k={top_k} measured {} configs (cap {})",
            a.evaluated,
            portatune::surrogate::SEED_SAMPLE + top_k
        );
    }
}

#[test]
fn surrogate_top32_winner_is_within_10pct_of_exhaustive_on_both_platforms() {
    // The acceptance pin: at k = 32 on the attention sim space the
    // surrogate's winner is within 10% of the exhaustive winner on both
    // vendors, while measuring an order of magnitude fewer configs.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let n_valid = space.enumerate(&w).count();
    let runs: [(&str, Box<dyn Fn() -> SimEvaluator>); 2] = [
        ("a100", Box::new(move || SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA))),
        ("mi250", Box::new(move || {
            SimEvaluator::new(SimGpu::mi250(), w, portatune::kernels::baselines::TRITON_AMD)
        })),
    ];
    for (label, make) in &runs {
        let mut eval = make();
        let exhaustive = builder_solo(&space, &w, &mut eval, &Strategy::Exhaustive, 0);
        let mut eval = make();
        let surrogate = TuningSession::new(&space, &w)
            .surrogate(32)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert!(
            surrogate.best_latency_us <= exhaustive.best_latency_us * 1.10,
            "{label}: surrogate winner {:.2} us misses exhaustive {:.2} us by more than 10%",
            surrogate.best_latency_us,
            exhaustive.best_latency_us
        );
        assert!(
            surrogate.evaluated < n_valid / 2,
            "{label}: surrogate measured {} of {n_valid} configs — no pruning happened",
            surrogate.evaluated
        );
    }
}

#[test]
fn tuning_cache_roundtrip_under_fingerprint_keys() {
    // The session keys cache entries by the space-definition
    // fingerprint; a restart (fresh TuningCache from the same file,
    // fresh space instance) must hit, and the hit must reproduce the
    // tuned best.
    let w = Workload::llama3_attention(8, 1024);
    let dir = TempDir::new("equiv-cache").unwrap();
    let path = dir.join("tune_cache.json");
    let first;
    {
        let mut cache = TuningCache::open(&path).unwrap();
        let space = spaces::attention_sim_space();
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        first = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert!(!first.from_cache);
        cache.save().unwrap();
    }
    {
        let mut cache = TuningCache::open(&path).unwrap();
        assert_eq!(cache.len(), 1);
        // A fresh space instance fingerprints identically.
        let space = spaces::attention_sim_space();
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        let second = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert!(second.from_cache, "restart must hit the fingerprint key");
        assert_eq!(second.best, first.best);
        assert_eq!(second.evaluated, 0);
        assert_eq!(eval.calls, 0, "cache hit performs zero evaluations");
    }
}
