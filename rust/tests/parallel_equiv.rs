//! Parallel/sequential equivalence contract for the batched autotuner.
//!
//! The tentpole guarantee of the parallel evaluation engine: a parallel
//! [`SimEvaluator`] must produce, for every strategy and seed, exactly
//! the outcome the sequential evaluator produces — same best config,
//! same invalid count, same evaluation log (fingerprints AND latencies,
//! bitwise).  Results are merged in submission order, so any divergence
//! here is a real bug, not scheduling noise.

use portatune::autotuner::{self, Evaluator, SimEvaluator, Strategy, TuneOutcome};
use portatune::cache::TuningCache;
use portatune::config::spaces;
use portatune::kernels::baselines::{HAND_TUNED, TRITON_NVIDIA};
use portatune::platform::SimGpu;
use portatune::util::tmp::TempDir;
use portatune::workload::Workload;

fn run(parallel: bool, strat: &Strategy, seed: u64) -> TuneOutcome {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    if !parallel {
        eval = eval.sequential();
    }
    autotuner::tune(&space, &w, &mut eval, strat, seed).expect("space is non-empty")
}

fn all_strategies() -> Vec<Strategy> {
    vec![
        Strategy::Exhaustive,
        Strategy::Random { budget: 120 },
        Strategy::HillClimb { restarts: 3, budget: 200 },
        Strategy::Anneal { budget: 150, t0: 2.0, alpha: 0.95 },
        Strategy::SuccessiveHalving { initial: 32, eta: 2 },
    ]
}

#[test]
fn same_seed_same_outcome_for_every_strategy() {
    for strat in all_strategies() {
        for seed in [0u64, 7, 42] {
            let seq = run(false, &strat, seed);
            let par = run(true, &strat, seed);
            assert_eq!(seq.best, par.best, "{strat:?} seed {seed}: best config differs");
            assert_eq!(
                seq.best_latency_us.to_bits(),
                par.best_latency_us.to_bits(),
                "{strat:?} seed {seed}: best latency differs"
            );
            assert_eq!(seq.invalid, par.invalid, "{strat:?} seed {seed}: invalid count differs");
            assert_eq!(seq.evaluated, par.evaluated, "{strat:?} seed {seed}: evaluated differs");
            // The full evaluation log must match entry for entry:
            // identical fingerprints in identical order, and bitwise
            // identical latencies.
            assert_eq!(seq.history.len(), par.history.len());
            for (i, (s, p)) in seq.history.iter().zip(&par.history).enumerate() {
                assert_eq!(s.0, p.0, "{strat:?} seed {seed}: eval {i} config differs");
                assert_eq!(
                    s.1.map(f64::to_bits),
                    p.1.map(f64::to_bits),
                    "{strat:?} seed {seed}: eval {i} latency differs"
                );
            }
        }
    }
}

#[test]
fn guided_tuning_parallel_prior_matches_sequential() {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let outcome = |parallel: bool| {
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        if !parallel {
            prior = prior.sequential();
            target = target.sequential();
        }
        autotuner::tune_guided(&space, &w, &mut prior, &mut target, 25).unwrap()
    };
    let seq = outcome(false);
    let par = outcome(true);
    assert_eq!(seq.best, par.best);
    assert_eq!(seq.best_latency_us.to_bits(), par.best_latency_us.to_bits());
    assert_eq!(seq.evaluated, par.evaluated);
    assert_eq!(seq.invalid, par.invalid);
}

#[test]
fn raw_batch_api_is_order_preserving() {
    // evaluate_batch's contract: out[i] belongs to cfgs[i].
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let cfgs: Vec<portatune::config::Config> = space.enumerate(&w).collect();
    let mut par = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
    let batch = par.evaluate_batch(&cfgs, 1.0);
    let mut one_by_one = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
    for (cfg, from_batch) in cfgs.iter().zip(&batch) {
        let single = one_by_one.evaluate(cfg);
        match (from_batch, single) {
            (Ok(a), Ok(b)) => assert_eq!(a.to_bits(), b.to_bits(), "{cfg}"),
            (Err(_), Err(_)) => {}
            _ => panic!("validity mismatch for {cfg}"),
        }
    }
}

#[test]
fn tuning_cache_roundtrip_under_fingerprint_keys() {
    // tune_cached keys entries by the space-definition fingerprint; a
    // restart (fresh TuningCache from the same file, fresh space
    // instance) must hit, and the hit must reproduce the tuned best.
    let w = Workload::llama3_attention(8, 1024);
    let dir = TempDir::new("equiv-cache").unwrap();
    let path = dir.join("tune_cache.json");
    let first;
    {
        let mut cache = TuningCache::open(&path).unwrap();
        let space = spaces::attention_sim_space();
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        first = autotuner::tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Exhaustive, 0)
            .unwrap();
        assert!(!first.from_cache);
        cache.save().unwrap();
    }
    {
        let mut cache = TuningCache::open(&path).unwrap();
        assert_eq!(cache.len(), 1);
        // A fresh space instance fingerprints identically.
        let space = spaces::attention_sim_space();
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        let second =
            autotuner::tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Exhaustive, 0)
                .unwrap();
        assert!(second.from_cache, "restart must hit the fingerprint key");
        assert_eq!(second.best, first.best);
        assert_eq!(second.evaluated, 0);
        assert_eq!(eval.calls, 0, "cache hit performs zero evaluations");
    }
}
