//! Parallel/sequential equivalence contract for the batched autotuner.
//!
//! The tentpole guarantee of the parallel evaluation engine: every
//! parallel path — per-batch scoped threads, the persistent worker
//! pool, and the sharded multi-device fleet — must produce, for every
//! strategy and seed, exactly the outcome the sequential evaluator
//! produces: same best config, same invalid count, same evaluation log
//! (fingerprints, latencies AND fidelities, bitwise).  Results are
//! merged in submission order, so any divergence here is a real bug,
//! not scheduling noise.
//!
//! The fleet ("measure everywhere") mode extends the contract across
//! platforms: tuning a heterogeneous fleet must give each platform
//! exactly the outcome of tuning that platform alone with a sequential
//! evaluator — however many replicas the fleet has and however its
//! batches were sharded.

use portatune::autotuner::{
    self, Evaluator, MultiDeviceEvaluator, SimEvaluator, Strategy, TuneOutcome,
};
use portatune::cache::TuningCache;
use portatune::config::spaces;
use portatune::kernels::baselines::{HAND_TUNED, TRITON_NVIDIA};
use portatune::platform::SimGpu;
use portatune::util::tmp::TempDir;
use portatune::workload::Workload;

/// Which evaluation engine a run goes through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Sequential,
    ScopedThreads,
    Pool,
    MultiDevice,
}

fn run(mode: Mode, strat: &Strategy, seed: u64) -> TuneOutcome {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let base = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let mut eval: Box<dyn Evaluator> = match mode {
        Mode::Sequential => Box::new(base.sequential()),
        Mode::ScopedThreads => Box::new(base.scoped_threads()),
        Mode::Pool => Box::new(base),
        Mode::MultiDevice => Box::new(MultiDeviceEvaluator::replicate(&base, 3)),
    };
    autotuner::tune(&space, &w, eval.as_mut(), strat, seed).expect("space is non-empty")
}

fn all_strategies() -> Vec<Strategy> {
    vec![
        Strategy::Exhaustive,
        Strategy::Random { budget: 120 },
        Strategy::HillClimb { restarts: 3, budget: 200 },
        Strategy::Anneal { budget: 150, t0: 2.0, alpha: 0.95 },
        Strategy::SuccessiveHalving { initial: 32, eta: 2 },
    ]
}

/// Full-outcome equality: best config + latency bits, counters, and the
/// entire evaluation log entry for entry (fingerprint, latency bits,
/// and the fidelity each measurement was taken at).
fn assert_same_outcome(seq: &TuneOutcome, other: &TuneOutcome, label: &str) {
    assert_eq!(seq.best, other.best, "{label}: best config differs");
    assert_eq!(
        seq.best_latency_us.to_bits(),
        other.best_latency_us.to_bits(),
        "{label}: best latency differs"
    );
    assert_eq!(seq.invalid, other.invalid, "{label}: invalid count differs");
    assert_eq!(seq.evaluated, other.evaluated, "{label}: evaluated differs");
    assert_eq!(seq.history.len(), other.history.len(), "{label}: history length differs");
    for (i, (s, p)) in seq.history.iter().zip(&other.history).enumerate() {
        assert_eq!(s.fingerprint, p.fingerprint, "{label}: eval {i} config differs");
        assert_eq!(
            s.latency_us.map(f64::to_bits),
            p.latency_us.map(f64::to_bits),
            "{label}: eval {i} latency differs"
        );
        assert_eq!(
            s.fidelity.to_bits(),
            p.fidelity.to_bits(),
            "{label}: eval {i} fidelity differs"
        );
    }
}

#[test]
fn same_seed_same_outcome_for_every_strategy_and_engine() {
    for strat in all_strategies() {
        for seed in [0u64, 7, 42] {
            let seq = run(Mode::Sequential, &strat, seed);
            for mode in [Mode::ScopedThreads, Mode::Pool, Mode::MultiDevice] {
                let par = run(mode, &strat, seed);
                assert_same_outcome(&seq, &par, &format!("{strat:?} seed {seed} {mode:?}"));
            }
        }
    }
}

#[test]
fn pool_reuse_across_batches_matches_scoped_threads() {
    // One pooled evaluator reused across several batches must keep
    // producing exactly what a fresh scoped-thread evaluation produces:
    // the persistent pool carries no state between scopes.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let cfgs: Vec<portatune::config::Config> = space.enumerate(&w).collect();
    let mut pooled = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    for round in 0..3 {
        let mut scoped = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA).scoped_threads();
        let a = pooled.evaluate_batch(&cfgs, 1.0);
        let b = scoped.evaluate_batch(&cfgs, 1.0);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            match (x, y) {
                (Ok(p), Ok(q)) => {
                    assert_eq!(p.to_bits(), q.to_bits(), "round {round} cfg {i} differs")
                }
                (Err(_), Err(_)) => {}
                _ => panic!("round {round} cfg {i}: validity differs"),
            }
        }
    }
    assert_eq!(pooled.calls, 3 * cfgs.len());
}

#[test]
fn multi_device_fleet_spreads_work_without_changing_results() {
    // Equivalence is covered per-strategy above; this pins the sharding
    // itself: every device of the fleet participates in a large tune,
    // and the per-device counters account for every evaluation.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let base = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let mut fleet = MultiDeviceEvaluator::replicate(&base, 4);
    let out = autotuner::tune(&space, &w, &mut fleet, &Strategy::Exhaustive, 0).unwrap();
    // `evaluated` counts valid + invalid submissions, exactly what the
    // per-device counters see.
    let counted: usize = fleet.utilization().iter().map(|u| u.evaluated).sum();
    assert_eq!(counted, out.evaluated, "counters must cover the whole run");
    assert_eq!(counted, out.history.len());
    for (i, u) in fleet.utilization().iter().enumerate() {
        assert!(u.evaluated > 0, "device {i} never saw work");
        assert!(u.shards > 0, "device {i} processed no shards");
    }
    assert!(fleet.wall_us() > 0.0);
}

/// A heterogeneous fleet for the measure-everywhere tests: two a100
/// replicas + one mi250, each with its vendor's codegen model.
fn het_fleet(w: Workload) -> MultiDeviceEvaluator {
    let a100 = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let mi250 = SimEvaluator::new(SimGpu::mi250(), w, portatune::kernels::baselines::TRITON_AMD);
    MultiDeviceEvaluator::new(vec![a100.clone(), mi250, a100])
}

/// Solo tuning of one fleet platform with a freshly built *sequential*
/// evaluator — ground truth constructed without any fleet machinery, so
/// the comparison cannot be circular.
fn solo_outcome(platform: &str, strat: &Strategy, seed: u64) -> TuneOutcome {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let a100 = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA).sequential();
    let mi250 = SimEvaluator::new(SimGpu::mi250(), w, portatune::kernels::baselines::TRITON_AMD)
        .sequential();
    let mut eval = if a100.name() == platform {
        a100
    } else {
        assert_eq!(mi250.name(), platform, "unknown fleet platform {platform}");
        mi250
    };
    autotuner::tune(&space, &w, &mut eval, strat, seed).expect("space is non-empty")
}

#[test]
fn fleet_measure_everywhere_is_bit_identical_to_solo_tuning_per_platform() {
    // The tentpole guarantee of fleet tuning: for every strategy and
    // seed, each platform's outcome — winner, latency bits, counters,
    // and the full (fingerprint, latency, fidelity) log — is exactly
    // what tuning that platform alone with a sequential evaluator
    // produces.  Exhaustive/random share one measure-everywhere
    // trajectory; the adaptive strategies run per platform; neither may
    // be observable in the result.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    for strat in all_strategies() {
        for seed in [0u64, 7] {
            let mut fleet = het_fleet(w);
            let out = autotuner::tune_fleet(&space, &w, &mut fleet, &strat, seed)
                .expect("fleet tune must succeed");
            assert_eq!(out.outcomes.len(), 2, "two distinct platforms");
            for (platform, got) in &out.outcomes {
                let want = solo_outcome(platform, &strat, seed);
                assert_same_outcome(&want, got, &format!("{strat:?} seed {seed} {platform}"));
            }
        }
    }
}

#[test]
fn fleet_replicas_shard_platform_copies_without_changing_results() {
    // 1 vs 2 a100 replicas: the a100 copy of each batch is sharded
    // differently, but the a100 outcome must not change.
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let a100 = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
    let mi250 = SimEvaluator::new(SimGpu::mi250(), w, portatune::kernels::baselines::TRITON_AMD);
    let mut small = MultiDeviceEvaluator::new(vec![a100.clone(), mi250.clone()]);
    let mut wide = MultiDeviceEvaluator::new(vec![a100.clone(), mi250, a100]);
    let a = autotuner::tune_fleet(&space, &w, &mut small, &Strategy::Exhaustive, 0).unwrap();
    let b = autotuner::tune_fleet(&space, &w, &mut wide, &Strategy::Exhaustive, 0).unwrap();
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for ((p1, o1), (p2, o2)) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(p1, p2);
        assert_same_outcome(o1, o2, &format!("replica widths for {p1}"));
    }
    assert_eq!(a.distinct_winners, b.distinct_winners);
    match (&a.portable, &b.portable) {
        (Some(x), Some(y)) => {
            assert_eq!(x.config, y.config, "portable pick must not depend on replica count");
            assert_eq!(x.worst_slowdown.to_bits(), y.worst_slowdown.to_bits());
        }
        (None, None) => {}
        _ => panic!("portable-best presence differs with replica count"),
    }
}

#[test]
fn guided_tuning_parallel_prior_matches_sequential() {
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let outcome = |parallel: bool| {
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        if !parallel {
            prior = prior.sequential();
            target = target.sequential();
        }
        autotuner::tune_guided(&space, &w, &mut prior, &mut target, 25).unwrap()
    };
    let seq = outcome(false);
    let par = outcome(true);
    assert_eq!(seq.best, par.best);
    assert_eq!(seq.best_latency_us.to_bits(), par.best_latency_us.to_bits());
    assert_eq!(seq.evaluated, par.evaluated);
    assert_eq!(seq.invalid, par.invalid);
}

#[test]
fn raw_batch_api_is_order_preserving() {
    // evaluate_batch's contract: out[i] belongs to cfgs[i].
    let w = Workload::llama3_attention(8, 1024);
    let space = spaces::attention_sim_space();
    let cfgs: Vec<portatune::config::Config> = space.enumerate(&w).collect();
    let mut par = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
    let batch = par.evaluate_batch(&cfgs, 1.0);
    let mut one_by_one = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
    for (cfg, from_batch) in cfgs.iter().zip(&batch) {
        let single = one_by_one.evaluate(cfg);
        match (from_batch, single) {
            (Ok(a), Ok(b)) => assert_eq!(a.to_bits(), b.to_bits(), "{cfg}"),
            (Err(_), Err(_)) => {}
            _ => panic!("validity mismatch for {cfg}"),
        }
    }
}

#[test]
fn tuning_cache_roundtrip_under_fingerprint_keys() {
    // tune_cached keys entries by the space-definition fingerprint; a
    // restart (fresh TuningCache from the same file, fresh space
    // instance) must hit, and the hit must reproduce the tuned best.
    let w = Workload::llama3_attention(8, 1024);
    let dir = TempDir::new("equiv-cache").unwrap();
    let path = dir.join("tune_cache.json");
    let first;
    {
        let mut cache = TuningCache::open(&path).unwrap();
        let space = spaces::attention_sim_space();
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        first = autotuner::tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Exhaustive, 0)
            .unwrap();
        assert!(!first.from_cache);
        cache.save().unwrap();
    }
    {
        let mut cache = TuningCache::open(&path).unwrap();
        assert_eq!(cache.len(), 1);
        // A fresh space instance fingerprints identically.
        let space = spaces::attention_sim_space();
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        let second =
            autotuner::tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Exhaustive, 0)
                .unwrap();
        assert!(second.from_cache, "restart must hit the fingerprint key");
        assert_eq!(second.best, first.best);
        assert_eq!(second.evaluated, 0);
        assert_eq!(eval.calls, 0, "cache hit performs zero evaluations");
    }
}
