//! `TuningSession` — the single public entry point of the autotuner.
//!
//! The engine grew five free functions (`tune`, `tune_guided`,
//! `tune_cached`, `tune_fleet`, `tune_fleet_cached`) whose signatures
//! drifted apart with every feature: caching, guided priors, fleets and
//! budgets are *orthogonal options* of one tuning loop, not separate
//! loops — exactly the paper's point that tuning scope is configuration,
//! not code.  [`TuningSession`] makes them compose:
//!
//! ```
//! use portatune::autotuner::{SessionOutcome, SimEvaluator, Strategy, TuningSession};
//! use portatune::config::spaces;
//! use portatune::kernels::baselines::HAND_TUNED;
//! use portatune::platform::SimGpu;
//! use portatune::workload::Workload;
//!
//! let w = Workload::llama3_attention(1, 512);
//! let space = spaces::attention_sim_space();
//! let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
//! let out = TuningSession::new(&space, &w)
//!     .strategy(Strategy::Random { budget: 32 })
//!     .seed(7)
//!     .evaluator(&mut eval)
//!     .run()
//!     .and_then(SessionOutcome::into_solo)
//!     .expect("space is non-empty");
//! assert!(out.best_latency_us > 0.0);
//! ```
//!
//! Options compose freely: `.cache(&mut c)` makes any run persistent
//! (including guided and fleet runs), `.guided(prior, k)` prunes with a
//! model prior (solo targets only — combining it with `.fleet()`
//! panics rather than silently running an unguided fleet pass),
//! `.surrogate(k)` does the same with a **self-generated** prior (a
//! [`crate::surrogate::CostModel`] fit on a cheap seed sample),
//! `.fleet(&mut f)` tunes every distinct platform at once,
//! `.budget(Budget::Evals(n))` caps any of them, and `.observe(&mut o)`
//! streams progress from all of them.  The legacy free functions spent
//! one release as thin `#[deprecated]` wrappers and have since been
//! removed; `tests/parallel_equiv.rs` now pins the builder's own
//! spellings (defaults, option order, cached-vs-plain) against each
//! other instead.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::evaluators::{MultiDeviceEvaluator, SimEvaluator};
use super::search::{self, Observer, Recorder, Strategy};
use super::{Evaluator, FleetOutcome, PortableBest, TuneOutcome};
use crate::cache::{entry_now, CacheEntry, TuningCache};
use crate::config::{Config, ConfigSpace};
use crate::workload::Workload;

/// A session-level stopping rule, orthogonal to the per-strategy knobs
/// (`Random { budget }` etc.): the budget caps *any* strategy, including
/// exhaustive enumeration, which the flat `tune*` signatures could never
/// express.
///
/// Enforcement lives in [`search::Recorder`]: an exhausted recorder
/// refuses further evaluations and truncates in-flight batches, so a
/// capped run's history is an exact prefix of the uncapped run's —
/// which makes [`Budget::Evals`] fully deterministic per seed (pinned
/// by `tests/parallel_equiv.rs`).  Wall-clock budgets are checked
/// between evaluations on the sequential strategies and between
/// *batches* on the batching ones — a deadline expiring mid-batch
/// still completes the in-flight batch (up to `search::EVAL_BATCH`
/// configurations), since a dispatched batch cannot be recalled from
/// the worker pool.
///
/// On fleet targets, [`Budget::Evals`] caps evaluations **per
/// platform** (each platform's recorder counts its own log, which for
/// the shared-trajectory strategies is the same sequence), while the
/// wall-clock budgets bound the whole fleet run; if a wall budget
/// expires partway through the adaptive per-platform loop, the session
/// returns the platforms completed so far (with no portability report)
/// instead of discarding them.
///
/// Possibly budget-truncated results are **never persisted** to an
/// attached cache: under the ordinary `workload × platform × space`
/// key a capped winner would masquerade as a full tuning result on the
/// next, uncapped run.  They are still returned to the caller.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Budget {
    /// Stop after at most this many evaluations (valid + invalid).
    Evals(usize),
    /// Stop once the session has run for this many wall-clock seconds.
    WallSecs(f64),
    /// Stop once this instant has passed.
    Deadline(Instant),
}

/// What a [`TuningSession`] produced: solo targets yield a
/// [`TuneOutcome`], fleet targets a [`FleetOutcome`].
#[derive(Debug, Clone)]
pub enum SessionOutcome {
    /// Outcome of a single-platform (or replicated single-platform) run.
    Solo(TuneOutcome),
    /// Outcome of a heterogeneous-fleet run.
    Fleet(FleetOutcome),
}

impl SessionOutcome {
    /// The solo outcome, if this was a solo run.
    pub fn into_solo(self) -> Option<TuneOutcome> {
        match self {
            SessionOutcome::Solo(o) => Some(o),
            SessionOutcome::Fleet(_) => None,
        }
    }

    /// The fleet outcome, if this was a fleet run.
    pub fn into_fleet(self) -> Option<FleetOutcome> {
        match self {
            SessionOutcome::Fleet(o) => Some(o),
            SessionOutcome::Solo(_) => None,
        }
    }

    /// Borrowing accessor for the solo outcome.
    pub fn as_solo(&self) -> Option<&TuneOutcome> {
        match self {
            SessionOutcome::Solo(o) => Some(o),
            SessionOutcome::Fleet(_) => None,
        }
    }

    /// Borrowing accessor for the fleet outcome.
    pub fn as_fleet(&self) -> Option<&FleetOutcome> {
        match self {
            SessionOutcome::Fleet(o) => Some(o),
            SessionOutcome::Solo(_) => None,
        }
    }
}

/// What the session tunes against.
enum Target<'a> {
    /// No target configured yet ([`TuningSession::run`] panics).
    Unset,
    /// A caller-owned evaluator.
    Solo(&'a mut (dyn Evaluator + 'a)),
    /// A session-owned evaluator (the `.devices(n)` sugar).
    Owned(Box<dyn Evaluator + 'a>),
    /// A heterogeneous fleet: measure everywhere, per-platform argmin.
    Fleet(&'a mut MultiDeviceEvaluator),
}

/// Builder for one tuning run — see the [module docs](self) for the
/// full option matrix and an example.
///
/// A session borrows everything it tunes with (space, workload,
/// evaluators, cache, observers) for the lifetime `'a` and is consumed
/// by [`TuningSession::run`].
pub struct TuningSession<'a> {
    space: &'a ConfigSpace,
    workload: &'a Workload,
    strategy: Strategy,
    seed: u64,
    cache: Option<&'a mut TuningCache>,
    prior: Option<(&'a mut (dyn Evaluator + 'a), usize)>,
    surrogate_k: Option<usize>,
    budget: Option<Budget>,
    observers: Vec<&'a mut dyn Observer>,
    target: Target<'a>,
}

impl<'a> TuningSession<'a> {
    /// Start configuring a tuning run over `space` for `workload`.
    /// Defaults: [`Strategy::Exhaustive`], seed 0, no cache, no prior,
    /// no budget, no observers.
    pub fn new(space: &'a ConfigSpace, workload: &'a Workload) -> Self {
        TuningSession {
            space,
            workload,
            strategy: Strategy::Exhaustive,
            seed: 0,
            cache: None,
            prior: None,
            surrogate_k: None,
            budget: None,
            observers: Vec::new(),
            target: Target::Unset,
        }
    }

    /// Select the search strategy (ignored by guided runs, which rank
    /// with the prior instead of searching).
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Seed for the stochastic strategies (deterministic per seed).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Serve from / persist into this cache (paper Q4.3).  Solo hits are
    /// keyed by `workload × platform × space fingerprint`; fleet runs
    /// persist every platform's winner under that platform's own key and
    /// reuse partial hits where the strategy allows (see
    /// [`TuningSession::fleet`]).
    ///
    /// The key is **strategy-agnostic** (as it always has been): a
    /// winner persisted by a cheap session — `Random { budget: 30 }`,
    /// successive halving, a guided top-k run — is served to any later
    /// session with the same workload/platform/space, exhaustive
    /// included.  Budget-truncated results are the one exception: they
    /// are never persisted (see [`Budget`]).  Callers who want a
    /// higher-quality entry than the cache holds should invalidate it
    /// first ([`TuningCache::invalidate_platform`] or `cache clear`).
    pub fn cache(mut self, cache: &'a mut TuningCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Model-guided (transfer) tuning: rank the whole space with the
    /// cheap `prior` evaluator, then measure only the `top_k` most
    /// promising configurations on the target evaluator.  The prior's
    /// ranking pass is not recorded and does not count against a
    /// [`Budget::Evals`] cap; the wall-clock budgets bound the whole
    /// session, ranking included (an already-expired deadline skips the
    /// ranking pass entirely).  Guided tuning requires a **solo**
    /// target ([`TuningSession::evaluator`] / [`TuningSession::devices`]);
    /// combining it with [`TuningSession::fleet`] panics in `run()`.
    pub fn guided(mut self, prior: &'a mut (dyn Evaluator + 'a), top_k: usize) -> Self {
        self.prior = Some((prior, top_k));
        self
    }

    /// Surrogate-assisted tuning — [`TuningSession::guided`] with a
    /// **self-generated** prior (ROADMAP item 3).  The session measures
    /// a small deterministic seed sample
    /// ([`crate::surrogate::SEED_SAMPLE`] equally spaced configs) at
    /// full fidelity, fits a [`crate::surrogate::CostModel`] on it by
    /// deterministic ridge regression, scores the rest of the space in
    /// nanoseconds per config, and measures only the model's top `k`
    /// predictions.  Seed measurements count toward the history, the
    /// running best and any [`Budget`] exactly like ordinary
    /// evaluations.
    ///
    /// Degradation is graceful and pinned by tests: with `k ≥` the
    /// valid-space size the run delegates to the exhaustive engine and
    /// is bit-identical to [`Strategy::Exhaustive`]; when the fit
    /// declines (fewer usable seed measurements than features, or a
    /// singular system) every remaining config is measured unguided —
    /// never a panic, never a silently wrong prune.  Like `.guided()`,
    /// this requires a solo target and is mutually exclusive with an
    /// explicit prior (combining them panics in `run()`).
    pub fn surrogate(mut self, top_k: usize) -> Self {
        self.surrogate_k = Some(top_k);
        self
    }

    /// Cap the session with a stopping rule the strategy itself cannot
    /// express — see [`Budget`].
    pub fn budget(mut self, budget: Budget) -> Self {
        self.budget = Some(budget);
        self
    }

    /// Stream progress events to `observer` (may be called repeatedly to
    /// attach several).  Observers never change the outcome.
    pub fn observe(mut self, observer: &'a mut dyn Observer) -> Self {
        self.observers.push(observer);
        self
    }

    /// Tune against one evaluator — the plain single-platform target.
    pub fn evaluator(mut self, eval: &'a mut (dyn Evaluator + 'a)) -> Self {
        self.target = Target::Solo(eval);
        self
    }

    /// Tune against `n` sharded replicas of `base` (the CLI's
    /// `--devices N`): one platform, evaluation batches spread over a
    /// simulated fleet, results bit-identical to a single device.  The
    /// session owns the replicated evaluator; callers who want its
    /// utilization counters afterwards should build a
    /// [`MultiDeviceEvaluator`] themselves and pass it to
    /// [`TuningSession::evaluator`].
    pub fn devices(mut self, base: &SimEvaluator, n: usize) -> Self {
        self.target = Target::Owned(Box::new(MultiDeviceEvaluator::replicate(base, n)));
        self
    }

    /// Tune every distinct platform of a heterogeneous fleet at once
    /// (measure-everywhere, per-platform argmin + portability report).
    ///
    /// With [`TuningSession::cache`]: every platform's winner persists
    /// under its own key; a run is served entirely from cache only when
    /// *every* platform hits.  On a **partial** hit the adaptive
    /// strategies (hill climb, annealing, successive halving — their
    /// per-platform searches are independent) reuse the cached platforms
    /// and re-tune only the missing ones; the shared-trajectory
    /// strategies (exhaustive, random) re-tune the whole fleet, because
    /// their one measure-everywhere pass cannot skip a platform without
    /// changing what the other platforms measure.
    pub fn fleet(mut self, fleet: &'a mut MultiDeviceEvaluator) -> Self {
        self.target = Target::Fleet(fleet);
        self
    }

    /// Execute the session.
    ///
    /// Returns `None` when no valid configuration was found (for fleet
    /// targets: when any platform found none).  Cache hits return with
    /// `from_cache = true` and zero evaluations.
    ///
    /// # Panics
    ///
    /// Panics if no target was configured — call
    /// [`TuningSession::evaluator`], [`TuningSession::devices`] or
    /// [`TuningSession::fleet`] first — or if [`TuningSession::guided`]
    /// was combined with a fleet target (guided tuning needs a solo
    /// target; silently ignoring the prior would run a far more
    /// expensive unguided fleet pass than the caller asked for).
    pub fn run(mut self) -> Option<SessionOutcome> {
        assert!(
            self.prior.is_none() || self.surrogate_k.is_none(),
            "TuningSession: .guided() and .surrogate() are mutually exclusive \
             (the surrogate mode generates its own prior)"
        );
        match std::mem::replace(&mut self.target, Target::Unset) {
            Target::Solo(eval) => self.run_solo(eval).map(SessionOutcome::Solo),
            Target::Owned(mut owned) => self.run_solo(owned.as_mut()).map(SessionOutcome::Solo),
            Target::Fleet(fleet) => {
                assert!(
                    self.prior.is_none(),
                    "TuningSession: .guided() requires a solo target \
                     (.evaluator() or .devices()); guided fleet tuning is not supported"
                );
                assert!(
                    self.surrogate_k.is_none(),
                    "TuningSession: .surrogate() requires a solo target \
                     (.evaluator() or .devices()); surrogate fleet tuning is not supported"
                );
                self.run_fleet(fleet).map(SessionOutcome::Fleet)
            }
            Target::Unset => panic!(
                "TuningSession::run() without a target: call .evaluator(), .devices() or .fleet() first"
            ),
        }
    }

    // ------------------------------------------------------------------
    // Solo path (plain / guided / cached — freely combined).
    // ------------------------------------------------------------------

    fn run_solo<'e>(mut self, eval: &mut (dyn Evaluator + 'e)) -> Option<TuneOutcome> {
        let t0 = Instant::now();
        let budget = self.budget;
        let Some(cache) = self.cache.take() else {
            return self.execute_solo(eval);
        };
        let platform = eval.name();
        let space_fp = self.space.fingerprint_key();
        // The space component of the cache key is the stable FNV-1a
        // digest of the space definition; constraint *bodies* are
        // closures and cannot be hashed, so a hit is re-validated with
        // `contains` — a cached winner the current space rejects falls
        // through to a fresh tune instead of being served.
        if let Some(hit) = cache.get(self.workload, &platform, &space_fp) {
            if let Some(best) = hit.config() {
                if self.space.contains(&best, self.workload) {
                    return Some(cached_outcome(hit, best));
                }
            }
            // Unparseable or no-longer-valid entry: re-tune, overwrite.
        }
        let workload = self.workload;
        let outcome = self.execute_solo(eval)?;
        // A budget-truncated result is reported but never persisted:
        // under the ordinary cache key it would masquerade as a full
        // tuning run on the next (uncapped) session.
        if possibly_capped(&budget, outcome.evaluated, t0) {
            return Some(outcome);
        }
        cache.put(
            workload,
            entry_now(
                &outcome.best,
                outcome.best_latency_us,
                outcome.evaluated,
                outcome.invalid,
                &platform,
                &space_fp,
                outcome.wall_seconds,
            ),
        );
        Some(outcome)
    }

    fn execute_solo<'e>(self, eval: &mut (dyn Evaluator + 'e)) -> Option<TuneOutcome> {
        let TuningSession { space, workload, strategy, seed, prior, surrogate_k, budget, observers, .. } =
            self;
        match (prior, surrogate_k) {
            (Some((prior, top_k)), _) => {
                guided_impl(space, workload, prior, top_k, eval, &budget, observers)
            }
            (None, Some(k)) => surrogate_impl(space, workload, k, eval, seed, &budget, observers),
            (None, None) => tune_impl(space, workload, eval, &strategy, seed, &budget, observers),
        }
    }

    // ------------------------------------------------------------------
    // Fleet path (plain / cached, with partial per-platform reuse).
    // ------------------------------------------------------------------

    fn run_fleet(mut self, fleet: &mut MultiDeviceEvaluator) -> Option<FleetOutcome> {
        let Some(cache) = self.cache.take() else {
            let TuningSession { space, workload, strategy, seed, budget, observers, .. } = self;
            return fleet_impl(
                space,
                workload,
                fleet,
                &strategy,
                seed,
                &budget,
                observers,
                HashMap::new(),
            );
        };
        let space_fp = self.space.fingerprint_key();
        let platforms = fleet.platforms().to_vec();
        let mut hits: HashMap<String, TuneOutcome> = HashMap::new();
        for platform in &platforms {
            let hit = cache.get(self.workload, platform, &space_fp).and_then(|h| {
                let best = h.config()?;
                self.space.contains(&best, self.workload).then(|| cached_outcome(h, best))
            });
            if let Some(o) = hit {
                hits.insert(platform.clone(), o);
            }
        }
        if !platforms.is_empty() && hits.len() == platforms.len() {
            // Full hit: zero evaluations.  Cached entries store winners
            // only (no history), so there is nothing to build a
            // portability report from.
            let outcomes: Vec<(String, TuneOutcome)> = platforms
                .iter()
                .map(|p| (p.clone(), hits.remove(p).expect("hit for every platform")))
                .collect();
            return Some(FleetOutcome {
                distinct_winners: distinct_winner_count(&outcomes),
                outcomes,
                portable: None,
                wall_seconds: 0.0,
                from_cache: true,
            });
        }
        // Partial (or no) hit.  Adaptive strategies tune per platform
        // independently, so cached platforms can be served as-is and
        // only the missing ones re-tuned; the shared-trajectory
        // strategies re-run the whole measure-everywhere pass.
        let reuse = if self.strategy.shared_trajectory() { HashMap::new() } else { hits };
        let workload = self.workload;
        let t0 = Instant::now();
        let TuningSession { space, strategy, seed, budget, observers, .. } = self;
        let outcome =
            fleet_impl(space, workload, fleet, &strategy, seed, &budget, observers, reuse)?;
        for (platform, o) in &outcome.outcomes {
            if o.from_cache {
                continue; // reused entries are already persisted
            }
            // Same rule as the solo path: possibly budget-truncated
            // winners are reported but never persisted (conservative:
            // an expired wall budget skips every platform of the
            // session, even ones that finished early).
            if possibly_capped(&budget, o.evaluated, t0) {
                continue;
            }
            cache.put(
                workload,
                entry_now(
                    &o.best,
                    o.best_latency_us,
                    o.evaluated,
                    o.invalid,
                    platform,
                    &space_fp,
                    o.wall_seconds,
                ),
            );
        }
        Some(outcome)
    }
}

/// Apply a session budget to a recorder.  `t0` anchors
/// [`Budget::WallSecs`] at the start of the whole session, so on fleet
/// targets the wall-clock budgets bound the fleet run, not each
/// platform.
fn apply_budget(rec: &mut Recorder<'_>, budget: &Option<Budget>, t0: Instant) {
    match budget {
        Some(Budget::Evals(n)) => rec.limit_evals(*n),
        Some(Budget::WallSecs(s)) => {
            // NaN, infinite or overflowing seconds mean "effectively
            // unlimited": fall through to no deadline instead of
            // panicking in Duration::from_secs_f64 / Instant addition
            // (NaN needs its own check — `NAN.max(0.0)` is 0.0, which
            // would stop the session immediately).
            if !s.is_nan() {
                if let Some(deadline) = Duration::try_from_secs_f64(s.max(0.0))
                    .ok()
                    .and_then(|d| t0.checked_add(d))
                {
                    rec.limit_deadline(deadline);
                }
            }
        }
        Some(Budget::Deadline(d)) => rec.limit_deadline(*d),
        None => {}
    }
}

/// Conservatively true when a finished run may have been truncated by
/// the session budget.  Used to gate cache persistence: a capped
/// winner stored under the ordinary `workload × platform × space` key
/// would masquerade as a full tuning result on the next (uncapped)
/// run, so possibly-truncated outcomes are reported but never
/// persisted.  `evaluated >= n` over-approximates for [`Budget::Evals`]
/// (a search that finished naturally at exactly the cap is also
/// skipped) — losing a cache write is harmless, serving a truncated
/// winner as the optimum is not.
fn possibly_capped(budget: &Option<Budget>, evaluated: usize, t0: Instant) -> bool {
    match budget {
        None => false,
        Some(Budget::Evals(n)) => evaluated >= *n,
        Some(Budget::WallSecs(s)) => t0.elapsed().as_secs_f64() >= *s,
        Some(Budget::Deadline(d)) => Instant::now() >= *d,
    }
}

/// A validated cache hit as a zero-cost outcome (`best` is the entry's
/// config, already re-validated against the live space by the caller).
fn cached_outcome(hit: &CacheEntry, best: Config) -> TuneOutcome {
    TuneOutcome {
        best,
        best_latency_us: hit.latency_us,
        evaluated: 0,
        invalid: hit.invalid,
        history: Vec::new(),
        wall_seconds: 0.0,
        from_cache: true,
    }
}

/// Build a [`TuneOutcome`] from a finished recorder.
fn finish(rec: Recorder<'_>, t0: Instant) -> Option<TuneOutcome> {
    let (best, best_latency_us) = rec.best()?;
    Some(TuneOutcome {
        best,
        best_latency_us,
        evaluated: rec.len(),
        invalid: rec.invalid,
        history: rec.evals,
        wall_seconds: t0.elapsed().as_secs_f64(),
        from_cache: false,
    })
}

/// The plain search engine: run `strategy` over `space` through one
/// recorder carrying the session's budget and observers.
fn tune_impl<'o, 'e>(
    space: &ConfigSpace,
    workload: &Workload,
    eval: &mut (dyn Evaluator + 'e),
    strategy: &Strategy,
    seed: u64,
    budget: &Option<Budget>,
    observers: Vec<&'o mut dyn Observer>,
) -> Option<TuneOutcome> {
    let t0 = Instant::now();
    let mut rec = Recorder::default();
    rec.set_observers(observers);
    apply_budget(&mut rec, budget, t0);
    strategy.run(space, workload, eval, seed, &mut rec);
    finish(rec, t0)
}

/// Model-guided (transfer) tuning: rank the whole space with a cheap
/// *prior* evaluator (e.g. an analytical platform model), then measure
/// only the `top_k` most promising configurations on the expensive
/// *target* evaluator (e.g. real PJRT execution).
///
/// This is the practical middle road between the paper's 24 h exhaustive
/// budget and heuristic-only dispatch: the prior prunes the space by an
/// order of magnitude, the target keeps the decision empirical.
fn guided_impl<'o, 'p, 'e>(
    space: &ConfigSpace,
    workload: &Workload,
    prior: &mut (dyn Evaluator + 'p),
    top_k: usize,
    target: &mut (dyn Evaluator + 'e),
    budget: &Option<Budget>,
    observers: Vec<&'o mut dyn Observer>,
) -> Option<TuneOutcome> {
    let t0 = Instant::now();
    // The measurement recorder is built up front so wall-clock budgets
    // cover the whole session: an already-expired deadline skips the
    // ranking pass instead of paying for a full prior sweep whose
    // results could never be measured.  (An Evals cap does not apply
    // to the ranking pass — the prior is not recorded.)
    let mut rec = Recorder::default();
    rec.set_observers(observers);
    apply_budget(&mut rec, budget, t0);
    if rec.out_of_budget() {
        return finish(rec, t0);
    }
    // Rank by prior (invalid-on-prior configs go last, not dropped: the
    // prior is a model, not ground truth).  The ranking pass streams
    // through the batch API so a parallel prior uses every core, and a
    // wall-clock deadline is honored between chunks (an Evals cap never
    // fires here: the ranking pass is not recorded).
    let configs: Vec<Config> = space.enumerate(workload).collect();
    let mut priors: Vec<Option<f64>> = Vec::with_capacity(configs.len());
    for chunk in configs.chunks(search::EVAL_BATCH) {
        if rec.out_of_budget() {
            return finish(rec, t0);
        }
        priors.extend(prior.evaluate_batch(chunk, 1.0).into_iter().map(|r| r.ok()));
    }
    let mut ranked: Vec<(Config, Option<f64>)> = configs.into_iter().zip(priors).collect();

    // Only top_k configs are ever measured, so an O(n) partial selection
    // replaces a full sort of the entire ranked space; only the k
    // survivors are sorted (for measurement order).  `rank_order` is the
    // shared total order (score, then fingerprint — see search.rs): ties
    // are pinned regardless of `select_nth_unstable_by`'s unspecified
    // ordering among equals, and the surrogate mode ranks with the very
    // same comparator.
    let k = top_k.max(1).min(ranked.len());
    if k < ranked.len() {
        ranked.select_nth_unstable_by(k - 1, search::rank_order);
        ranked.truncate(k);
    }
    ranked.sort_by(search::rank_order);

    // Measure the survivors through the recorder: same bookkeeping
    // (fingerprint history, invalid count, running best) as every
    // search strategy — budget and observers included.
    for (cfg, _) in ranked {
        if rec.out_of_budget() {
            break;
        }
        rec.eval(target, &cfg, 1.0);
    }
    finish(rec, t0)
}

/// Surrogate-assisted tuning: [`guided_impl`] with a self-generated
/// prior.  Measures a deterministic seed sample at full fidelity, fits
/// a [`crate::surrogate::CostModel`] on it, ranks the rest of the
/// space with the model and measures only the predicted top-k — see
/// [`TuningSession::surrogate`] for the degradation contract.
fn surrogate_impl<'o, 'e>(
    space: &ConfigSpace,
    workload: &Workload,
    top_k: usize,
    target: &mut (dyn Evaluator + 'e),
    seed: u64,
    budget: &Option<Budget>,
    observers: Vec<&'o mut dyn Observer>,
) -> Option<TuneOutcome> {
    use crate::surrogate::{CostModel, RIDGE_LAMBDA, SEED_SAMPLE};
    // Top-k of everything is everything: delegate to the exhaustive
    // engine so the run is bit-identical to `Strategy::Exhaustive`
    // (pinned by tests/parallel_equiv.rs) instead of re-implementing
    // its trajectory here.
    let n_valid = space.enumerate(workload).count();
    if top_k >= n_valid {
        return tune_impl(space, workload, target, &Strategy::Exhaustive, seed, budget, observers);
    }
    let t0 = Instant::now();
    let mut rec = Recorder::default();
    rec.set_observers(observers);
    apply_budget(&mut rec, budget, t0);
    if rec.out_of_budget() {
        return finish(rec, t0);
    }
    // 1. Train on a cheap seed sample: equally spaced through the valid
    //    enumeration (deterministic, no RNG), measured at full fidelity
    //    through the recorder so the samples count toward the history,
    //    the budget and the running best like any other measurement.
    let platform = target.name();
    let mut train: Vec<(Config, Workload, f64)> = Vec::new();
    let mut sampled: std::collections::HashSet<u64> = std::collections::HashSet::new();
    for cfg in space.equally_spaced(workload, SEED_SAMPLE.min(n_valid)) {
        if rec.out_of_budget() {
            break;
        }
        sampled.insert(cfg.fingerprint());
        if let Some(us) = rec.eval(target, &cfg, 1.0) {
            train.push((cfg, *workload, us));
        }
    }
    // 2. Fit.  A declined fit (fewer usable seed measurements than
    //    features, or a singular system) leaves `model` empty and the
    //    run falls back to unguided completion below: every remaining
    //    config is measured in enumeration order — slower, never wrong,
    //    never a panic.
    let model = CostModel::fit(&platform, &train, RIDGE_LAMBDA);
    // 3. Score the rest of the space with the model (nanoseconds per
    //    config, no hardware) and keep only the predicted top-k, ranked
    //    by the same total order as `.guided()` (score, then
    //    fingerprint).
    let mut rest: Vec<(Config, Option<f64>)> = space
        .enumerate(workload)
        .filter(|c| !sampled.contains(&c.fingerprint()))
        .map(|c| {
            let p = model.as_ref().map(|m| m.predict_us(&c, workload));
            (c, p)
        })
        .collect();
    if model.is_some() {
        let k = top_k.max(1).min(rest.len());
        if k < rest.len() {
            rest.select_nth_unstable_by(k - 1, search::rank_order);
            rest.truncate(k);
        }
        rest.sort_by(search::rank_order);
    }
    // 4. Spend hardware time only on the frontier.
    for (cfg, _) in rest {
        if rec.out_of_budget() {
            break;
        }
        rec.eval(target, &cfg, 1.0);
    }
    finish(rec, t0)
}

/// The fleet engine: tune the shared `space` for every distinct
/// platform of `fleet` at once — the "A Few Fit Most" regime.
///
/// Exhaustive and random share one measure-everywhere trajectory (their
/// evaluation order never depends on measured latencies); the adaptive
/// strategies run once per platform — their trajectories genuinely
/// diverge, which is exactly the per-platform argmin the regime asks
/// for.  Either way each platform's outcome is **bit-identical** to
/// tuning that platform alone with a sequential evaluator (pinned by
/// `tests/parallel_equiv.rs`).
///
/// `reuse` carries cached per-platform outcomes to serve instead of
/// re-tuning; it is consulted only on the adaptive path (callers pass
/// it empty for the shared-trajectory strategies, whose single shared
/// pass cannot skip a platform).  Returns `None` when any platform
/// found no valid configuration — except when a session budget expired
/// partway through the adaptive per-platform loop, in which case the
/// platforms completed so far are returned (portability report
/// omitted: it needs every platform).
#[allow(clippy::too_many_arguments)]
fn fleet_impl<'o>(
    space: &ConfigSpace,
    workload: &Workload,
    fleet: &mut MultiDeviceEvaluator,
    strategy: &Strategy,
    seed: u64,
    budget: &Option<Budget>,
    mut observers: Vec<&'o mut dyn Observer>,
    reuse: HashMap<String, TuneOutcome>,
) -> Option<FleetOutcome> {
    let t0 = Instant::now();
    // Owned copy: the fleet is mutably re-borrowed below (the shared
    // pass and the per-platform loop) while the names are still in use.
    let platforms = fleet.platforms().to_vec();
    if strategy.shared_trajectory() {
        debug_assert!(reuse.is_empty(), "shared trajectories cannot partially reuse");
        // Only the first recorder captures configs (every portable-best
        // candidate is by definition evaluated on every platform —
        // including platform 0 — so one fingerprint→Config map carries
        // the whole portability analysis).  Observers also attach to
        // the first recorder: the trajectory is shared, so platform 0's
        // event stream *is* the progress of the whole pass.
        let mut recs: Vec<Recorder<'_>> = platforms
            .iter()
            .enumerate()
            .map(|(i, _)| {
                if i == 0 {
                    Recorder::capturing()
                } else {
                    Recorder::default()
                }
            })
            .collect();
        for rec in &mut recs {
            apply_budget(rec, budget, t0);
        }
        if let (Some(first), Some(platform)) = (recs.first_mut(), platforms.first()) {
            first.set_observers(observers);
            first.platform(platform);
        }
        search::run_fleet_shared(space, workload, fleet, strategy, seed, &mut recs);
        let wall_seconds = t0.elapsed().as_secs_f64();
        // The platforms run concurrently inside the shared pass, so the
        // total is not P times anyone's cost: attribute an even share.
        let share = wall_seconds / platforms.len().max(1) as f64;
        let mut outcomes: Vec<(String, TuneOutcome)> = Vec::with_capacity(platforms.len());
        for (platform, rec) in platforms.iter().zip(&recs) {
            let (best, best_latency_us) = rec.best()?;
            outcomes.push((
                platform.clone(),
                TuneOutcome {
                    best,
                    best_latency_us,
                    evaluated: rec.len(),
                    invalid: rec.invalid,
                    history: rec.evals.clone(),
                    wall_seconds: share,
                    from_cache: false,
                },
            ));
        }
        let portable = portability(&outcomes, &recs);
        Some(FleetOutcome {
            distinct_winners: distinct_winner_count(&outcomes),
            outcomes,
            portable,
            wall_seconds,
            from_cache: false,
        })
    } else {
        // Adaptive strategies: independent per-platform searches, so a
        // cached outcome can be served verbatim and only the missing
        // platforms re-tuned (the partial-reuse path of
        // `TuningSession::cache` + `TuningSession::fleet`).
        let mut outcomes: Vec<(String, TuneOutcome)> = Vec::with_capacity(platforms.len());
        for platform in &platforms {
            if let Some(hit) = reuse.get(platform) {
                outcomes.push((platform.clone(), hit.clone()));
                continue;
            }
            // Pool mode: the per-platform search still fans its rung
            // batches across the worker pool — bit-identical to
            // sequential (the engine contract pinned by
            // tests/parallel_equiv.rs), just not one-config-per-core-
            // tick slow.
            let mut eval = fleet
                .platform_evaluator(platform)
                .expect("platform comes from the fleet")
                .pooled();
            let mut rec = Recorder::default();
            apply_budget(&mut rec, budget, t0);
            for obs in observers.iter_mut() {
                obs.on_platform(platform);
            }
            rec.set_observers(std::mem::take(&mut observers));
            let t = Instant::now();
            strategy.run(space, workload, &mut eval, seed, &mut rec);
            let secs = t.elapsed().as_secs_f64();
            fleet.credit_platform(platform, rec.len(), secs * 1e6);
            observers = rec.take_observers();
            let Some((best, best_latency_us)) = rec.best() else {
                if rec.out_of_budget() {
                    // The session budget expired before this platform
                    // could finish: return the platforms already tuned
                    // instead of discarding the whole session's work.
                    break;
                }
                return None; // genuinely no valid config on this platform
            };
            outcomes.push((
                platform.clone(),
                TuneOutcome {
                    best,
                    best_latency_us,
                    evaluated: rec.len(),
                    invalid: rec.invalid,
                    history: rec.evals,
                    wall_seconds: secs,
                    from_cache: false,
                },
            ));
        }
        if outcomes.is_empty() {
            return None; // budget expired before any platform finished
        }
        // The adaptive searches measured *different* configs per
        // platform, so the recorder logs rarely intersect; the honest
        // portability analysis cross-measures the per-platform winners
        // on every platform.  This happens outside the recorders, so
        // the per-platform outcomes stay bit-identical to solo tuning —
        // and it works for reused (cached) winners too.  A
        // budget-shortened run that covered only some platforms has no
        // whole-fleet portability story to tell (the cross-measured
        // latency rows would not align with the missing outcomes).
        let portable = if outcomes.len() == platforms.len() {
            portable_from_winners(fleet, &outcomes)
        } else {
            None
        };
        Some(FleetOutcome {
            distinct_winners: distinct_winner_count(&outcomes),
            outcomes,
            portable,
            wall_seconds: t0.elapsed().as_secs_f64(),
            from_cache: false,
        })
    }
}

/// Number of distinct winning configurations across platform outcomes.
fn distinct_winner_count(outcomes: &[(String, TuneOutcome)]) -> usize {
    let mut winners: Vec<u64> = outcomes.iter().map(|(_, o)| o.best.fingerprint()).collect();
    winners.sort_unstable();
    winners.dedup();
    winners.len()
}

/// The one portable-best selection rule, shared by both analyses:
/// among `candidates` (fingerprint + per-platform full-fidelity
/// latencies, aligned with `outcomes`), minimize the worst-case
/// slowdown versus each platform's own best; ties break on the lower
/// fingerprint so the selection is deterministic regardless of
/// candidate order.  Returns `(fingerprint, latencies, slowdown,
/// worst_slowdown)`.
fn pick_portable(
    candidates: impl IntoIterator<Item = (u64, Vec<f64>)>,
    outcomes: &[(String, TuneOutcome)],
) -> Option<(u64, Vec<f64>, Vec<f64>, f64)> {
    let mut best: Option<(f64, u64, Vec<f64>)> = None;
    for (fp, lats) in candidates {
        debug_assert_eq!(lats.len(), outcomes.len(), "candidate not measured on every platform");
        let worst = lats
            .iter()
            .zip(outcomes)
            .map(|(l, (_, o))| l / o.best_latency_us)
            .fold(0.0f64, f64::max);
        let better = match &best {
            None => true,
            Some((w, f, _)) => worst < *w || (worst == *w && fp < *f),
        };
        if better {
            best = Some((worst, fp, lats));
        }
    }
    best.map(|(worst, fp, lats)| {
        let slowdown: Vec<f64> = lats
            .iter()
            .zip(outcomes)
            .map(|(l, (_, o))| l / o.best_latency_us)
            .collect();
        (fp, lats, slowdown, worst)
    })
}

/// Portability analysis for the adaptive strategies: measure each
/// platform's winner on *every* platform (one measure-everywhere batch)
/// and pick via [`pick_portable`] among those valid everywhere.
///
/// Unlike the shared-trajectory analysis, a budgeted search's portable
/// slowdown can dip below 1.0 on some platform: another platform's
/// winner may genuinely beat the local incumbent the search settled on.
fn portable_from_winners(
    fleet: &mut MultiDeviceEvaluator,
    outcomes: &[(String, TuneOutcome)],
) -> Option<PortableBest> {
    let mut winners: Vec<Config> = Vec::new();
    for (_, o) in outcomes {
        if !winners.iter().any(|c| c.fingerprint() == o.best.fingerprint()) {
            winners.push(o.best.clone());
        }
    }
    winners.sort_by_key(Config::fingerprint);
    let results = fleet.evaluate_batch_everywhere(&winners, 1.0);
    let candidates = winners.iter().enumerate().filter_map(|(i, cfg)| {
        let lats: Option<Vec<f64>> =
            results.iter().map(|per_platform| per_platform[i].as_ref().ok().copied()).collect();
        lats.map(|l| (cfg.fingerprint(), l))
    });
    pick_portable(candidates, outcomes).map(|(fp, lats, slowdown, worst)| PortableBest {
        config: winners
            .iter()
            .find(|c| c.fingerprint() == fp)
            .expect("candidate came from winners")
            .clone(),
        latency_us: lats,
        slowdown,
        worst_slowdown: worst,
    })
}

/// Portability analysis for the shared-trajectory strategies: every
/// recorder logged the same config sequence, so the candidate set is
/// every config measured valid at full fidelity on *every* platform,
/// selected via [`pick_portable`].
fn portability(
    outcomes: &[(String, TuneOutcome)],
    recs: &[Recorder<'_>],
) -> Option<PortableBest> {
    let maps: Vec<HashMap<u64, f64>> =
        recs.iter().map(|r| r.full_fidelity_latencies()).collect();
    let first = maps.first()?;
    let candidates = first.keys().filter_map(|&fp| {
        let lats: Option<Vec<f64>> = maps.iter().map(|m| m.get(&fp).copied()).collect();
        lats.map(|l| (fp, l))
    });
    let (fp, lats, slowdown, worst) = pick_portable(candidates, outcomes)?;
    let config = recs.iter().find_map(|r| r.captured_config(fp))?.clone();
    Some(PortableBest { config, latency_us: lats, slowdown, worst_slowdown: worst })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spaces;
    use crate::kernels::baselines::{HAND_TUNED, TRITON_AMD, TRITON_NVIDIA};
    use crate::platform::SimGpu;
    use crate::workload::Workload;

    fn setup() -> (ConfigSpace, Workload, SimEvaluator) {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        (space, w, eval)
    }

    fn fleet_a100_mi250() -> MultiDeviceEvaluator {
        let w = Workload::llama3_attention(8, 1024);
        MultiDeviceEvaluator::new(vec![
            SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA),
            SimEvaluator::new(SimGpu::mi250(), w, TRITON_AMD),
        ])
    }

    /// Counts every observer event; used to prove the plumbing fires.
    #[derive(Default)]
    struct Counting {
        evals: usize,
        bests: usize,
        rungs: usize,
        platforms: Vec<String>,
        last_best_us: f64,
    }

    impl Observer for Counting {
        fn on_eval(&mut self, _r: &search::EvalRecord) {
            self.evals += 1;
        }
        fn on_new_best(&mut self, _c: &Config, us: f64) {
            self.bests += 1;
            self.last_best_us = us;
        }
        fn on_rung(&mut self, _f: f64, _p: usize) {
            self.rungs += 1;
        }
        fn on_platform(&mut self, p: &str) {
            self.platforms.push(p.to_string());
        }
    }

    #[test]
    fn observer_counts_match_outcome() {
        let (space, w, mut eval) = setup();
        let mut obs = Counting::default();
        let out = TuningSession::new(&space, &w)
            .observe(&mut obs)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert_eq!(obs.evals, out.evaluated, "observer must see every evaluation");
        assert!(obs.bests >= 1, "at least the first best fires");
        assert_eq!(obs.last_best_us.to_bits(), out.best_latency_us.to_bits());
        assert!(obs.platforms.is_empty(), "solo runs emit no platform events");
    }

    #[test]
    fn observer_sees_sha_rungs() {
        let (space, w, mut eval) = setup();
        let mut obs = Counting::default();
        TuningSession::new(&space, &w)
            .strategy(Strategy::SuccessiveHalving { initial: 32, eta: 2 })
            .seed(7)
            .observe(&mut obs)
            .evaluator(&mut eval)
            .run()
            .unwrap();
        assert!(obs.rungs >= 1, "successive halving must announce its rungs");
    }

    #[test]
    fn observer_never_changes_the_outcome() {
        let (space, w, _) = setup();
        let run = |observed: bool| {
            let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
            let mut obs = Counting::default();
            let mut s = TuningSession::new(&space, &w)
                .strategy(Strategy::SuccessiveHalving { initial: 32, eta: 2 })
                .seed(7);
            if observed {
                s = s.observe(&mut obs);
            }
            s.evaluator(&mut eval).run().and_then(SessionOutcome::into_solo).unwrap()
        };
        let (plain, observed) = (run(false), run(true));
        assert_eq!(plain.best, observed.best);
        assert_eq!(plain.best_latency_us.to_bits(), observed.best_latency_us.to_bits());
        assert_eq!(plain.history, observed.history);
    }

    #[test]
    fn budget_evals_caps_any_strategy() {
        let (space, w, _) = setup();
        for cap in [1usize, 7, 50] {
            let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
            let out = TuningSession::new(&space, &w)
                .budget(Budget::Evals(cap))
                .evaluator(&mut eval)
                .run()
                .and_then(SessionOutcome::into_solo);
            // Exhaustive would evaluate hundreds; the cap must hold
            // exactly (a capped history is a prefix of the uncapped
            // one, so with cap >= 1 the first config was evaluated —
            // but it may be invalid, in which case there is no best).
            if let Some(out) = out {
                assert!(out.evaluated <= cap, "cap {cap}: evaluated {}", out.evaluated);
                assert_eq!(out.evaluated, out.history.len());
            }
        }
    }

    #[test]
    fn budget_evals_is_a_prefix_of_the_uncapped_run() {
        let (space, w, _) = setup();
        let run = |budget: Option<Budget>| {
            let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
            let mut s = TuningSession::new(&space, &w)
                .strategy(Strategy::Random { budget: 120 })
                .seed(42);
            if let Some(b) = budget {
                s = s.budget(b);
            }
            s.evaluator(&mut eval).run().and_then(SessionOutcome::into_solo).unwrap()
        };
        let full = run(None);
        let capped = run(Some(Budget::Evals(30)));
        assert_eq!(capped.evaluated, 30);
        assert_eq!(capped.history[..], full.history[..30]);
    }

    #[test]
    fn budget_wallsecs_zero_stops_immediately() {
        let (space, w, mut eval) = setup();
        let out = TuningSession::new(&space, &w)
            .budget(Budget::WallSecs(0.0))
            .evaluator(&mut eval)
            .run();
        // Deadline already passed: nothing may be evaluated, so there
        // is no best and the session reports no outcome.
        assert!(out.is_none());
        assert_eq!(eval.calls, 0);
    }

    #[test]
    fn budget_deadline_in_the_past_stops_fleet_runs() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut fleet = fleet_a100_mi250();
        let out = TuningSession::new(&space, &w)
            .budget(Budget::Deadline(Instant::now() - Duration::from_secs(1)))
            .fleet(&mut fleet)
            .run();
        assert!(out.is_none());
    }

    #[test]
    fn fleet_observer_sees_each_adaptive_platform() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut fleet = fleet_a100_mi250();
        let mut obs = Counting::default();
        let out = TuningSession::new(&space, &w)
            .strategy(Strategy::SuccessiveHalving { initial: 16, eta: 2 })
            .seed(3)
            .observe(&mut obs)
            .fleet(&mut fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
            .unwrap();
        let platforms: Vec<String> = out.outcomes.iter().map(|(p, _)| p.clone()).collect();
        assert_eq!(obs.platforms, platforms, "one on_platform per tuned platform, in order");
        let total: usize = out.outcomes.iter().map(|(_, o)| o.evaluated).sum();
        assert_eq!(obs.evals, total, "observer follows the recorder across platforms");
    }

    #[test]
    fn devices_target_matches_plain_evaluator() {
        let (space, w, _) = setup();
        let base = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let sharded = TuningSession::new(&space, &w)
            .devices(&base, 3)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        let mut solo = base.clone().sequential();
        let plain = TuningSession::new(&space, &w)
            .evaluator(&mut solo)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert_eq!(sharded.best, plain.best);
        assert_eq!(sharded.best_latency_us.to_bits(), plain.best_latency_us.to_bits());
        assert_eq!(sharded.evaluated, plain.evaluated);
    }

    #[test]
    #[should_panic(expected = "without a target")]
    fn run_without_target_panics() {
        let (space, w, _) = setup();
        let _ = TuningSession::new(&space, &w).run();
    }

    #[test]
    #[should_panic(expected = "guided fleet tuning is not supported")]
    fn guided_with_fleet_target_panics() {
        // Silently dropping the prior would run a far more expensive
        // unguided fleet pass than the caller asked for.
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut fleet = fleet_a100_mi250();
        let _ = TuningSession::new(&space, &w)
            .guided(&mut prior, 10)
            .fleet(&mut fleet)
            .run();
    }

    #[test]
    fn budget_capped_results_are_not_persisted() {
        let (space, w, mut eval) = setup();
        let mut cache = TuningCache::ephemeral();
        // Truncated run (5 of several hundred configs): reported, but
        // never written under the full-run cache key.
        let capped = TuningSession::new(&space, &w)
            .budget(Budget::Evals(5))
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run();
        assert_eq!(cache.len(), 0, "a truncated winner must not be persisted");
        drop(capped);
        // A budget that never binds persists normally.
        let full = TuningSession::new(&space, &w)
            .budget(Budget::Evals(1_000_000))
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert!(!full.from_cache);
        assert_eq!(cache.len(), 1, "an unbound budget must not block persistence");
    }

    #[test]
    fn budget_wallsecs_huge_values_mean_unlimited() {
        // Non-finite or overflowing wall budgets must not panic in
        // Duration/Instant arithmetic — they behave as "no deadline".
        let (space, w, _) = setup();
        for secs in [f64::INFINITY, f64::NAN, 1e300, 1e15] {
            let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
            let out = TuningSession::new(&space, &w)
                .strategy(Strategy::Random { budget: 10 })
                .budget(Budget::WallSecs(secs))
                .evaluator(&mut eval)
                .run();
            assert!(out.is_some(), "wall-secs {secs} must run to completion");
        }
    }

    #[test]
    fn guided_expired_deadline_skips_the_prior_sweep() {
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
        let out = TuningSession::new(&space, &w)
            .guided(&mut prior, 20)
            .budget(Budget::WallSecs(0.0))
            .evaluator(&mut target)
            .run();
        assert!(out.is_none());
        assert_eq!(prior.calls, 0, "expired deadline must skip the ranking pass");
        assert_eq!(target.calls, 0);
    }

    #[test]
    fn guided_composes_with_cache() {
        // The builder allows guided + cache — a combination the flat
        // signatures never offered: the second run is a cache hit.
        let (space, w, _) = setup();
        let mut cache = TuningCache::ephemeral();
        let run = |cache: &mut TuningCache| {
            let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
            let mut target = SimEvaluator::new(SimGpu::a100(), w, TRITON_NVIDIA);
            TuningSession::new(&space, &w)
                .guided(&mut prior, 20)
                .cache(cache)
                .evaluator(&mut target)
                .run()
                .and_then(SessionOutcome::into_solo)
                .unwrap()
        };
        let first = run(&mut cache);
        assert!(!first.from_cache);
        assert!(first.evaluated <= 20);
        let second = run(&mut cache);
        assert!(second.from_cache);
        assert_eq!(second.best, first.best);
        assert_eq!(second.evaluated, 0);
    }

    #[test]
    fn fleet_partial_cache_reuse_hit_miss_mixed() {
        // The satellite contract: with an adaptive strategy, a partial
        // per-platform hit serves the cached platforms and re-tunes
        // only the missing ones.
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let strat = Strategy::SuccessiveHalving { initial: 32, eta: 2 };
        let mut cache = TuningCache::ephemeral();

        // MISS: cold cache, every platform tunes.
        let mut fleet = fleet_a100_mi250();
        let miss = TuningSession::new(&space, &w)
            .strategy(strat.clone())
            .seed(7)
            .cache(&mut cache)
            .fleet(&mut fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
            .unwrap();
        assert!(!miss.from_cache);
        assert!(miss.outcomes.iter().all(|(_, o)| !o.from_cache && o.evaluated > 0));
        assert_eq!(cache.len(), 2, "one entry per platform");

        // MIXED: invalidate one platform's entry; only that platform
        // re-tunes, the other is served from cache — and the re-tuned
        // outcome is bit-identical to its cold-cache run.
        let (gone, kept) =
            (miss.outcomes[0].0.clone(), miss.outcomes[1].0.clone());
        cache.invalidate_platform(&gone);
        let mut fleet = fleet_a100_mi250();
        let mixed = TuningSession::new(&space, &w)
            .strategy(strat.clone())
            .seed(7)
            .cache(&mut cache)
            .fleet(&mut fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
            .unwrap();
        assert!(!mixed.from_cache, "a partial hit is not a cached outcome");
        let retuned = mixed.outcomes.iter().find(|(p, _)| *p == gone).unwrap();
        let served = mixed.outcomes.iter().find(|(p, _)| *p == kept).unwrap();
        assert!(!retuned.1.from_cache && retuned.1.evaluated > 0);
        assert!(served.1.from_cache, "{kept} must be served from cache");
        assert_eq!(served.1.evaluated, 0);
        let cold = miss.outcomes.iter().find(|(p, _)| *p == gone).unwrap();
        assert_eq!(retuned.1.best, cold.1.best);
        assert_eq!(retuned.1.best_latency_us.to_bits(), cold.1.best_latency_us.to_bits());
        assert_eq!(retuned.1.history, cold.1.history);
        // When a portable pick exists, the cross-measured report covers
        // both platforms (cached winners are re-measured, not guessed).
        if let Some(pb) = &mixed.portable {
            assert_eq!(pb.latency_us.len(), 2);
            assert_eq!(pb.slowdown.len(), 2);
        }
        assert_eq!(cache.len(), 2, "the re-tuned winner is persisted again");

        // HIT: everything cached, zero evaluations.
        let mut fleet = fleet_a100_mi250();
        let hit = TuningSession::new(&space, &w)
            .strategy(strat)
            .seed(7)
            .cache(&mut cache)
            .fleet(&mut fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
            .unwrap();
        assert!(hit.from_cache);
        assert!(hit.outcomes.iter().all(|(_, o)| o.from_cache && o.evaluated == 0));
    }

    #[test]
    fn fleet_partial_hit_with_shared_trajectory_retunes_everything() {
        // Exhaustive/random share one measure-everywhere pass; a
        // partial hit cannot skip a platform, so the whole fleet
        // re-tunes (and the result matches a cold run bit-for-bit).
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut cache = TuningCache::ephemeral();
        let mut fleet = fleet_a100_mi250();
        let cold = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .fleet(&mut fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
            .unwrap();
        cache.invalidate_platform(&cold.outcomes[0].0);
        let mut fleet = fleet_a100_mi250();
        let partial = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .fleet(&mut fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
            .unwrap();
        assert!(!partial.from_cache);
        for ((p1, o1), (p2, o2)) in cold.outcomes.iter().zip(&partial.outcomes) {
            assert_eq!(p1, p2);
            assert!(!o2.from_cache, "{p2}: shared trajectory re-tunes every platform");
            assert_eq!(o1.best, o2.best);
            assert_eq!(o1.evaluated, o2.evaluated);
        }
    }
}
