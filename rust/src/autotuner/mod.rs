//! The autotuner: empirical search over kernel-configuration spaces.
//!
//! Addresses the paper's gap **Q4.2** (*"Autotuning needs to leverage
//! advanced search methods to reduce autotuning time and reliably
//! identify optimal configurations"*): several [`search`] strategies
//! share one [`Evaluator`] abstraction, so the same engine tunes against
//! the analytical platform models (simulated A100/MI250) *and* against
//! real PJRT-CPU executions of the AOT artifacts.
//!
//! Unlike the Triton built-in autotuner the paper critiques (§Q3), tuning
//! here is (a) cached persistently via [`crate::cache`], (b) composable
//! with background execution (`serving::executor`, feature `pjrt`), and
//! (c) explicit about invalid configurations (they are counted, not
//! hidden).
//!
//! **Throughput** (the paper's §Q4.2 time budget): every entry point
//! ([`tune`], [`tune_guided`], [`tune_cached`]) and every [`search`]
//! strategy takes *any* `&mut dyn Evaluator` and drives it through
//! [`Evaluator::evaluate_batch`].  Parallel evaluators fan batches
//! across the persistent worker pool ([`crate::util::pool`]):
//! [`SimEvaluator`] chunks a batch over every core, and
//! [`MultiDeviceEvaluator`] shards it across a fleet of per-device
//! evaluators.  Results are merged in submission order, so parallel and
//! multi-device runs are bit-identical to sequential ones — `cargo
//! bench --bench autotuner` reports configs/second for the scoped,
//! pooled, and multi-device paths.
//!
//! **Portability** (the paper's cross-vendor thesis): [`tune_fleet`]
//! runs one search over a *heterogeneous* fleet in measure-everywhere
//! mode — every candidate is measured on every distinct device platform
//! and each platform keeps its own recorder — returning a per-platform
//! argmin ([`FleetOutcome`]) plus the portability report
//! ([`PortableBest`]: winner overlap and the cost of shipping one
//! config fleet-wide).  `portatune tune --fleet a100,mi250` is the CLI
//! face of this mode.

pub mod evaluators;
pub mod search;

#[cfg(feature = "pjrt")]
pub use evaluators::PjrtEvaluator;
pub use evaluators::{BatchMode, MultiDeviceEvaluator, SimEvaluator};
pub use search::{EvalRecord, Strategy};

use std::collections::HashMap;
use std::time::Instant;

use crate::cache::{entry_now, TuningCache};
use crate::config::{Config, ConfigSpace};
use crate::platform::model::InvalidConfig;
use crate::workload::Workload;

/// Anything that can attach a latency to a configuration.
///
/// `fidelity` ∈ (0, 1] lets multi-fidelity searches (successive halving)
/// ask for cheaper, noisier measurements; evaluators may ignore it.
pub trait Evaluator {
    /// Stable platform identifier — part of persistent cache keys, so
    /// it must only change when tuning results stop being comparable.
    fn name(&self) -> String;

    /// Evaluate one configuration at full fidelity.
    fn evaluate(&mut self, cfg: &Config) -> Result<f64, InvalidConfig> {
        self.evaluate_fidelity(cfg, 1.0)
    }

    /// Evaluate one configuration at the given measurement fidelity.
    fn evaluate_fidelity(&mut self, cfg: &Config, fidelity: f64) -> Result<f64, InvalidConfig>;

    /// Evaluate a batch of configurations, returning results in
    /// submission order (`out[i]` belongs to `cfgs[i]`).
    ///
    /// The default implementation is sequential, so evaluators that
    /// cannot parallelize — `PjrtEvaluator`'s PJRT handles are not
    /// `Send` — work unchanged.  Parallel evaluators override this and
    /// fan the batch across the worker pool (or a device fleet); because
    /// the contract fixes the output *order*, callers cannot observe the
    /// difference except in wall-clock time.
    fn evaluate_batch(
        &mut self,
        cfgs: &[Config],
        fidelity: f64,
    ) -> Vec<Result<f64, InvalidConfig>> {
        cfgs.iter().map(|c| self.evaluate_fidelity(c, fidelity)).collect()
    }
}

/// One tuning run's outcome.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// The fastest valid configuration found.
    pub best: Config,
    /// Measured/modeled latency of [`TuneOutcome::best`], µs.
    pub best_latency_us: f64,
    /// Configurations actually evaluated (cache-miss cost of the run).
    pub evaluated: usize,
    /// Configurations rejected as invalid on this platform.
    pub invalid: usize,
    /// The evaluation log in submission order ([`EvalRecord`]:
    /// fingerprint, latency, fidelity).  Fingerprints, not configs: the
    /// log exists for counting/spread analysis, and cloning hundreds of
    /// `BTreeMap`s per run was pure overhead (only `best` needs the
    /// full config).
    pub history: Vec<EvalRecord>,
    /// Wall-clock duration of the tuning run, seconds.
    pub wall_seconds: f64,
    /// True when the result was served from the persistent cache.
    pub from_cache: bool,
}

impl TuneOutcome {
    /// Latency spread across valid **full-fidelity** evaluations (paper
    /// §Q3 reports ~20x for complex kernels).  Reduced-fidelity rung
    /// measurements are excluded: latencies measured at different
    /// fidelities are not comparable, and mixing them silently inflated
    /// (or deflated) the spread whenever successive halving ran.
    pub fn spread(&self) -> Option<f64> {
        let valid: Vec<f64> = self
            .history
            .iter()
            .filter(|r| r.is_full_fidelity())
            .filter_map(|r| r.latency_us)
            .collect();
        if valid.is_empty() {
            return None;
        }
        let best = valid.iter().cloned().fold(f64::INFINITY, f64::min);
        let worst = valid.iter().cloned().fold(0.0f64, f64::max);
        Some(worst / best)
    }
}

/// Run `strategy` over `space` for `workload` using `eval`.
pub fn tune(
    space: &ConfigSpace,
    workload: &Workload,
    eval: &mut dyn Evaluator,
    strategy: &Strategy,
    seed: u64,
) -> Option<TuneOutcome> {
    let t0 = Instant::now();
    let mut rec = search::Recorder::default();
    strategy.run(space, workload, eval, seed, &mut rec);
    let (best, best_latency_us) = rec.best()?;
    Some(TuneOutcome {
        best,
        best_latency_us,
        evaluated: rec.len(),
        invalid: rec.invalid,
        history: rec.evals,
        wall_seconds: t0.elapsed().as_secs_f64(),
        from_cache: false,
    })
}

/// Model-guided (transfer) tuning: rank the whole space with a cheap
/// *prior* evaluator (e.g. an analytical platform model), then measure
/// only the `top_k` most promising configurations on the expensive
/// *target* evaluator (e.g. real PJRT execution).
///
/// This is the practical middle road between the paper's 24 h exhaustive
/// budget and heuristic-only dispatch: the prior prunes the space by an
/// order of magnitude, the target keeps the decision empirical.
pub fn tune_guided(
    space: &ConfigSpace,
    workload: &Workload,
    prior: &mut dyn Evaluator,
    target: &mut dyn Evaluator,
    top_k: usize,
) -> Option<TuneOutcome> {
    let t0 = Instant::now();
    // Rank by prior (invalid-on-prior configs go last, not dropped: the
    // prior is a model, not ground truth).  The ranking pass streams
    // through the batch API so a parallel prior uses every core.
    let configs: Vec<Config> = space.enumerate(workload).collect();
    let mut priors: Vec<Option<f64>> = Vec::with_capacity(configs.len());
    for chunk in configs.chunks(search::EVAL_BATCH) {
        priors.extend(prior.evaluate_batch(chunk, 1.0).into_iter().map(|r| r.ok()));
    }
    let mut ranked: Vec<(Config, Option<f64>)> = configs.into_iter().zip(priors).collect();

    // Total order: prior-score ties (common when the prior ignores a
    // parameter) break on the config fingerprint, so the measured
    // top-k set is pinned regardless of `select_nth_unstable_by`'s
    // unspecified ordering among equals.
    fn by_prior(a: &(Config, Option<f64>), b: &(Config, Option<f64>)) -> std::cmp::Ordering {
        let primary = match (a.1, b.1) {
            (Some(x), Some(y)) => x.total_cmp(&y),
            (Some(_), None) => std::cmp::Ordering::Less,
            (None, Some(_)) => std::cmp::Ordering::Greater,
            (None, None) => std::cmp::Ordering::Equal,
        };
        primary.then_with(|| a.0.fingerprint().cmp(&b.0.fingerprint()))
    }

    // Only top_k configs are ever measured, so an O(n) partial selection
    // replaces the old full O(n log n) sort of the entire ranked space;
    // only the k survivors are sorted (for measurement order).
    let k = top_k.max(1).min(ranked.len());
    if k < ranked.len() {
        ranked.select_nth_unstable_by(k - 1, by_prior);
        ranked.truncate(k);
    }
    ranked.sort_by(by_prior);

    // Measure the survivors through a Recorder: same bookkeeping
    // (fingerprint history, invalid count, running best) as every
    // search strategy.
    let mut rec = search::Recorder::default();
    for (cfg, _) in ranked {
        rec.eval(target, &cfg, 1.0);
    }
    let (best, best_latency_us) = rec.best()?;
    Some(TuneOutcome {
        best,
        best_latency_us,
        evaluated: rec.len(),
        invalid: rec.invalid,
        history: rec.evals,
        wall_seconds: t0.elapsed().as_secs_f64(),
        from_cache: false,
    })
}

/// Cache-aware tuning (Q4.3): return a reusable cached result when the
/// platform/space fingerprints match, otherwise tune and persist.
///
/// The space component of the cache key is
/// [`ConfigSpace::fingerprint_key`] — a stable FNV-1a digest of the
/// space definition (name, parameters, choices, constraint *names*) —
/// so edits to parameters or choices invalidate old entries, not just
/// cardinality changes.  Constraint *bodies* are closures and cannot be
/// hashed, so a hit is additionally re-validated with
/// [`ConfigSpace::contains`]; a cached winner the current space rejects
/// falls through to a fresh tune instead of being served.
pub fn tune_cached(
    cache: &mut TuningCache,
    space: &ConfigSpace,
    workload: &Workload,
    eval: &mut dyn Evaluator,
    strategy: &Strategy,
    seed: u64,
) -> Option<TuneOutcome> {
    let platform = eval.name();
    let space_fp = space.fingerprint_key();
    if let Some(hit) = cache.get(workload, &platform, &space_fp) {
        if let Some(best) = hit.config() {
            if space.contains(&best, workload) {
                return Some(TuneOutcome {
                    best,
                    best_latency_us: hit.latency_us,
                    evaluated: 0,
                    invalid: hit.invalid,
                    history: Vec::new(),
                    wall_seconds: 0.0,
                    from_cache: true,
                });
            }
        }
        // Unparseable or no-longer-valid entry: re-tune and overwrite.
    }
    let outcome = tune(space, workload, eval, strategy, seed)?;
    cache.put(
        workload,
        entry_now(
            &outcome.best,
            outcome.best_latency_us,
            outcome.evaluated,
            outcome.invalid,
            &platform,
            &space_fp,
            outcome.wall_seconds,
        ),
    );
    Some(outcome)
}

/// Outcome of a fleet ("measure everywhere") tuning run: one tuning
/// result per *distinct platform* in the fleet, plus the paper's
/// cross-vendor portability analysis.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    /// `(platform fingerprint, outcome)` per distinct platform, in
    /// [`MultiDeviceEvaluator::platforms`] (sorted-name) order.  Each
    /// outcome is bit-identical to tuning that platform alone with a
    /// sequential evaluator (same strategy, seed, and space).
    pub outcomes: Vec<(String, TuneOutcome)>,
    /// Number of distinct winning configurations across the platforms.
    /// 1 means a single config wins everywhere (perfect winner overlap);
    /// equal to the platform count means every platform wants its own
    /// kernel — the paper's argument for per-platform multi-versioning.
    pub distinct_winners: usize,
    /// The portable compromise config — chosen from all shared
    /// candidates (exhaustive/random) or from the cross-measured
    /// per-platform winners (adaptive strategies).  `None` when no
    /// measured candidate is valid on every platform, or when the
    /// outcomes came from the cache (which stores winners only).
    pub portable: Option<PortableBest>,
    /// Wall-clock duration of the whole fleet run, seconds.
    pub wall_seconds: f64,
    /// True when every platform outcome was served from the cache.
    pub from_cache: bool,
}

impl FleetOutcome {
    /// The outcome for one platform, if it is part of the fleet.
    pub fn platform(&self, name: &str) -> Option<&TuneOutcome> {
        self.outcomes.iter().find(|(p, _)| p == name).map(|(_, o)| o)
    }
}

/// The cross-platform compromise: among configurations measured valid at
/// full fidelity on *every* platform of the fleet, the one minimizing
/// the worst-case slowdown versus each platform's own best (ties broken
/// by config fingerprint, so the selection is deterministic).
///
/// This is the "one portable kernel" column of the paper's cross-vendor
/// table: how much each platform gives up if a single configuration
/// must serve the whole fleet.
#[derive(Debug, Clone)]
pub struct PortableBest {
    /// The portable configuration.
    pub config: Config,
    /// Full-fidelity latency of [`PortableBest::config`] on each
    /// platform, aligned with [`FleetOutcome::outcomes`].
    pub latency_us: Vec<f64>,
    /// Per-platform slowdown `latency_us[i] / platform i's best`,
    /// aligned with [`FleetOutcome::outcomes`].  Always ≥ 1 for the
    /// shared-trajectory strategies (the platform best is the minimum
    /// over the same candidate set); for budgeted adaptive strategies a
    /// value below 1 means another platform's winner beats the config
    /// this platform's own search settled on.
    pub slowdown: Vec<f64>,
    /// The minimized objective: the largest entry of
    /// [`PortableBest::slowdown`].
    pub worst_slowdown: f64,
}

/// Tune the shared `space` for every distinct platform of `fleet` at
/// once — the "A Few Fit Most" regime: each evaluated configuration is
/// measured on **every** platform (via
/// [`MultiDeviceEvaluator::evaluate_batch_everywhere`]) and each
/// platform keeps its own [`search::Recorder`], so the result is a
/// per-platform argmin plus the portability report, for the cost of one
/// pass over the space.
///
/// Per-platform outcomes are **bit-identical** to tuning each platform
/// alone with a sequential evaluator (pinned by
/// `tests/parallel_equiv.rs`): exhaustive and random searches share one
/// trajectory (their evaluation order never depends on measured
/// latencies), while the adaptive strategies (hill climb, annealing,
/// successive halving) are run once per platform — their trajectories
/// genuinely diverge per platform, which is exactly the per-platform
/// argmin the regime asks for.
///
/// Returns `None` when any platform found no valid configuration.
pub fn tune_fleet(
    space: &ConfigSpace,
    workload: &Workload,
    fleet: &mut MultiDeviceEvaluator,
    strategy: &Strategy,
    seed: u64,
) -> Option<FleetOutcome> {
    let t0 = Instant::now();
    let platforms = fleet.platforms();
    let shared_trajectory = matches!(strategy, Strategy::Exhaustive | Strategy::Random { .. });
    // Only the first recorder captures configs, and only on the
    // shared-trajectory path (the adaptive analysis works from the
    // winners, not the capture map): every portable-best candidate is
    // by definition evaluated on *every* platform — including platform
    // 0 — so one fingerprint→Config map carries the whole portability
    // analysis, instead of P identical maps cloning every config once
    // per platform.
    let mut recs: Vec<search::Recorder> = platforms
        .iter()
        .enumerate()
        .map(|(i, _)| {
            if i == 0 && shared_trajectory {
                search::Recorder::capturing()
            } else {
                search::Recorder::default()
            }
        })
        .collect();
    // Wall-clock attributed to each platform: measured per platform on
    // the adaptive path, an even share of the shared pass otherwise
    // (the platforms run concurrently there, so the total is not P
    // times anyone's cost).
    let mut per_platform_secs: Vec<f64> = vec![0.0; platforms.len()];
    if shared_trajectory {
        search::run_fleet_shared(space, workload, fleet, strategy, seed, &mut recs);
        let share = t0.elapsed().as_secs_f64() / platforms.len().max(1) as f64;
        per_platform_secs.fill(share);
    } else {
        for (i, (platform, rec)) in platforms.iter().zip(recs.iter_mut()).enumerate() {
            // Pool mode: the per-platform search still fans its rung
            // batches across the worker pool — bit-identical to
            // sequential (the engine contract pinned by
            // tests/parallel_equiv.rs), just not one-config-per-core-
            // tick slow.
            let mut eval = fleet
                .platform_evaluator(platform)
                .expect("platform comes from the fleet")
                .pooled();
            let t = Instant::now();
            strategy.run(space, workload, &mut eval, seed, rec);
            per_platform_secs[i] = t.elapsed().as_secs_f64();
            fleet.credit_platform(platform, rec.len(), per_platform_secs[i] * 1e6);
        }
    }
    let wall_seconds = t0.elapsed().as_secs_f64();
    let mut outcomes: Vec<(String, TuneOutcome)> = Vec::with_capacity(platforms.len());
    for ((platform, rec), secs) in platforms.iter().zip(&recs).zip(&per_platform_secs) {
        let (best, best_latency_us) = rec.best()?;
        outcomes.push((
            platform.clone(),
            TuneOutcome {
                best,
                best_latency_us,
                evaluated: rec.len(),
                invalid: rec.invalid,
                history: rec.evals.clone(),
                wall_seconds: *secs,
                from_cache: false,
            },
        ));
    }
    let portable = if shared_trajectory {
        portability(&outcomes, &recs)
    } else {
        // The adaptive searches measured *different* configs per
        // platform, so the recorder logs rarely intersect; the honest
        // portability analysis cross-measures the per-platform winners
        // on every platform.  This happens outside the recorders, so
        // the per-platform outcomes stay bit-identical to solo tuning.
        portable_from_winners(fleet, &outcomes)
    };
    Some(FleetOutcome {
        distinct_winners: distinct_winner_count(&outcomes),
        outcomes,
        portable,
        wall_seconds,
        from_cache: false,
    })
}

/// Number of distinct winning configurations across platform outcomes.
fn distinct_winner_count(outcomes: &[(String, TuneOutcome)]) -> usize {
    let mut winners: Vec<u64> = outcomes.iter().map(|(_, o)| o.best.fingerprint()).collect();
    winners.sort_unstable();
    winners.dedup();
    winners.len()
}

/// The one portable-best selection rule, shared by both analyses:
/// among `candidates` (fingerprint + per-platform full-fidelity
/// latencies, aligned with `outcomes`), minimize the worst-case
/// slowdown versus each platform's own best; ties break on the lower
/// fingerprint so the selection is deterministic regardless of
/// candidate order.  Returns `(fingerprint, latencies, slowdown,
/// worst_slowdown)`.
fn pick_portable(
    candidates: impl IntoIterator<Item = (u64, Vec<f64>)>,
    outcomes: &[(String, TuneOutcome)],
) -> Option<(u64, Vec<f64>, Vec<f64>, f64)> {
    let mut best: Option<(f64, u64, Vec<f64>)> = None;
    for (fp, lats) in candidates {
        debug_assert_eq!(lats.len(), outcomes.len(), "candidate not measured on every platform");
        let worst = lats
            .iter()
            .zip(outcomes)
            .map(|(l, (_, o))| l / o.best_latency_us)
            .fold(0.0f64, f64::max);
        let better = match &best {
            None => true,
            Some((w, f, _)) => worst < *w || (worst == *w && fp < *f),
        };
        if better {
            best = Some((worst, fp, lats));
        }
    }
    best.map(|(worst, fp, lats)| {
        let slowdown: Vec<f64> = lats
            .iter()
            .zip(outcomes)
            .map(|(l, (_, o))| l / o.best_latency_us)
            .collect();
        (fp, lats, slowdown, worst)
    })
}

/// Portability analysis for the adaptive strategies: measure each
/// platform's winner on *every* platform (one measure-everywhere batch)
/// and pick via [`pick_portable`] among those valid everywhere.
///
/// Unlike the shared-trajectory analysis, a budgeted search's portable
/// slowdown can dip below 1.0 on some platform: another platform's
/// winner may genuinely beat the local incumbent the search settled on.
fn portable_from_winners(
    fleet: &mut MultiDeviceEvaluator,
    outcomes: &[(String, TuneOutcome)],
) -> Option<PortableBest> {
    let mut winners: Vec<Config> = Vec::new();
    for (_, o) in outcomes {
        if !winners.iter().any(|c| c.fingerprint() == o.best.fingerprint()) {
            winners.push(o.best.clone());
        }
    }
    winners.sort_by_key(Config::fingerprint);
    let results = fleet.evaluate_batch_everywhere(&winners, 1.0);
    let candidates = winners.iter().enumerate().filter_map(|(i, cfg)| {
        let lats: Option<Vec<f64>> =
            results.iter().map(|per_platform| per_platform[i].as_ref().ok().copied()).collect();
        lats.map(|l| (cfg.fingerprint(), l))
    });
    pick_portable(candidates, outcomes).map(|(fp, lats, slowdown, worst)| PortableBest {
        config: winners
            .iter()
            .find(|c| c.fingerprint() == fp)
            .expect("candidate came from winners")
            .clone(),
        latency_us: lats,
        slowdown,
        worst_slowdown: worst,
    })
}

/// Portability analysis for the shared-trajectory strategies: every
/// recorder logged the same config sequence, so the candidate set is
/// every config measured valid at full fidelity on *every* platform,
/// selected via [`pick_portable`].
fn portability(
    outcomes: &[(String, TuneOutcome)],
    recs: &[search::Recorder],
) -> Option<PortableBest> {
    let maps: Vec<HashMap<u64, f64>> =
        recs.iter().map(|r| r.full_fidelity_latencies()).collect();
    let first = maps.first()?;
    let candidates = first.keys().filter_map(|&fp| {
        let lats: Option<Vec<f64>> = maps.iter().map(|m| m.get(&fp).copied()).collect();
        lats.map(|l| (fp, l))
    });
    let (fp, lats, slowdown, worst) = pick_portable(candidates, outcomes)?;
    let config = recs.iter().find_map(|r| r.captured_config(fp))?.clone();
    Some(PortableBest { config, latency_us: lats, slowdown, worst_slowdown: worst })
}

/// Cache-aware [`tune_fleet`]: every platform's winner is persisted
/// under **that platform's own cache key** (`workload × platform ×
/// space`), so a later single-platform [`tune_cached`] run — or a
/// serving process pinned to one device model — reuses fleet results
/// directly.  Conversely, the fleet run is served from the cache only
/// when *every* platform hits: a partial hit cannot shortcut the shared
/// measure-everywhere pass, and for uniformity the adaptive strategies
/// currently re-tune all platforms too (skipping cached platforms on
/// their independent per-platform searches is a queued ROADMAP
/// follow-up).  Cached fleet outcomes carry no evaluation history, so
/// [`FleetOutcome::portable`] is `None` on that path.
pub fn tune_fleet_cached(
    cache: &mut TuningCache,
    space: &ConfigSpace,
    workload: &Workload,
    fleet: &mut MultiDeviceEvaluator,
    strategy: &Strategy,
    seed: u64,
) -> Option<FleetOutcome> {
    let space_fp = space.fingerprint_key();
    let platforms = fleet.platforms();
    let mut hits: Vec<(String, TuneOutcome)> = Vec::with_capacity(platforms.len());
    for platform in &platforms {
        let hit = cache.get(workload, platform, &space_fp).and_then(|h| {
            let best = h.config()?;
            space.contains(&best, workload).then(|| TuneOutcome {
                best,
                best_latency_us: h.latency_us,
                evaluated: 0,
                invalid: h.invalid,
                history: Vec::new(),
                wall_seconds: 0.0,
                from_cache: true,
            })
        });
        match hit {
            Some(o) => hits.push((platform.clone(), o)),
            None => {
                hits.clear();
                break;
            }
        }
    }
    if !platforms.is_empty() && hits.len() == platforms.len() {
        return Some(FleetOutcome {
            distinct_winners: distinct_winner_count(&hits),
            outcomes: hits,
            portable: None,
            wall_seconds: 0.0,
            from_cache: true,
        });
    }
    let outcome = tune_fleet(space, workload, fleet, strategy, seed)?;
    for (platform, o) in &outcome.outcomes {
        cache.put(
            workload,
            entry_now(
                &o.best,
                o.best_latency_us,
                o.evaluated,
                o.invalid,
                platform,
                &space_fp,
                o.wall_seconds,
            ),
        );
    }
    Some(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spaces;
    use crate::kernels::baselines::HAND_TUNED;
    use crate::platform::SimGpu;

    fn setup() -> (ConfigSpace, Workload, SimEvaluator) {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        (space, w, eval)
    }

    #[test]
    fn exhaustive_finds_global_optimum() {
        let (space, w, mut eval) = setup();
        let out = tune(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        // Cross-check against direct enumeration.
        let gpu = SimGpu::a100();
        let best_direct = space
            .enumerate(&w)
            .filter_map(|c| gpu.latency_us(&c, &w, &HAND_TUNED).ok())
            .fold(f64::INFINITY, f64::min);
        assert!((out.best_latency_us - best_direct).abs() < 1e-9);
        assert!(out.evaluated > 400);
    }

    #[test]
    fn random_is_reproducible_per_seed() {
        let (space, w, mut eval) = setup();
        let a = tune(&space, &w, &mut eval, &Strategy::Random { budget: 50 }, 7).unwrap();
        let b = tune(&space, &w, &mut eval, &Strategy::Random { budget: 50 }, 7).unwrap();
        assert_eq!(a.best, b.best);
        let c = tune(&space, &w, &mut eval, &Strategy::Random { budget: 50 }, 8).unwrap();
        // different seed may find a different best (not asserted), but
        // must still return a valid config
        assert!(space.contains(&c.best, &w));
    }

    #[test]
    fn all_strategies_return_valid_configs() {
        let (space, w, mut eval) = setup();
        for strat in [
            Strategy::Exhaustive,
            Strategy::Random { budget: 40 },
            Strategy::HillClimb { restarts: 3, budget: 200 },
            Strategy::Anneal { budget: 150, t0: 2.0, alpha: 0.95 },
            Strategy::SuccessiveHalving { initial: 32, eta: 2 },
        ] {
            let out = tune(&space, &w, &mut eval, &strat, 3)
                .unwrap_or_else(|| panic!("{strat:?} found nothing"));
            assert!(space.contains(&out.best, &w), "{strat:?} returned invalid config");
            assert!(out.best_latency_us > 0.0);
        }
    }

    #[test]
    fn local_search_competitive_with_exhaustive() {
        let (space, w, mut eval) = setup();
        let ex = tune(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        let hc = tune(&space, &w, &mut eval, &Strategy::HillClimb { restarts: 5, budget: 400 }, 11).unwrap();
        assert!(
            hc.best_latency_us <= ex.best_latency_us * 1.3,
            "hill climb {:.1}us vs exhaustive {:.1}us",
            hc.best_latency_us,
            ex.best_latency_us
        );
        assert!(hc.evaluated < ex.evaluated, "local search should be cheaper");
    }

    #[test]
    fn tune_cached_hits_second_time() {
        let (space, w, mut eval) = setup();
        let mut cache = TuningCache::ephemeral();
        let first = tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Random { budget: 30 }, 1).unwrap();
        assert!(!first.from_cache);
        let second = tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Random { budget: 30 }, 1).unwrap();
        assert!(second.from_cache);
        assert_eq!(second.best, first.best);
        assert_eq!(second.evaluated, 0);
    }

    #[test]
    fn tune_cached_misses_when_space_definition_changes() {
        // A space with the same name and cardinality but different
        // choices must NOT reuse the entry (the old name#cardinality
        // fingerprint could not tell these apart).
        let w = Workload::llama3_attention(8, 1024);
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut cache = TuningCache::ephemeral();
        let s1 = ConfigSpace::new("s")
            .param("BLOCK_M", &[32, 64])
            .param("BLOCK_N", &[32, 64])
            .param("num_warps", &[2, 4])
            .param("num_stages", &[1, 2]);
        let s2 = ConfigSpace::new("s")
            .param("BLOCK_M", &[64, 128])
            .param("BLOCK_N", &[32, 64])
            .param("num_warps", &[2, 4])
            .param("num_stages", &[1, 2]);
        assert_eq!(s1.cardinality(), s2.cardinality());
        let first = tune_cached(&mut cache, &s1, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(!first.from_cache);
        let second = tune_cached(&mut cache, &s2, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(!second.from_cache, "changed choices must invalidate the cache");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn tune_cached_revalidates_hit_against_current_space() {
        // Constraint *bodies* are closures and not part of the space
        // fingerprint, so a predicate change can leave a stale entry
        // under a matching key: the hit must be re-validated, not
        // served blindly.
        let w = Workload::llama3_attention(8, 1024);
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut cache = TuningCache::ephemeral();
        let space = ConfigSpace::new("reval")
            .param("BLOCK_M", &[32, 64])
            .param("BLOCK_N", &[32, 64])
            .param("num_warps", &[4])
            .param("num_stages", &[1])
            .constraint("block_m_bound", |c, _| c.req("BLOCK_M") <= 32);
        let stale = Config::new(&[
            ("BLOCK_M", 64), // violates the (tightened) constraint
            ("BLOCK_N", 32),
            ("num_warps", 4),
            ("num_stages", 1),
        ]);
        cache.put(
            &w,
            entry_now(&stale, 1.0, 10, 0, &eval.name(), &space.fingerprint_key(), 0.1),
        );
        let out = tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(!out.from_cache, "a no-longer-valid cached winner must not be served");
        assert!(space.contains(&out.best, &w));
    }

    #[test]
    fn guided_tuning_prunes_but_stays_close_to_exhaustive() {
        // Prior = hand-tuned model, target = triton-codegen model with
        // a different efficiency surface: the prior's ranking transfers.
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target =
            SimEvaluator::new(SimGpu::a100(), w, crate::kernels::baselines::TRITON_NVIDIA);
        let guided = tune_guided(&space, &w, &mut prior, &mut target, 20).unwrap();
        let exhaustive = tune(&space, &w, &mut target, &Strategy::Exhaustive, 0).unwrap();
        assert!(guided.evaluated <= 20);
        assert!(
            guided.best_latency_us <= exhaustive.best_latency_us * 1.10,
            "guided {:.1}us vs exhaustive {:.1}us",
            guided.best_latency_us,
            exhaustive.best_latency_us
        );
    }

    #[test]
    fn guided_tuning_cross_platform_prior_still_works() {
        // Even a *wrong-platform* prior (A100 model ranking for an MI250
        // target) finds a decent config with k=60 — but the same budget
        // of native random search is the fair comparison; the test just
        // guards the mechanism, not the transfer quality.
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(
            crate::platform::SimGpu::mi250(),
            w,
            crate::kernels::baselines::TRITON_AMD,
        );
        let guided = tune_guided(&space, &w, &mut prior, &mut target, 60);
        assert!(guided.is_some());
    }

    #[test]
    fn guided_top_k_larger_than_space_measures_everything() {
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let n_valid = space.enumerate(&w).count();
        let guided = tune_guided(&space, &w, &mut prior, &mut target, n_valid + 100).unwrap();
        assert_eq!(guided.evaluated, n_valid);
    }

    #[test]
    fn invalid_configs_are_counted_not_fatal() {
        let (space, w, mut eval) = setup();
        let out = tune(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        // The A100 rejects big-staging configs (smem) — some must appear.
        assert!(out.invalid > 0);
        assert_eq!(out.evaluated, out.history.len());
    }

    #[test]
    fn spread_matches_paper_scale() {
        let (space, w, mut eval) = setup();
        let out = tune(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(out.spread().unwrap() > 5.0);
    }

    #[test]
    fn spread_ignores_reduced_fidelity_measurements() {
        // A history mixing rung fidelities must compute the spread over
        // the full-fidelity entries only: the 1 µs low-fidelity sample
        // below would otherwise fake a 100x spread.
        let out = TuneOutcome {
            best: Config::new(&[("a", 1)]),
            best_latency_us: 10.0,
            evaluated: 3,
            invalid: 0,
            history: vec![
                EvalRecord { fingerprint: 1, latency_us: Some(1.0), fidelity: 0.25 },
                EvalRecord { fingerprint: 2, latency_us: Some(10.0), fidelity: 1.0 },
                EvalRecord { fingerprint: 3, latency_us: Some(100.0), fidelity: 1.0 },
            ],
            wall_seconds: 0.0,
            from_cache: false,
        };
        assert_eq!(out.spread(), Some(10.0));
    }

    fn fleet_a100_mi250() -> MultiDeviceEvaluator {
        let w = Workload::llama3_attention(8, 1024);
        MultiDeviceEvaluator::new(vec![
            SimEvaluator::new(SimGpu::a100(), w, crate::kernels::baselines::TRITON_NVIDIA),
            SimEvaluator::new(SimGpu::mi250(), w, crate::kernels::baselines::TRITON_AMD),
        ])
    }

    #[test]
    fn tune_fleet_matches_solo_per_platform_winners() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut fleet = fleet_a100_mi250();
        let out = tune_fleet(&space, &w, &mut fleet, &Strategy::Exhaustive, 0).unwrap();
        assert_eq!(out.outcomes.len(), 2);
        for (platform, got) in &out.outcomes {
            let mut solo = fleet.platform_evaluator(platform).unwrap();
            let want = tune(&space, &w, &mut solo, &Strategy::Exhaustive, 0).unwrap();
            assert_eq!(got.best, want.best, "{platform}: winner differs from solo tune");
            assert_eq!(
                got.best_latency_us.to_bits(),
                want.best_latency_us.to_bits(),
                "{platform}: best latency differs from solo tune"
            );
            assert_eq!(got.evaluated, want.evaluated);
            assert_eq!(got.invalid, want.invalid);
        }
    }

    #[test]
    fn tune_fleet_portability_report_is_consistent() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut fleet = fleet_a100_mi250();
        let out = tune_fleet(&space, &w, &mut fleet, &Strategy::Exhaustive, 0).unwrap();
        assert!(out.distinct_winners >= 1 && out.distinct_winners <= 2);
        let pb = out.portable.as_ref().expect("exhaustive fleet must find a portable config");
        // The portable config is valid (in-space) and its slowdowns are
        // genuine ratios against each platform's best.
        assert!(space.contains(&pb.config, &w));
        assert_eq!(pb.latency_us.len(), out.outcomes.len());
        assert_eq!(pb.slowdown.len(), out.outcomes.len());
        let mut worst: f64 = 0.0;
        for ((lat, slow), (_, o)) in pb.latency_us.iter().zip(&pb.slowdown).zip(&out.outcomes) {
            assert!(*slow >= 1.0, "portable config cannot beat a platform's own best");
            assert!((slow - lat / o.best_latency_us).abs() < 1e-12);
            worst = worst.max(*slow);
        }
        assert_eq!(pb.worst_slowdown, worst);
        // If a single config wins everywhere, the portable best pays no
        // slowdown anywhere (the portable pick may be a latency-tied
        // twin of the winner, so compare objectives, not configs).
        if out.distinct_winners == 1 {
            assert!((pb.worst_slowdown - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn tune_fleet_counts_replicated_work() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut fleet = fleet_a100_mi250();
        let out = tune_fleet(&space, &w, &mut fleet, &Strategy::Exhaustive, 0).unwrap();
        let per_platform: usize = out.outcomes.iter().map(|(_, o)| o.evaluated).sum();
        let replicated: usize = fleet.utilization().iter().map(|u| u.replicated).sum();
        assert_eq!(replicated, per_platform, "every config measured on every platform");
    }

    #[test]
    fn tune_fleet_supports_adaptive_strategies_per_platform() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut fleet = fleet_a100_mi250();
        let out = tune_fleet(
            &space,
            &w,
            &mut fleet,
            &Strategy::SuccessiveHalving { initial: 32, eta: 2 },
            7,
        )
        .unwrap();
        for (platform, got) in &out.outcomes {
            let mut solo = fleet.platform_evaluator(platform).unwrap();
            let want =
                tune(&space, &w, &mut solo, &Strategy::SuccessiveHalving { initial: 32, eta: 2 }, 7)
                    .unwrap();
            assert_eq!(got.best, want.best, "{platform}: SHA winner differs from solo");
            assert_eq!(got.best_latency_us.to_bits(), want.best_latency_us.to_bits());
        }
        // The adaptive path cross-measures the per-platform winners, so
        // when a portable pick exists it must be one of those winners,
        // with one latency/slowdown per platform.
        if let Some(pb) = &out.portable {
            assert!(
                out.outcomes.iter().any(|(_, o)| o.best == pb.config),
                "adaptive portable pick must be one of the platform winners"
            );
            assert_eq!(pb.latency_us.len(), out.outcomes.len());
            assert_eq!(pb.slowdown.len(), out.outcomes.len());
            assert!(pb.worst_slowdown > 0.0);
            let max = pb.slowdown.iter().cloned().fold(0.0f64, f64::max);
            assert_eq!(pb.worst_slowdown, max);
        }
    }

    #[test]
    fn tune_fleet_cached_writes_per_platform_keys() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut cache = TuningCache::ephemeral();
        let mut fleet = fleet_a100_mi250();
        let first =
            tune_fleet_cached(&mut cache, &space, &w, &mut fleet, &Strategy::Exhaustive, 0)
                .unwrap();
        assert!(!first.from_cache);
        assert_eq!(cache.len(), 2, "one entry per distinct platform");
        // A later SINGLE-platform cached tune hits the fleet's entry.
        for (platform, o) in &first.outcomes {
            let mut solo = fleet.platform_evaluator(platform).unwrap();
            let hit =
                tune_cached(&mut cache, &space, &w, &mut solo, &Strategy::Exhaustive, 0).unwrap();
            assert!(hit.from_cache, "{platform}: solo tune must reuse the fleet entry");
            assert_eq!(hit.best, o.best);
        }
        // And the fleet run itself hits when every platform is cached.
        let second =
            tune_fleet_cached(&mut cache, &space, &w, &mut fleet, &Strategy::Exhaustive, 0)
                .unwrap();
        assert!(second.from_cache);
        assert_eq!(second.distinct_winners, first.distinct_winners);
        for ((p1, o1), (p2, o2)) in first.outcomes.iter().zip(&second.outcomes) {
            assert_eq!(p1, p2);
            assert_eq!(o1.best, o2.best);
            assert_eq!(o2.evaluated, 0);
        }
    }
}
