//! The autotuner: empirical search over kernel-configuration spaces.
//!
//! Addresses the paper's gap **Q4.2** (*"Autotuning needs to leverage
//! advanced search methods to reduce autotuning time and reliably
//! identify optimal configurations"*): several [`search`] strategies
//! share one [`Evaluator`] abstraction, so the same engine tunes against
//! the analytical platform models (simulated A100/MI250) *and* against
//! real PJRT-CPU executions of the AOT artifacts.
//!
//! Unlike the Triton built-in autotuner the paper critiques (§Q3), tuning
//! here is (a) cached persistently via [`crate::cache`], (b) composable
//! with background execution (`serving::executor`, feature `pjrt`), and
//! (c) explicit about invalid configurations (they are counted, not
//! hidden).
//!
//! **Throughput** (the paper's §Q4.2 time budget): every entry point
//! ([`tune`], [`tune_guided`], [`tune_cached`]) and every [`search`]
//! strategy takes *any* `&mut dyn Evaluator` and drives it through
//! [`Evaluator::evaluate_batch`].  Parallel evaluators fan batches
//! across the persistent worker pool ([`crate::util::pool`]):
//! [`SimEvaluator`] chunks a batch over every core, and
//! [`MultiDeviceEvaluator`] shards it across a fleet of per-device
//! evaluators.  Results are merged in submission order, so parallel and
//! multi-device runs are bit-identical to sequential ones — `cargo
//! bench --bench autotuner` reports configs/second for the scoped,
//! pooled, and multi-device paths.

pub mod evaluators;
pub mod search;

#[cfg(feature = "pjrt")]
pub use evaluators::PjrtEvaluator;
pub use evaluators::{BatchMode, MultiDeviceEvaluator, SimEvaluator};
pub use search::Strategy;

use std::time::Instant;

use crate::cache::{entry_now, TuningCache};
use crate::config::{Config, ConfigSpace};
use crate::platform::model::InvalidConfig;
use crate::workload::Workload;

/// Anything that can attach a latency to a configuration.
///
/// `fidelity` ∈ (0, 1] lets multi-fidelity searches (successive halving)
/// ask for cheaper, noisier measurements; evaluators may ignore it.
pub trait Evaluator {
    /// Stable platform identifier — part of persistent cache keys, so
    /// it must only change when tuning results stop being comparable.
    fn name(&self) -> String;

    /// Evaluate one configuration at full fidelity.
    fn evaluate(&mut self, cfg: &Config) -> Result<f64, InvalidConfig> {
        self.evaluate_fidelity(cfg, 1.0)
    }

    /// Evaluate one configuration at the given measurement fidelity.
    fn evaluate_fidelity(&mut self, cfg: &Config, fidelity: f64) -> Result<f64, InvalidConfig>;

    /// Evaluate a batch of configurations, returning results in
    /// submission order (`out[i]` belongs to `cfgs[i]`).
    ///
    /// The default implementation is sequential, so evaluators that
    /// cannot parallelize — `PjrtEvaluator`'s PJRT handles are not
    /// `Send` — work unchanged.  Parallel evaluators override this and
    /// fan the batch across the worker pool (or a device fleet); because
    /// the contract fixes the output *order*, callers cannot observe the
    /// difference except in wall-clock time.
    fn evaluate_batch(
        &mut self,
        cfgs: &[Config],
        fidelity: f64,
    ) -> Vec<Result<f64, InvalidConfig>> {
        cfgs.iter().map(|c| self.evaluate_fidelity(c, fidelity)).collect()
    }
}

/// One tuning run's outcome.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// The fastest valid configuration found.
    pub best: Config,
    /// Measured/modeled latency of [`TuneOutcome::best`], µs.
    pub best_latency_us: f64,
    /// Configurations actually evaluated (cache-miss cost of the run).
    pub evaluated: usize,
    /// Configurations rejected as invalid on this platform.
    pub invalid: usize,
    /// (config fingerprint, latency) pairs in evaluation order;
    /// `None` = invalid.  Fingerprints, not configs: the log exists for
    /// counting/spread analysis, and cloning hundreds of `BTreeMap`s
    /// per run was pure overhead (only `best` needs the full config).
    pub history: Vec<(u64, Option<f64>)>,
    /// Wall-clock duration of the tuning run, seconds.
    pub wall_seconds: f64,
    /// True when the result was served from the persistent cache.
    pub from_cache: bool,
}

impl TuneOutcome {
    /// Latency spread across valid evaluations (paper §Q3 reports ~20x
    /// for complex kernels).
    pub fn spread(&self) -> Option<f64> {
        let valid: Vec<f64> = self.history.iter().filter_map(|(_, l)| *l).collect();
        if valid.is_empty() {
            return None;
        }
        let best = valid.iter().cloned().fold(f64::INFINITY, f64::min);
        let worst = valid.iter().cloned().fold(0.0f64, f64::max);
        Some(worst / best)
    }
}

/// Run `strategy` over `space` for `workload` using `eval`.
pub fn tune(
    space: &ConfigSpace,
    workload: &Workload,
    eval: &mut dyn Evaluator,
    strategy: &Strategy,
    seed: u64,
) -> Option<TuneOutcome> {
    let t0 = Instant::now();
    let mut rec = search::Recorder::default();
    strategy.run(space, workload, eval, seed, &mut rec);
    let (best, best_latency_us) = rec.best()?;
    Some(TuneOutcome {
        best,
        best_latency_us,
        evaluated: rec.len(),
        invalid: rec.invalid,
        history: rec.evals,
        wall_seconds: t0.elapsed().as_secs_f64(),
        from_cache: false,
    })
}

/// Model-guided (transfer) tuning: rank the whole space with a cheap
/// *prior* evaluator (e.g. an analytical platform model), then measure
/// only the `top_k` most promising configurations on the expensive
/// *target* evaluator (e.g. real PJRT execution).
///
/// This is the practical middle road between the paper's 24 h exhaustive
/// budget and heuristic-only dispatch: the prior prunes the space by an
/// order of magnitude, the target keeps the decision empirical.
pub fn tune_guided(
    space: &ConfigSpace,
    workload: &Workload,
    prior: &mut dyn Evaluator,
    target: &mut dyn Evaluator,
    top_k: usize,
) -> Option<TuneOutcome> {
    let t0 = Instant::now();
    // Rank by prior (invalid-on-prior configs go last, not dropped: the
    // prior is a model, not ground truth).  The ranking pass streams
    // through the batch API so a parallel prior uses every core.
    let configs: Vec<Config> = space.enumerate(workload).collect();
    let mut priors: Vec<Option<f64>> = Vec::with_capacity(configs.len());
    for chunk in configs.chunks(search::EVAL_BATCH) {
        priors.extend(prior.evaluate_batch(chunk, 1.0).into_iter().map(|r| r.ok()));
    }
    let mut ranked: Vec<(Config, Option<f64>)> = configs.into_iter().zip(priors).collect();

    // Total order: prior-score ties (common when the prior ignores a
    // parameter) break on the config fingerprint, so the measured
    // top-k set is pinned regardless of `select_nth_unstable_by`'s
    // unspecified ordering among equals.
    fn by_prior(a: &(Config, Option<f64>), b: &(Config, Option<f64>)) -> std::cmp::Ordering {
        let primary = match (a.1, b.1) {
            (Some(x), Some(y)) => x.total_cmp(&y),
            (Some(_), None) => std::cmp::Ordering::Less,
            (None, Some(_)) => std::cmp::Ordering::Greater,
            (None, None) => std::cmp::Ordering::Equal,
        };
        primary.then_with(|| a.0.fingerprint().cmp(&b.0.fingerprint()))
    }

    // Only top_k configs are ever measured, so an O(n) partial selection
    // replaces the old full O(n log n) sort of the entire ranked space;
    // only the k survivors are sorted (for measurement order).
    let k = top_k.max(1).min(ranked.len());
    if k < ranked.len() {
        ranked.select_nth_unstable_by(k - 1, by_prior);
        ranked.truncate(k);
    }
    ranked.sort_by(by_prior);

    // Measure the survivors through a Recorder: same bookkeeping
    // (fingerprint history, invalid count, running best) as every
    // search strategy.
    let mut rec = search::Recorder::default();
    for (cfg, _) in ranked {
        rec.eval(target, &cfg, 1.0);
    }
    let (best, best_latency_us) = rec.best()?;
    Some(TuneOutcome {
        best,
        best_latency_us,
        evaluated: rec.len(),
        invalid: rec.invalid,
        history: rec.evals,
        wall_seconds: t0.elapsed().as_secs_f64(),
        from_cache: false,
    })
}

/// Cache-aware tuning (Q4.3): return a reusable cached result when the
/// platform/space fingerprints match, otherwise tune and persist.
///
/// The space component of the cache key is
/// [`ConfigSpace::fingerprint_key`] — a stable FNV-1a digest of the
/// space definition (name, parameters, choices, constraint *names*) —
/// so edits to parameters or choices invalidate old entries, not just
/// cardinality changes.  Constraint *bodies* are closures and cannot be
/// hashed, so a hit is additionally re-validated with
/// [`ConfigSpace::contains`]; a cached winner the current space rejects
/// falls through to a fresh tune instead of being served.
pub fn tune_cached(
    cache: &mut TuningCache,
    space: &ConfigSpace,
    workload: &Workload,
    eval: &mut dyn Evaluator,
    strategy: &Strategy,
    seed: u64,
) -> Option<TuneOutcome> {
    let platform = eval.name();
    let space_fp = space.fingerprint_key();
    if let Some(hit) = cache.get(workload, &platform, &space_fp) {
        if let Some(best) = hit.config() {
            if space.contains(&best, workload) {
                return Some(TuneOutcome {
                    best,
                    best_latency_us: hit.latency_us,
                    evaluated: 0,
                    invalid: hit.invalid,
                    history: Vec::new(),
                    wall_seconds: 0.0,
                    from_cache: true,
                });
            }
        }
        // Unparseable or no-longer-valid entry: re-tune and overwrite.
    }
    let outcome = tune(space, workload, eval, strategy, seed)?;
    cache.put(
        workload,
        entry_now(
            &outcome.best,
            outcome.best_latency_us,
            outcome.evaluated,
            outcome.invalid,
            &platform,
            &space_fp,
            outcome.wall_seconds,
        ),
    );
    Some(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spaces;
    use crate::kernels::baselines::HAND_TUNED;
    use crate::platform::SimGpu;

    fn setup() -> (ConfigSpace, Workload, SimEvaluator) {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        (space, w, eval)
    }

    #[test]
    fn exhaustive_finds_global_optimum() {
        let (space, w, mut eval) = setup();
        let out = tune(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        // Cross-check against direct enumeration.
        let gpu = SimGpu::a100();
        let best_direct = space
            .enumerate(&w)
            .filter_map(|c| gpu.latency_us(&c, &w, &HAND_TUNED).ok())
            .fold(f64::INFINITY, f64::min);
        assert!((out.best_latency_us - best_direct).abs() < 1e-9);
        assert!(out.evaluated > 400);
    }

    #[test]
    fn random_is_reproducible_per_seed() {
        let (space, w, mut eval) = setup();
        let a = tune(&space, &w, &mut eval, &Strategy::Random { budget: 50 }, 7).unwrap();
        let b = tune(&space, &w, &mut eval, &Strategy::Random { budget: 50 }, 7).unwrap();
        assert_eq!(a.best, b.best);
        let c = tune(&space, &w, &mut eval, &Strategy::Random { budget: 50 }, 8).unwrap();
        // different seed may find a different best (not asserted), but
        // must still return a valid config
        assert!(space.contains(&c.best, &w));
    }

    #[test]
    fn all_strategies_return_valid_configs() {
        let (space, w, mut eval) = setup();
        for strat in [
            Strategy::Exhaustive,
            Strategy::Random { budget: 40 },
            Strategy::HillClimb { restarts: 3, budget: 200 },
            Strategy::Anneal { budget: 150, t0: 2.0, alpha: 0.95 },
            Strategy::SuccessiveHalving { initial: 32, eta: 2 },
        ] {
            let out = tune(&space, &w, &mut eval, &strat, 3)
                .unwrap_or_else(|| panic!("{strat:?} found nothing"));
            assert!(space.contains(&out.best, &w), "{strat:?} returned invalid config");
            assert!(out.best_latency_us > 0.0);
        }
    }

    #[test]
    fn local_search_competitive_with_exhaustive() {
        let (space, w, mut eval) = setup();
        let ex = tune(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        let hc = tune(&space, &w, &mut eval, &Strategy::HillClimb { restarts: 5, budget: 400 }, 11).unwrap();
        assert!(
            hc.best_latency_us <= ex.best_latency_us * 1.3,
            "hill climb {:.1}us vs exhaustive {:.1}us",
            hc.best_latency_us,
            ex.best_latency_us
        );
        assert!(hc.evaluated < ex.evaluated, "local search should be cheaper");
    }

    #[test]
    fn tune_cached_hits_second_time() {
        let (space, w, mut eval) = setup();
        let mut cache = TuningCache::ephemeral();
        let first = tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Random { budget: 30 }, 1).unwrap();
        assert!(!first.from_cache);
        let second = tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Random { budget: 30 }, 1).unwrap();
        assert!(second.from_cache);
        assert_eq!(second.best, first.best);
        assert_eq!(second.evaluated, 0);
    }

    #[test]
    fn tune_cached_misses_when_space_definition_changes() {
        // A space with the same name and cardinality but different
        // choices must NOT reuse the entry (the old name#cardinality
        // fingerprint could not tell these apart).
        let w = Workload::llama3_attention(8, 1024);
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut cache = TuningCache::ephemeral();
        let s1 = ConfigSpace::new("s")
            .param("BLOCK_M", &[32, 64])
            .param("BLOCK_N", &[32, 64])
            .param("num_warps", &[2, 4])
            .param("num_stages", &[1, 2]);
        let s2 = ConfigSpace::new("s")
            .param("BLOCK_M", &[64, 128])
            .param("BLOCK_N", &[32, 64])
            .param("num_warps", &[2, 4])
            .param("num_stages", &[1, 2]);
        assert_eq!(s1.cardinality(), s2.cardinality());
        let first = tune_cached(&mut cache, &s1, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(!first.from_cache);
        let second = tune_cached(&mut cache, &s2, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(!second.from_cache, "changed choices must invalidate the cache");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn tune_cached_revalidates_hit_against_current_space() {
        // Constraint *bodies* are closures and not part of the space
        // fingerprint, so a predicate change can leave a stale entry
        // under a matching key: the hit must be re-validated, not
        // served blindly.
        let w = Workload::llama3_attention(8, 1024);
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut cache = TuningCache::ephemeral();
        let space = ConfigSpace::new("reval")
            .param("BLOCK_M", &[32, 64])
            .param("BLOCK_N", &[32, 64])
            .param("num_warps", &[4])
            .param("num_stages", &[1])
            .constraint("block_m_bound", |c, _| c.req("BLOCK_M") <= 32);
        let stale = Config::new(&[
            ("BLOCK_M", 64), // violates the (tightened) constraint
            ("BLOCK_N", 32),
            ("num_warps", 4),
            ("num_stages", 1),
        ]);
        cache.put(
            &w,
            entry_now(&stale, 1.0, 10, 0, &eval.name(), &space.fingerprint_key(), 0.1),
        );
        let out = tune_cached(&mut cache, &space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(!out.from_cache, "a no-longer-valid cached winner must not be served");
        assert!(space.contains(&out.best, &w));
    }

    #[test]
    fn guided_tuning_prunes_but_stays_close_to_exhaustive() {
        // Prior = hand-tuned model, target = triton-codegen model with
        // a different efficiency surface: the prior's ranking transfers.
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target =
            SimEvaluator::new(SimGpu::a100(), w, crate::kernels::baselines::TRITON_NVIDIA);
        let guided = tune_guided(&space, &w, &mut prior, &mut target, 20).unwrap();
        let exhaustive = tune(&space, &w, &mut target, &Strategy::Exhaustive, 0).unwrap();
        assert!(guided.evaluated <= 20);
        assert!(
            guided.best_latency_us <= exhaustive.best_latency_us * 1.10,
            "guided {:.1}us vs exhaustive {:.1}us",
            guided.best_latency_us,
            exhaustive.best_latency_us
        );
    }

    #[test]
    fn guided_tuning_cross_platform_prior_still_works() {
        // Even a *wrong-platform* prior (A100 model ranking for an MI250
        // target) finds a decent config with k=60 — but the same budget
        // of native random search is the fair comparison; the test just
        // guards the mechanism, not the transfer quality.
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(
            crate::platform::SimGpu::mi250(),
            w,
            crate::kernels::baselines::TRITON_AMD,
        );
        let guided = tune_guided(&space, &w, &mut prior, &mut target, 60);
        assert!(guided.is_some());
    }

    #[test]
    fn guided_top_k_larger_than_space_measures_everything() {
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let n_valid = space.enumerate(&w).count();
        let guided = tune_guided(&space, &w, &mut prior, &mut target, n_valid + 100).unwrap();
        assert_eq!(guided.evaluated, n_valid);
    }

    #[test]
    fn invalid_configs_are_counted_not_fatal() {
        let (space, w, mut eval) = setup();
        let out = tune(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        // The A100 rejects big-staging configs (smem) — some must appear.
        assert!(out.invalid > 0);
        assert_eq!(out.evaluated, out.history.len());
    }

    #[test]
    fn spread_matches_paper_scale() {
        let (space, w, mut eval) = setup();
        let out = tune(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(out.spread().unwrap() > 5.0);
    }
}
