//! The autotuner: empirical search over kernel-configuration spaces.
//!
//! Addresses the paper's gap **Q4.2** (*"Autotuning needs to leverage
//! advanced search methods to reduce autotuning time and reliably
//! identify optimal configurations"*): several [`search`] strategies
//! share one [`Evaluator`] abstraction, so the same engine tunes against
//! the analytical platform models (simulated A100/MI250) *and* against
//! real PJRT-CPU executions of the AOT artifacts.
//!
//! **The public API is [`TuningSession`]** ([`session`]): one builder
//! composes everything that used to be five diverging free functions —
//! strategy and seed, persistent caching ([`TuningSession::cache`]),
//! model-guided pruning ([`TuningSession::guided`]), device sharding
//! ([`TuningSession::devices`]), heterogeneous fleets
//! ([`TuningSession::fleet`]), session budgets ([`Budget`]) and live
//! progress observers ([`Observer`]).  The five legacy free functions
//! (`tune`, `tune_guided`, `tune_cached`, `tune_fleet`,
//! `tune_fleet_cached`) spent one release as `#[deprecated]` wrappers
//! and have been **removed**; their builder spellings are documented in
//! `docs/ARCHITECTURE.md` §2b, and `tests/parallel_equiv.rs` pins the
//! builder's own spellings (defaults, option order, cached-vs-plain)
//! bit-identical to each other per strategy × seed.
//!
//! Unlike the Triton built-in autotuner the paper critiques (§Q3), tuning
//! here is (a) cached persistently via [`crate::cache`], (b) composable
//! with background execution ([`crate::serving::executor`], on any
//! serving backend), and
//! (c) explicit about invalid configurations (they are counted, not
//! hidden).
//!
//! **Throughput** (the paper's §Q4.2 time budget): every tuning path and
//! every [`search`] strategy takes *any* `&mut dyn Evaluator` and drives
//! it through [`Evaluator::evaluate_batch`].  Parallel evaluators fan
//! batches across the persistent worker pool ([`crate::util::pool`]):
//! [`SimEvaluator`] chunks a batch over every core, and
//! [`MultiDeviceEvaluator`] shards it across a fleet of per-device
//! evaluators.  Results are merged in submission order, so parallel and
//! multi-device runs are bit-identical to sequential ones — `cargo
//! bench --bench autotuner` reports configs/second for the scoped,
//! pooled, and multi-device paths.
//!
//! **Portability** (the paper's cross-vendor thesis):
//! [`TuningSession::fleet`] runs one search over a *heterogeneous* fleet
//! in measure-everywhere mode — every candidate is measured on every
//! distinct device platform and each platform keeps its own recorder —
//! returning a per-platform argmin ([`FleetOutcome`]) plus the
//! portability report ([`PortableBest`]: winner overlap and the cost of
//! shipping one config fleet-wide).  `portatune tune --fleet a100,mi250`
//! is the CLI face of this mode.

pub mod evaluators;
pub mod search;
pub mod session;

#[cfg(feature = "pjrt")]
pub use evaluators::PjrtEvaluator;
pub use evaluators::{BatchMode, ChaosEvaluator, MultiDeviceEvaluator, SimEvaluator};
pub use search::{EvalRecord, Observer, Strategy};
pub use session::{Budget, SessionOutcome, TuningSession};

use crate::config::Config;
use crate::platform::model::InvalidConfig;

/// One output cell of a batch evaluation: `None` until the evaluator
/// fills it, then the measurement (or invalidity) for the config at the
/// same index.  Callers keep a slab of these alive across batches so
/// the hot loop stops allocating a fresh `Vec` per rung.
pub type BatchSlot = Option<Result<f64, InvalidConfig>>;

/// Anything that can attach a latency to a configuration.
///
/// `fidelity` ∈ (0, 1] lets multi-fidelity searches (successive halving)
/// ask for cheaper, noisier measurements; evaluators may ignore it.
pub trait Evaluator {
    /// Stable platform identifier — part of persistent cache keys, so
    /// it must only change when tuning results stop being comparable.
    fn name(&self) -> String;

    /// Evaluate one configuration at full fidelity.
    fn evaluate(&mut self, cfg: &Config) -> Result<f64, InvalidConfig> {
        self.evaluate_fidelity(cfg, 1.0)
    }

    /// Evaluate one configuration at the given measurement fidelity.
    fn evaluate_fidelity(&mut self, cfg: &Config, fidelity: f64) -> Result<f64, InvalidConfig>;

    /// Evaluate a batch of configurations, returning results in
    /// submission order (`out[i]` belongs to `cfgs[i]`).
    ///
    /// The default implementation is sequential, so evaluators that
    /// cannot parallelize — `PjrtEvaluator`'s PJRT handles are not
    /// `Send` — work unchanged.  Parallel evaluators override this and
    /// fan the batch across the worker pool (or a device fleet); because
    /// the contract fixes the output *order*, callers cannot observe the
    /// difference except in wall-clock time.
    fn evaluate_batch(
        &mut self,
        cfgs: &[Config],
        fidelity: f64,
    ) -> Vec<Result<f64, InvalidConfig>> {
        let mut out: Vec<BatchSlot> = vec![None; cfgs.len()];
        self.evaluate_batch_into(cfgs, fidelity, &mut out);
        out.into_iter()
            .map(|slot| slot.expect("evaluator left a batch slot unfilled"))
            .collect()
    }

    /// Evaluate a batch into a caller-provided slab: `out[i]` receives
    /// `Some(result)` for `cfgs[i]`.  `out` must be at least as long as
    /// `cfgs` (extra slots are left untouched); pre-existing contents of
    /// the first `cfgs.len()` slots are overwritten, so callers reuse
    /// one slab across rungs/batches without clearing it.
    ///
    /// This is the zero-alloc spelling of [`Evaluator::evaluate_batch`]
    /// and carries the same ordering contract.  The default is
    /// sequential; parallel evaluators override it and the `Vec` form
    /// above is derived from it, so overriding one method keeps both
    /// consistent.
    fn evaluate_batch_into(&mut self, cfgs: &[Config], fidelity: f64, out: &mut [BatchSlot]) {
        assert!(out.len() >= cfgs.len(), "output slab shorter than batch");
        for (c, slot) in cfgs.iter().zip(out.iter_mut()) {
            *slot = Some(self.evaluate_fidelity(c, fidelity));
        }
    }
}

/// One tuning run's outcome.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// The fastest valid configuration found.
    pub best: Config,
    /// Measured/modeled latency of [`TuneOutcome::best`], µs.
    pub best_latency_us: f64,
    /// Configurations actually evaluated (cache-miss cost of the run).
    pub evaluated: usize,
    /// Configurations rejected as invalid on this platform.
    pub invalid: usize,
    /// The evaluation log in submission order ([`EvalRecord`]:
    /// fingerprint, latency, fidelity).  Fingerprints, not configs: the
    /// log exists for counting/spread analysis, and cloning hundreds of
    /// `BTreeMap`s per run was pure overhead (only `best` needs the
    /// full config).  Multi-fidelity runs compact the log per rung
    /// (superseded reduced-fidelity records are dropped), so
    /// `history.len()` may be less than [`TuneOutcome::evaluated`];
    /// every full-fidelity record always survives, so
    /// [`TuneOutcome::spread`] is unaffected.
    pub history: Vec<EvalRecord>,
    /// Wall-clock duration of the tuning run, seconds.
    pub wall_seconds: f64,
    /// True when the result was served from the persistent cache.
    pub from_cache: bool,
}

impl TuneOutcome {
    /// Latency spread across valid **full-fidelity** evaluations (paper
    /// §Q3 reports ~20x for complex kernels).  Reduced-fidelity rung
    /// measurements are excluded: latencies measured at different
    /// fidelities are not comparable, and mixing them silently inflated
    /// (or deflated) the spread whenever successive halving ran.
    pub fn spread(&self) -> Option<f64> {
        let valid: Vec<f64> = self
            .history
            .iter()
            .filter(|r| r.is_full_fidelity())
            .filter_map(|r| r.latency_us)
            .collect();
        if valid.is_empty() {
            return None;
        }
        let best = valid.iter().cloned().fold(f64::INFINITY, f64::min);
        let worst = valid.iter().cloned().fold(0.0f64, f64::max);
        Some(worst / best)
    }
}

/// Outcome of a fleet ("measure everywhere") tuning run: one tuning
/// result per *distinct platform* in the fleet, plus the paper's
/// cross-vendor portability analysis.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    /// `(platform fingerprint, outcome)` per distinct platform, in
    /// [`MultiDeviceEvaluator::platforms`] (sorted-name) order.  Each
    /// outcome is bit-identical to tuning that platform alone with a
    /// sequential evaluator (same strategy, seed, and space).
    pub outcomes: Vec<(String, TuneOutcome)>,
    /// Number of distinct winning configurations across the platforms.
    /// 1 means a single config wins everywhere (perfect winner overlap);
    /// equal to the platform count means every platform wants its own
    /// kernel — the paper's argument for per-platform multi-versioning.
    pub distinct_winners: usize,
    /// The portable compromise config — chosen from all shared
    /// candidates (exhaustive/random) or from the cross-measured
    /// per-platform winners (adaptive strategies).  `None` when no
    /// measured candidate is valid on every platform, or when the
    /// outcomes came from the cache (which stores winners only).
    pub portable: Option<PortableBest>,
    /// Wall-clock duration of the whole fleet run, seconds.
    pub wall_seconds: f64,
    /// True when every platform outcome was served from the cache.  A
    /// *partial* cache hit (adaptive strategies reuse cached platforms
    /// and re-tune the rest) reports `false` here, with the per-platform
    /// [`TuneOutcome::from_cache`] flags telling the two groups apart.
    pub from_cache: bool,
}

impl FleetOutcome {
    /// The outcome for one platform, if it is part of the fleet.
    pub fn platform(&self, name: &str) -> Option<&TuneOutcome> {
        self.outcomes.iter().find(|(p, _)| p == name).map(|(_, o)| o)
    }
}

/// The cross-platform compromise: among configurations measured valid at
/// full fidelity on *every* platform of the fleet, the one minimizing
/// the worst-case slowdown versus each platform's own best (ties broken
/// by config fingerprint, so the selection is deterministic).
///
/// This is the "one portable kernel" column of the paper's cross-vendor
/// table: how much each platform gives up if a single configuration
/// must serve the whole fleet.
#[derive(Debug, Clone)]
pub struct PortableBest {
    /// The portable configuration.
    pub config: Config,
    /// Full-fidelity latency of [`PortableBest::config`] on each
    /// platform, aligned with [`FleetOutcome::outcomes`].
    pub latency_us: Vec<f64>,
    /// Per-platform slowdown `latency_us[i] / platform i's best`,
    /// aligned with [`FleetOutcome::outcomes`].  Always ≥ 1 for the
    /// shared-trajectory strategies (the platform best is the minimum
    /// over the same candidate set); for budgeted adaptive strategies a
    /// value below 1 means another platform's winner beats the config
    /// this platform's own search settled on.
    pub slowdown: Vec<f64>,
    /// The minimized objective: the largest entry of
    /// [`PortableBest::slowdown`].
    pub worst_slowdown: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{entry_now, TuningCache};
    use crate::config::{spaces, ConfigSpace};
    use crate::kernels::baselines::HAND_TUNED;
    use crate::platform::SimGpu;
    use crate::workload::Workload;

    /// Builder shorthand for the plain solo tune used throughout.
    fn tune_b(
        space: &ConfigSpace,
        w: &Workload,
        eval: &mut dyn Evaluator,
        strategy: &Strategy,
        seed: u64,
    ) -> Option<TuneOutcome> {
        TuningSession::new(space, w)
            .strategy(strategy.clone())
            .seed(seed)
            .evaluator(eval)
            .run()
            .and_then(SessionOutcome::into_solo)
    }

    /// Builder shorthand for the cached solo tune.
    fn tune_cached_b(
        cache: &mut TuningCache,
        space: &ConfigSpace,
        w: &Workload,
        eval: &mut dyn Evaluator,
        strategy: &Strategy,
        seed: u64,
    ) -> Option<TuneOutcome> {
        TuningSession::new(space, w)
            .strategy(strategy.clone())
            .seed(seed)
            .cache(cache)
            .evaluator(eval)
            .run()
            .and_then(SessionOutcome::into_solo)
    }

    /// Builder shorthand for the fleet tune.
    fn tune_fleet_b(
        space: &ConfigSpace,
        w: &Workload,
        fleet: &mut MultiDeviceEvaluator,
        strategy: &Strategy,
        seed: u64,
    ) -> Option<FleetOutcome> {
        TuningSession::new(space, w)
            .strategy(strategy.clone())
            .seed(seed)
            .fleet(fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
    }

    fn setup() -> (ConfigSpace, Workload, SimEvaluator) {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        (space, w, eval)
    }

    #[test]
    fn exhaustive_finds_global_optimum() {
        let (space, w, mut eval) = setup();
        let out = tune_b(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        // Cross-check against direct enumeration.
        let gpu = SimGpu::a100();
        let best_direct = space
            .enumerate(&w)
            .filter_map(|c| gpu.latency_us(&c, &w, &HAND_TUNED).ok())
            .fold(f64::INFINITY, f64::min);
        assert!((out.best_latency_us - best_direct).abs() < 1e-9);
        assert!(out.evaluated > 400);
    }

    #[test]
    fn random_is_reproducible_per_seed() {
        let (space, w, mut eval) = setup();
        let a = tune_b(&space, &w, &mut eval, &Strategy::Random { budget: 50 }, 7).unwrap();
        let b = tune_b(&space, &w, &mut eval, &Strategy::Random { budget: 50 }, 7).unwrap();
        assert_eq!(a.best, b.best);
        let c = tune_b(&space, &w, &mut eval, &Strategy::Random { budget: 50 }, 8).unwrap();
        // different seed may find a different best (not asserted), but
        // must still return a valid config
        assert!(space.contains(&c.best, &w));
    }

    #[test]
    fn all_strategies_return_valid_configs() {
        let (space, w, mut eval) = setup();
        for strat in [
            Strategy::Exhaustive,
            Strategy::Random { budget: 40 },
            Strategy::HillClimb { restarts: 3, budget: 200 },
            Strategy::Anneal { budget: 150, t0: 2.0, alpha: 0.95 },
            Strategy::SuccessiveHalving { initial: 32, eta: 2 },
        ] {
            let out = tune_b(&space, &w, &mut eval, &strat, 3)
                .unwrap_or_else(|| panic!("{strat:?} found nothing"));
            assert!(space.contains(&out.best, &w), "{strat:?} returned invalid config");
            assert!(out.best_latency_us > 0.0);
        }
    }

    #[test]
    fn local_search_competitive_with_exhaustive() {
        let (space, w, mut eval) = setup();
        let ex = tune_b(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        let hc =
            tune_b(&space, &w, &mut eval, &Strategy::HillClimb { restarts: 5, budget: 400 }, 11)
                .unwrap();
        assert!(
            hc.best_latency_us <= ex.best_latency_us * 1.3,
            "hill climb {:.1}us vs exhaustive {:.1}us",
            hc.best_latency_us,
            ex.best_latency_us
        );
        assert!(hc.evaluated < ex.evaluated, "local search should be cheaper");
    }

    #[test]
    fn tune_cached_hits_second_time() {
        let (space, w, mut eval) = setup();
        let mut cache = TuningCache::ephemeral();
        let first =
            tune_cached_b(&mut cache, &space, &w, &mut eval, &Strategy::Random { budget: 30 }, 1)
                .unwrap();
        assert!(!first.from_cache);
        let second =
            tune_cached_b(&mut cache, &space, &w, &mut eval, &Strategy::Random { budget: 30 }, 1)
                .unwrap();
        assert!(second.from_cache);
        assert_eq!(second.best, first.best);
        assert_eq!(second.evaluated, 0);
    }

    #[test]
    fn tune_cached_misses_when_space_definition_changes() {
        // A space with the same name and cardinality but different
        // choices must NOT reuse the entry (the old name#cardinality
        // fingerprint could not tell these apart).
        let w = Workload::llama3_attention(8, 1024);
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut cache = TuningCache::ephemeral();
        let s1 = ConfigSpace::new("s")
            .param("BLOCK_M", &[32, 64])
            .param("BLOCK_N", &[32, 64])
            .param("num_warps", &[2, 4])
            .param("num_stages", &[1, 2]);
        let s2 = ConfigSpace::new("s")
            .param("BLOCK_M", &[64, 128])
            .param("BLOCK_N", &[32, 64])
            .param("num_warps", &[2, 4])
            .param("num_stages", &[1, 2]);
        assert_eq!(s1.cardinality(), s2.cardinality());
        let first = tune_cached_b(&mut cache, &s1, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(!first.from_cache);
        let second =
            tune_cached_b(&mut cache, &s2, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(!second.from_cache, "changed choices must invalidate the cache");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn tune_cached_revalidates_hit_against_current_space() {
        // Constraint *bodies* are closures and not part of the space
        // fingerprint, so a predicate change can leave a stale entry
        // under a matching key: the hit must be re-validated, not
        // served blindly.
        let w = Workload::llama3_attention(8, 1024);
        let mut eval = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut cache = TuningCache::ephemeral();
        let space = ConfigSpace::new("reval")
            .param("BLOCK_M", &[32, 64])
            .param("BLOCK_N", &[32, 64])
            .param("num_warps", &[4])
            .param("num_stages", &[1])
            .constraint("block_m_bound", |c, _| c.req("BLOCK_M") <= 32);
        let stale = Config::new(&[
            ("BLOCK_M", 64), // violates the (tightened) constraint
            ("BLOCK_N", 32),
            ("num_warps", 4),
            ("num_stages", 1),
        ]);
        cache.put(
            &w,
            entry_now(&stale, 1.0, 10, 0, &eval.name(), &space.fingerprint_key(), 0.1),
        );
        let out = tune_cached_b(&mut cache, &space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(!out.from_cache, "a no-longer-valid cached winner must not be served");
        assert!(space.contains(&out.best, &w));
    }

    #[test]
    fn guided_tuning_prunes_but_stays_close_to_exhaustive() {
        // Prior = hand-tuned model, target = triton-codegen model with
        // a different efficiency surface: the prior's ranking transfers.
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target =
            SimEvaluator::new(SimGpu::a100(), w, crate::kernels::baselines::TRITON_NVIDIA);
        let guided = TuningSession::new(&space, &w)
            .guided(&mut prior, 20)
            .evaluator(&mut target)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        let exhaustive = tune_b(&space, &w, &mut target, &Strategy::Exhaustive, 0).unwrap();
        assert!(guided.evaluated <= 20);
        assert!(
            guided.best_latency_us <= exhaustive.best_latency_us * 1.10,
            "guided {:.1}us vs exhaustive {:.1}us",
            guided.best_latency_us,
            exhaustive.best_latency_us
        );
    }

    #[test]
    fn guided_tuning_cross_platform_prior_still_works() {
        // Even a *wrong-platform* prior (A100 model ranking for an MI250
        // target) finds a decent config with k=60 — but the same budget
        // of native random search is the fair comparison; the test just
        // guards the mechanism, not the transfer quality.
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(
            crate::platform::SimGpu::mi250(),
            w,
            crate::kernels::baselines::TRITON_AMD,
        );
        let guided = TuningSession::new(&space, &w)
            .guided(&mut prior, 60)
            .evaluator(&mut target)
            .run();
        assert!(guided.is_some());
    }

    #[test]
    fn guided_top_k_larger_than_space_measures_everything() {
        let (space, w, _) = setup();
        let mut prior = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut target = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let n_valid = space.enumerate(&w).count();
        let guided = TuningSession::new(&space, &w)
            .guided(&mut prior, n_valid + 100)
            .evaluator(&mut target)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        assert_eq!(guided.evaluated, n_valid);
    }

    #[test]
    fn invalid_configs_are_counted_not_fatal() {
        let (space, w, mut eval) = setup();
        let out = tune_b(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        // The A100 rejects big-staging configs (smem) — some must appear.
        assert!(out.invalid > 0);
        assert_eq!(out.evaluated, out.history.len());
    }

    #[test]
    fn spread_matches_paper_scale() {
        let (space, w, mut eval) = setup();
        let out = tune_b(&space, &w, &mut eval, &Strategy::Exhaustive, 0).unwrap();
        assert!(out.spread().unwrap() > 5.0);
    }

    #[test]
    fn spread_ignores_reduced_fidelity_measurements() {
        // A history mixing rung fidelities must compute the spread over
        // the full-fidelity entries only: the 1 µs low-fidelity sample
        // below would otherwise fake a 100x spread.
        let out = TuneOutcome {
            best: Config::new(&[("a", 1)]),
            best_latency_us: 10.0,
            evaluated: 3,
            invalid: 0,
            history: vec![
                EvalRecord { fingerprint: 1, latency_us: Some(1.0), fidelity: 0.25 },
                EvalRecord { fingerprint: 2, latency_us: Some(10.0), fidelity: 1.0 },
                EvalRecord { fingerprint: 3, latency_us: Some(100.0), fidelity: 1.0 },
            ],
            wall_seconds: 0.0,
            from_cache: false,
        };
        assert_eq!(out.spread(), Some(10.0));
    }

    fn fleet_a100_mi250() -> MultiDeviceEvaluator {
        let w = Workload::llama3_attention(8, 1024);
        MultiDeviceEvaluator::new(vec![
            SimEvaluator::new(SimGpu::a100(), w, crate::kernels::baselines::TRITON_NVIDIA),
            SimEvaluator::new(SimGpu::mi250(), w, crate::kernels::baselines::TRITON_AMD),
        ])
    }

    #[test]
    fn tune_fleet_matches_solo_per_platform_winners() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut fleet = fleet_a100_mi250();
        let out = tune_fleet_b(&space, &w, &mut fleet, &Strategy::Exhaustive, 0).unwrap();
        assert_eq!(out.outcomes.len(), 2);
        for (platform, got) in &out.outcomes {
            let mut solo = fleet.platform_evaluator(platform).unwrap();
            let want = tune_b(&space, &w, &mut solo, &Strategy::Exhaustive, 0).unwrap();
            assert_eq!(got.best, want.best, "{platform}: winner differs from solo tune");
            assert_eq!(
                got.best_latency_us.to_bits(),
                want.best_latency_us.to_bits(),
                "{platform}: best latency differs from solo tune"
            );
            assert_eq!(got.evaluated, want.evaluated);
            assert_eq!(got.invalid, want.invalid);
        }
    }

    #[test]
    fn tune_fleet_portability_report_is_consistent() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut fleet = fleet_a100_mi250();
        let out = tune_fleet_b(&space, &w, &mut fleet, &Strategy::Exhaustive, 0).unwrap();
        assert!(out.distinct_winners >= 1 && out.distinct_winners <= 2);
        let pb = out.portable.as_ref().expect("exhaustive fleet must find a portable config");
        // The portable config is valid (in-space) and its slowdowns are
        // genuine ratios against each platform's best.
        assert!(space.contains(&pb.config, &w));
        assert_eq!(pb.latency_us.len(), out.outcomes.len());
        assert_eq!(pb.slowdown.len(), out.outcomes.len());
        let mut worst: f64 = 0.0;
        for ((lat, slow), (_, o)) in pb.latency_us.iter().zip(&pb.slowdown).zip(&out.outcomes) {
            assert!(*slow >= 1.0, "portable config cannot beat a platform's own best");
            assert!((slow - lat / o.best_latency_us).abs() < 1e-12);
            worst = worst.max(*slow);
        }
        assert_eq!(pb.worst_slowdown, worst);
        // If a single config wins everywhere, the portable best pays no
        // slowdown anywhere (the portable pick may be a latency-tied
        // twin of the winner, so compare objectives, not configs).
        if out.distinct_winners == 1 {
            assert!((pb.worst_slowdown - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn tune_fleet_counts_replicated_work() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut fleet = fleet_a100_mi250();
        let out = tune_fleet_b(&space, &w, &mut fleet, &Strategy::Exhaustive, 0).unwrap();
        let per_platform: usize = out.outcomes.iter().map(|(_, o)| o.evaluated).sum();
        let replicated: usize = fleet.utilization().iter().map(|u| u.replicated).sum();
        assert_eq!(replicated, per_platform, "every config measured on every platform");
    }

    #[test]
    fn tune_fleet_supports_adaptive_strategies_per_platform() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut fleet = fleet_a100_mi250();
        let out = tune_fleet_b(
            &space,
            &w,
            &mut fleet,
            &Strategy::SuccessiveHalving { initial: 32, eta: 2 },
            7,
        )
        .unwrap();
        for (platform, got) in &out.outcomes {
            let mut solo = fleet.platform_evaluator(platform).unwrap();
            let want = tune_b(
                &space,
                &w,
                &mut solo,
                &Strategy::SuccessiveHalving { initial: 32, eta: 2 },
                7,
            )
            .unwrap();
            assert_eq!(got.best, want.best, "{platform}: SHA winner differs from solo");
            assert_eq!(got.best_latency_us.to_bits(), want.best_latency_us.to_bits());
        }
        // The adaptive path cross-measures the per-platform winners, so
        // when a portable pick exists it must be one of those winners,
        // with one latency/slowdown per platform.
        if let Some(pb) = &out.portable {
            assert!(
                out.outcomes.iter().any(|(_, o)| o.best == pb.config),
                "adaptive portable pick must be one of the platform winners"
            );
            assert_eq!(pb.latency_us.len(), out.outcomes.len());
            assert_eq!(pb.slowdown.len(), out.outcomes.len());
            assert!(pb.worst_slowdown > 0.0);
            let max = pb.slowdown.iter().cloned().fold(0.0f64, f64::max);
            assert_eq!(pb.worst_slowdown, max);
        }
    }

    #[test]
    fn tune_fleet_cached_writes_per_platform_keys() {
        let w = Workload::llama3_attention(8, 1024);
        let space = spaces::attention_sim_space();
        let mut cache = TuningCache::ephemeral();
        let mut fleet = fleet_a100_mi250();
        let first = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .fleet(&mut fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
            .unwrap();
        assert!(!first.from_cache);
        assert_eq!(cache.len(), 2, "one entry per distinct platform");
        // A later SINGLE-platform cached tune hits the fleet's entry.
        for (platform, o) in &first.outcomes {
            let mut solo = fleet.platform_evaluator(platform).unwrap();
            let hit =
                tune_cached_b(&mut cache, &space, &w, &mut solo, &Strategy::Exhaustive, 0).unwrap();
            assert!(hit.from_cache, "{platform}: solo tune must reuse the fleet entry");
            assert_eq!(hit.best, o.best);
        }
        // And the fleet run itself hits when every platform is cached.
        let second = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .fleet(&mut fleet)
            .run()
            .and_then(SessionOutcome::into_fleet)
            .unwrap();
        assert!(second.from_cache);
        assert_eq!(second.distinct_winners, first.distinct_winners);
        for ((p1, o1), (p2, o2)) in first.outcomes.iter().zip(&second.outcomes) {
            assert_eq!(p1, p2);
            assert_eq!(o1.best, o2.best);
            assert_eq!(o2.evaluated, 0);
        }
    }

}
