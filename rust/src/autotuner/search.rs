//! Search strategies over configuration spaces.
//!
//! The paper's Q4.2 calls for "advanced search methods to reduce
//! autotuning time and reliably identify optimal configurations".
//! Implemented here:
//!
//! - [`Strategy::Exhaustive`] — the ground truth (what the 24 h budget in
//!   the paper's method buys);
//! - [`Strategy::Random`] — the classic cheap baseline;
//! - [`Strategy::HillClimb`] — restarted greedy local search over
//!   one-parameter neighbourhoods;
//! - [`Strategy::Anneal`] — simulated annealing (escapes the local optima
//!   hill-climbing gets stuck in);
//! - [`Strategy::SuccessiveHalving`] — multi-fidelity racing: evaluate
//!   many configs cheaply, promote the best survivors to full fidelity.
//!
//! Every strategy records through a [`Recorder`] so outcomes are
//! comparable (#evaluated, #invalid, best).  The recorder is
//! **fidelity-correct**: each log entry carries the fidelity it was
//! measured at, and only full-fidelity results may become `best` —
//! successive halving's cheap rung measurements can race configs but
//! never speak for the final latency (the survivor is re-confirmed at
//! fidelity 1.0).
//!
//! **Batched evaluation**: the strategies whose evaluation order does not
//! depend on earlier results (exhaustive, random, each successive-halving
//! rung) submit work through [`Evaluator::evaluate_batch`] so a parallel
//! evaluator can fan the batch across a worker pool.  Results are folded
//! back into the [`Recorder`] in submission order, which keeps the
//! evaluation history — and therefore `best()` and per-seed
//! reproducibility — bit-identical to sequential evaluation.  The
//! inherently sequential strategies (hill climb, annealing: every step
//! depends on the previous measurement) stay on the one-at-a-time path.
//!
//! **Observation and budgets** (the `TuningSession` plumbing): the
//! recorder is also where [`Observer`]s are threaded through — every
//! strategy reports progress (`on_eval` / `on_new_best` / `on_rung`)
//! simply by recording, so the CLI can stream a live tuning log and the
//! bench can count evaluations without re-parsing the history — and
//! where session-level budgets ([`crate::autotuner::Budget`]) are
//! enforced: an exhausted recorder refuses further evaluations (and
//! truncates in-flight batches deterministically), so every strategy
//! honors the cap without owning budget logic.  A recorder with no
//! budget behaves bit-identically to the pre-budget engine.

use std::collections::{HashMap, HashSet};
use std::time::Instant;

use super::evaluators::MultiDeviceEvaluator;
use super::{BatchSlot, Evaluator};
use crate::config::{Config, ConfigSpace};
use crate::util::rng::Rng;
use crate::workload::Workload;

/// How many configurations the batching strategies submit per
/// [`Evaluator::evaluate_batch`] call.  Large enough to amortize a
/// thread-pool dispatch across every worker, small enough to keep
/// streaming (lazy enumeration never materializes more than one batch).
pub const EVAL_BATCH: usize = 256;

/// Total order for prior-scored configurations, shared by the guided
/// and surrogate tuning paths: lower scores first, unscored (`None` —
/// the prior rejected the config) last, and score ties broken by the
/// config fingerprint.  The fingerprint tie-break matters: ties are
/// common when a prior ignores a parameter, and without a total order
/// the measured top-k *set* would depend on
/// `select_nth_unstable_by`'s unspecified ordering among equals.
pub(crate) fn rank_order(a: &(Config, Option<f64>), b: &(Config, Option<f64>)) -> std::cmp::Ordering {
    let primary = match (a.1, b.1) {
        (Some(x), Some(y)) => x.total_cmp(&y),
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => std::cmp::Ordering::Equal,
    };
    primary.then_with(|| a.0.fingerprint().cmp(&b.0.fingerprint()))
}

/// Floor for [`Strategy::SuccessiveHalving`]'s rung-0 fidelity.  The
/// rung schedule is computed in `f64` (the previous integer
/// `eta.pow(rungs - 1)` overflowed in debug builds for extreme
/// `eta`/`initial` combinations), and no rung is ever asked to measure
/// below this fidelity — cheaper measurements than this stop being
/// informative long before they stop being representable.
pub const MIN_SHA_FIDELITY: f64 = 1e-4;

/// Search strategy selector (all deterministic given a seed).
#[derive(Debug, Clone, PartialEq)]
pub enum Strategy {
    /// Measure every valid configuration (the ground truth).
    Exhaustive,
    /// `budget` distinct uniform samples.
    Random {
        /// Maximum number of evaluations.
        budget: usize,
    },
    /// Restarted steepest-descent over one-parameter neighbourhoods.
    HillClimb {
        /// Number of random restarts.
        restarts: usize,
        /// Maximum number of evaluations across all restarts.
        budget: usize,
    },
    /// Simulated annealing over the neighbourhood graph.
    Anneal {
        /// Maximum number of evaluations.
        budget: usize,
        /// Initial temperature.
        t0: f64,
        /// Per-step geometric cooling factor.
        alpha: f64,
    },
    /// Multi-fidelity racing: start `initial` configs cheap, promote the
    /// best `1/eta` fraction per rung.
    SuccessiveHalving {
        /// Rung-0 population size.
        initial: usize,
        /// Promotion ratio between rungs (≥ 2).
        eta: usize,
    },
}

impl Strategy {
    /// True for the strategies whose evaluation *order* is a pure
    /// function of (space, workload, seed) — never of measured
    /// latencies — so one trajectory can be shared across a whole
    /// fleet (exhaustive enumeration, seeded random sampling).  The
    /// adaptive strategies branch on latencies and must run once per
    /// platform.  This predicate is the single source of truth for the
    /// fleet-path routing; keep any new strategy's classification here.
    pub fn shared_trajectory(&self) -> bool {
        matches!(self, Strategy::Exhaustive | Strategy::Random { .. })
    }

    /// Compact human-readable identifier (used in reports and caches).
    pub fn label(&self) -> String {
        match self {
            Strategy::Exhaustive => "exhaustive".into(),
            Strategy::Random { budget } => format!("random({budget})"),
            Strategy::HillClimb { restarts, budget } => format!("hillclimb({restarts},{budget})"),
            Strategy::Anneal { budget, .. } => format!("anneal({budget})"),
            Strategy::SuccessiveHalving { initial, eta } => format!("sha({initial},{eta})"),
        }
    }
}

/// One logged evaluation: what was measured, what came back, and at
/// which fidelity.  Fidelity matters for correctness, not just
/// bookkeeping: latencies measured at different fidelities are not
/// comparable, so every consumer of the log ([`Recorder::best`],
/// [`crate::autotuner::TuneOutcome::spread`]) must filter on it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalRecord {
    /// Fingerprint of the evaluated [`Config`].
    pub fingerprint: u64,
    /// Measured/modeled latency in µs; `None` = invalid on this platform.
    pub latency_us: Option<f64>,
    /// Measurement fidelity in (0, 1]; 1.0 = a full-fidelity result.
    pub fidelity: f64,
}

impl EvalRecord {
    /// True when this is a trustworthy full-fidelity measurement.
    pub fn is_full_fidelity(&self) -> bool {
        self.fidelity >= 1.0
    }
}

/// Live view into a tuning run, threaded through [`Recorder`].
///
/// Observers are registered on a `TuningSession`
/// ([`crate::autotuner::TuningSession::observe`]) and receive events
/// from whichever strategy the session runs — the CLI streams progress
/// lines from them, the bench counts evaluations without re-parsing
/// [`crate::autotuner::TuneOutcome::history`].  All methods have
/// default no-op bodies, so an observer implements only what it needs.
///
/// Observers see events but cannot influence the search: every method
/// takes the event by reference and returns nothing, so an observed run
/// stays bit-identical to an unobserved one (pinned by
/// `tests/parallel_equiv.rs`).
pub trait Observer {
    /// One evaluation was folded into the log (valid or invalid).
    fn on_eval(&mut self, record: &EvalRecord) {
        let _ = record;
    }

    /// A new full-fidelity running best was found.
    fn on_new_best(&mut self, config: &Config, latency_us: f64) {
        let _ = (config, latency_us);
    }

    /// A successive-halving rung is starting: `pool` configs are about
    /// to be measured at `fidelity`.
    fn on_rung(&mut self, fidelity: f64, pool: usize) {
        let _ = (fidelity, pool);
    }

    /// A fleet run is switching to (or starting on) `platform`.  Solo
    /// runs never emit this.
    fn on_platform(&mut self, platform: &str) {
        let _ = platform;
    }

    /// An evaluation failed: the config is invalid on this platform, or
    /// the measurement faulted (e.g. an injected
    /// [`crate::serving::ChaosBackend`]/`ChaosEvaluator` fault).  Fired
    /// in addition to [`Observer::on_eval`] for the same record, with
    /// the failure reason.  Like every observer hook this is
    /// watch-only: it cannot influence the search.
    fn on_fault(&mut self, fingerprint: u64, reason: &str) {
        let _ = (fingerprint, reason);
    }
}

/// Records every evaluation a strategy performs.
///
/// The recorder keeps the evaluation log as [`EvalRecord`]s (fingerprint
/// + latency + fidelity) rather than cloning every [`Config`]:
/// strategies only ever re-read the *count* and the *best*, so the
/// single running-best clone is the only config a default recorder owns
/// ([`Recorder::capturing`] opts into keeping all of them, for
/// cross-platform analyses that need the configs back).
///
/// **Fidelity correctness**: only full-fidelity (1.0) results may update
/// [`Recorder::best`].  Multi-fidelity strategies (successive halving)
/// measure most configs cheaply, and a cheap measurement — noisy, fewer
/// iterations — must never be reported as the tuning result; the rung
/// winners are re-confirmed at fidelity 1.0 before they can become
/// `best`.
///
/// **Budget enforcement**: the recorder carries the session's
/// evaluation cap ([`Recorder::limit_evals`]) and wall-clock deadline
/// ([`Recorder::limit_deadline`]).  Once exhausted, [`Recorder::eval`]
/// refuses to evaluate and [`Recorder::eval_batch`] truncates its batch
/// to the remaining allowance — deterministically, so a capped run is
/// always an exact prefix of the uncapped history.  Strategies
/// additionally poll [`Recorder::out_of_budget`] so their control loops
/// terminate promptly.  The `'o` lifetime is the borrow of any attached
/// [`Observer`]s.
pub struct Recorder<'o> {
    /// Evaluation log in submission order.  Multi-fidelity runs compact
    /// it once per rung ([`Recorder::rung`]): reduced-fidelity records
    /// superseded by a later measurement of the same config are dropped
    /// (full-fidelity records and each config's latest record survive),
    /// so the log stops growing with the rung count.  Counting consumers
    /// use [`Recorder::len`], which is compaction-independent.
    pub evals: Vec<EvalRecord>,
    /// How many evaluations were invalid on this platform.
    pub invalid: usize,
    /// Evaluations performed over the recorder's lifetime — the
    /// monotone counter behind [`Recorder::len`] and the budget; never
    /// reduced by compaction (compaction must not refund budget).
    performed: usize,
    seen: HashSet<u64>,
    best: Option<(Config, f64)>,
    captured: Option<HashMap<u64, Config>>,
    observers: Vec<&'o mut dyn Observer>,
    /// Maximum number of evaluations this recorder may log
    /// (`usize::MAX` = unlimited).
    max_evals: usize,
    /// Wall-clock cutoff; evaluations stop once it has passed.
    deadline: Option<Instant>,
    /// Reusable output slab for [`Recorder::eval_batch`] — allocated
    /// once at the first batch's size, then shared by every later
    /// batch/rung instead of a fresh `vec![None; n]` per call.
    slab: Vec<BatchSlot>,
}

impl Default for Recorder<'_> {
    fn default() -> Self {
        Recorder {
            evals: Vec::new(),
            invalid: 0,
            performed: 0,
            seen: HashSet::new(),
            best: None,
            captured: None,
            observers: Vec::new(),
            max_evals: usize::MAX,
            deadline: None,
            slab: Vec::new(),
        }
    }
}

impl<'o> Recorder<'o> {
    /// A recorder that additionally retains every evaluated [`Config`]
    /// (fingerprint → config).  Used by fleet tuning, where the
    /// cross-platform portability analysis needs to map the joined
    /// evaluation logs back to concrete configurations.
    pub fn capturing() -> Self {
        Recorder { captured: Some(HashMap::new()), ..Recorder::default() }
    }

    /// Attach an observer for the rest of this recorder's life.
    pub fn observe(&mut self, observer: &'o mut dyn Observer) {
        self.observers.push(observer);
    }

    /// Replace the observer set (used by fleet tuning to walk one
    /// observer set across the per-platform recorders in turn).
    pub(crate) fn set_observers(&mut self, observers: Vec<&'o mut dyn Observer>) {
        self.observers = observers;
    }

    /// Detach and return the observer set.
    pub(crate) fn take_observers(&mut self) -> Vec<&'o mut dyn Observer> {
        std::mem::take(&mut self.observers)
    }

    /// Cap the number of evaluations this recorder will perform.
    pub fn limit_evals(&mut self, max: usize) {
        self.max_evals = max;
    }

    /// Stop evaluating once `deadline` has passed.
    pub fn limit_deadline(&mut self, deadline: Instant) {
        self.deadline = Some(deadline);
    }

    /// True when the evaluation cap or the deadline is exhausted.
    /// Strategies poll this so their control loops terminate promptly
    /// instead of spinning on refused evaluations.
    pub fn out_of_budget(&self) -> bool {
        self.remaining_evals() == 0
    }

    /// Evaluations still allowed under the budget (`usize::MAX` when
    /// unlimited; 0 once the deadline has passed).
    fn remaining_evals(&self) -> usize {
        if self.deadline.is_some_and(|d| Instant::now() >= d) {
            return 0;
        }
        self.max_evals.saturating_sub(self.performed)
    }

    /// Notify observers that a successive-halving rung is starting, and
    /// compact the log accumulated so far.  Rung boundaries are the one
    /// place the log is safe to rewrite: no batch is in flight, and
    /// everything a consumer can still ask of the superseded records —
    /// `best` (full-fidelity-gated), the full-fidelity latencies feeding
    /// `TuneOutcome::spread` and surrogate fits — is preserved by
    /// keeping all full-fidelity records plus each config's latest
    /// record.
    pub(crate) fn rung(&mut self, fidelity: f64, pool: usize) {
        self.compact();
        for obs in self.observers.iter_mut() {
            obs.on_rung(fidelity, pool);
        }
    }

    /// Drop reduced-fidelity records that a later record of the same
    /// config supersedes.  Counting ([`Recorder::len`], budgets) is
    /// untouched — it runs on the monotone `performed` counter — and
    /// the surviving log is a deterministic function of the full log,
    /// so parallel engines compact bit-identically to sequential ones.
    fn compact(&mut self) {
        // Index of each config's last reduced-fidelity record; earlier
        // reduced-fidelity records of the same config are superseded.
        let mut latest: HashMap<u64, usize> = HashMap::new();
        for (i, r) in self.evals.iter().enumerate() {
            if !r.is_full_fidelity() {
                latest.insert(r.fingerprint, i);
            }
        }
        let mut i = 0usize;
        self.evals.retain(|r| {
            let keep = r.is_full_fidelity() || latest.get(&r.fingerprint) == Some(&i);
            i += 1;
            keep
        });
    }

    /// Notify observers that a fleet run switched to `platform`.
    pub(crate) fn platform(&mut self, platform: &str) {
        for obs in self.observers.iter_mut() {
            obs.on_platform(platform);
        }
    }

    /// Number of evaluations performed so far (valid + invalid).
    /// Monotone: per-rung log compaction never reduces it.
    pub fn len(&self) -> usize {
        self.performed
    }

    /// True when nothing has been evaluated yet.
    pub fn is_empty(&self) -> bool {
        self.performed == 0
    }

    /// Fold one evaluation result into the log (dedup-independent).
    /// Only full-fidelity results are allowed to update the running
    /// best: lower-fidelity latencies are not comparable to it.
    pub(crate) fn record(
        &mut self,
        cfg: &Config,
        res: Result<f64, crate::platform::model::InvalidConfig>,
        fidelity: f64,
    ) -> Option<f64> {
        let mut fault_reason: Option<String> = None;
        let entry = match res {
            Ok(us) => {
                // Capture only valid configs: invalid ones can never be
                // portability candidates, and cloning their BTreeMaps
                // for the whole run would be pure overhead.
                if let Some(map) = self.captured.as_mut() {
                    map.entry(cfg.fingerprint()).or_insert_with(|| cfg.clone());
                }
                EvalRecord { fingerprint: cfg.fingerprint(), latency_us: Some(us), fidelity }
            }
            Err(e) => {
                self.invalid += 1;
                fault_reason = Some(e.reason);
                EvalRecord { fingerprint: cfg.fingerprint(), latency_us: None, fidelity }
            }
        };
        let new_best = entry.latency_us.is_some_and(|us| {
            fidelity >= 1.0 && self.best.as_ref().map(|(_, b)| us < *b).unwrap_or(true)
        });
        if new_best {
            self.best = Some((cfg.clone(), entry.latency_us.unwrap()));
        }
        self.performed += 1;
        self.evals.push(entry);
        for obs in self.observers.iter_mut() {
            obs.on_eval(&entry);
            if let Some(reason) = &fault_reason {
                obs.on_fault(entry.fingerprint, reason);
            }
            if new_best {
                obs.on_new_best(cfg, entry.latency_us.unwrap());
            }
        }
        entry.latency_us
    }

    /// Evaluate through the recorder (bookkeeping + best tracking).
    /// Returns the latency if the config is valid — or `None` without
    /// evaluating when the budget is exhausted (callers polling
    /// [`Recorder::out_of_budget`] never observe that case).
    pub(crate) fn eval(
        &mut self,
        eval: &mut dyn Evaluator,
        cfg: &Config,
        fidelity: f64,
    ) -> Option<f64> {
        if self.out_of_budget() {
            return None;
        }
        let res = eval.evaluate_fidelity(cfg, fidelity);
        self.record(cfg, res, fidelity)
    }

    /// Batched counterpart of [`Recorder::eval`]: submit `cfgs` in one
    /// evaluator call, fold results back in submission order.  The
    /// returned latencies line up index-for-index with `cfgs`.  Under an
    /// evaluation budget the batch is truncated to the remaining
    /// allowance (the unevaluated tail reports `None` without being
    /// logged), so a capped history is an exact prefix of the uncapped
    /// one.
    pub(crate) fn eval_batch(
        &mut self,
        eval: &mut dyn Evaluator,
        cfgs: &[Config],
        fidelity: f64,
    ) -> Vec<Option<f64>> {
        let allowed = cfgs.len().min(self.remaining_evals());
        let (run, skipped) = cfgs.split_at(allowed);
        let mut out: Vec<Option<f64>> = Vec::with_capacity(cfgs.len());
        if !run.is_empty() {
            // The evaluator writes into the recorder's reusable slab
            // (grown once to the largest batch, never shrunk), so the
            // hot rung/batch loop performs no per-call allocation.
            // Taken out of `self` for the duration: `record` below
            // needs `&mut self` while the slab is borrowed.
            let mut slab = std::mem::take(&mut self.slab);
            if slab.len() < run.len() {
                slab.resize(run.len(), None);
            }
            eval.evaluate_batch_into(run, fidelity, &mut slab);
            for (cfg, slot) in run.iter().zip(slab.iter_mut()) {
                // `take` doubles as the contract check: an evaluator
                // that skipped a slot fails loudly instead of silently
                // misattributing a stale result to this config.
                let res = slot.take().expect("evaluator left a batch slot unfilled");
                out.push(self.record(cfg, res, fidelity));
            }
            self.slab = slab;
        }
        out.extend(skipped.iter().map(|_| None));
        out
    }

    pub(crate) fn mark_seen(&mut self, cfg: &Config) -> bool {
        self.seen.insert(cfg.fingerprint())
    }

    /// Best valid **full-fidelity** (config, latency) seen so far.
    pub fn best(&self) -> Option<(Config, f64)> {
        self.best.clone()
    }

    /// All valid full-fidelity measurements as a fingerprint → latency
    /// map (re-evaluations of a config overwrite; every evaluator here
    /// is deterministic per (config, fidelity), so the value is stable).
    pub fn full_fidelity_latencies(&self) -> HashMap<u64, f64> {
        self.evals
            .iter()
            .filter(|r| r.is_full_fidelity())
            .filter_map(|r| r.latency_us.map(|l| (r.fingerprint, l)))
            .collect()
    }

    /// The retained [`Config`] for `fingerprint` — `Some` only on
    /// [`Recorder::capturing`] recorders that evaluated it.
    pub fn captured_config(&self, fingerprint: u64) -> Option<&Config> {
        self.captured.as_ref()?.get(&fingerprint)
    }
}

impl Strategy {
    /// Execute the strategy over `space` for `w`, recording every
    /// evaluation into `rec`.  Works with any [`Evaluator`] — batching
    /// strategies submit through `evaluate_batch`, so parallel and
    /// multi-device evaluators are used transparently.
    pub fn run(
        &self,
        space: &ConfigSpace,
        w: &Workload,
        eval: &mut dyn Evaluator,
        seed: u64,
        rec: &mut Recorder<'_>,
    ) {
        match *self {
            Strategy::Exhaustive | Strategy::Random { .. } => {
                let mut sink = SoloSink { eval, rec };
                run_deterministic(space, w, self, seed, &mut sink);
            }
            Strategy::HillClimb { restarts, budget } => {
                hill_climb(space, w, eval, seed, restarts, budget, rec)
            }
            Strategy::Anneal { budget, t0, alpha } => {
                anneal(space, w, eval, seed, budget, t0, alpha, rec)
            }
            Strategy::SuccessiveHalving { initial, eta } => {
                successive_halving(space, w, eval, seed, initial, eta, rec)
            }
        }
    }
}

/// Where a deterministic trajectory's batches land: the solo path
/// records into one recorder through one evaluator; the fleet path
/// measures each batch on every platform.  One trait so the
/// *trajectory* — enumeration order, draw sequence, dedup decisions,
/// batch boundaries — lives in exactly one place
/// ([`run_deterministic`]) and the two consumers cannot drift apart
/// (the fleet-vs-solo bit-identity contract pinned by
/// `tests/parallel_equiv.rs` depends on the batch sequence being
/// byte-for-byte identical).
trait TrajectorySink {
    /// Random-draw dedup filter.  Config-driven only, so every
    /// consumer makes identical keep/skip decisions.
    fn mark_seen(&mut self, cfg: &Config) -> bool;
    /// Measure one batch at full fidelity.
    fn submit(&mut self, cfgs: &[Config]);
    /// True once the session budget is exhausted — the driver stops
    /// submitting (already-submitted work was truncated by the
    /// recorder itself).
    fn out_of_budget(&self) -> bool;
    /// Evaluations still allowed under the session budget
    /// (`usize::MAX` when unlimited).  Lets the random driver avoid
    /// drawing thousands of samples that could never be measured.
    fn remaining(&self) -> usize;
}

/// One evaluator, one recorder — the ordinary tuning path.  (Separate
/// lifetime for the trait object: `&mut dyn` is invariant in its
/// object lifetime, so tying it to the recorder borrow would reject
/// callers whose two borrows differ.)
struct SoloSink<'a, 'e, 'o> {
    eval: &'a mut (dyn Evaluator + 'e),
    rec: &'a mut Recorder<'o>,
}

impl TrajectorySink for SoloSink<'_, '_, '_> {
    fn mark_seen(&mut self, cfg: &Config) -> bool {
        self.rec.mark_seen(cfg)
    }

    fn submit(&mut self, cfgs: &[Config]) {
        self.rec.eval_batch(&mut *self.eval, cfgs, 1.0);
    }

    fn out_of_budget(&self) -> bool {
        self.rec.out_of_budget()
    }

    fn remaining(&self) -> usize {
        self.rec.remaining_evals()
    }
}

/// Measure-everywhere: every batch goes to every distinct platform,
/// one recorder per platform.
struct FleetSink<'a, 'o> {
    fleet: &'a mut MultiDeviceEvaluator,
    recs: &'a mut [Recorder<'o>],
}

impl TrajectorySink for FleetSink<'_, '_> {
    fn mark_seen(&mut self, cfg: &Config) -> bool {
        // Mark in every platform recorder so each one's seen-state
        // matches a solo run of that platform; the decisions always
        // agree (dedup consults only the config fingerprint), and the
        // fold is non-short-circuiting so no recorder is skipped.
        self.recs
            .iter_mut()
            .map(|rec| rec.mark_seen(cfg))
            .fold(true, |acc, fresh| acc && fresh)
    }

    fn submit(&mut self, cfgs: &[Config]) {
        record_everywhere(&mut *self.fleet, cfgs, 1.0, &mut *self.recs);
    }

    fn out_of_budget(&self) -> bool {
        // Budgets are applied uniformly across the per-platform
        // recorders; `any` keeps this robust if one recorder was
        // configured tighter.
        self.recs.iter().any(|rec| rec.out_of_budget())
    }

    fn remaining(&self) -> usize {
        self.recs.iter().map(|rec| rec.remaining_evals()).min().unwrap_or(0)
    }
}

/// Drive an order-deterministic strategy — one whose evaluation order
/// is a pure function of (space, workload, seed), never of measured
/// latencies — batch by batch into `sink`.
///
/// Exhaustive streams the lazy enumeration in [`EVAL_BATCH`] chunks (at
/// most one batch resident at a time).  Random draws and dedups the
/// whole budget first, then measures in batches — sampling is
/// independent of measurement, so the history is identical to a
/// sample-measure-sample loop.
fn run_deterministic(
    space: &ConfigSpace,
    w: &Workload,
    strategy: &Strategy,
    seed: u64,
    sink: &mut dyn TrajectorySink,
) {
    match *strategy {
        Strategy::Exhaustive => {
            let mut batch: Vec<Config> = Vec::with_capacity(EVAL_BATCH);
            for cfg in space.enumerate(w) {
                batch.push(cfg);
                if batch.len() == EVAL_BATCH {
                    sink.submit(&batch);
                    batch.clear();
                    if sink.out_of_budget() {
                        return;
                    }
                }
            }
            if !batch.is_empty() && !sink.out_of_budget() {
                sink.submit(&batch);
            }
        }
        Strategy::Random { budget } => {
            // Sampling happens before any measurement, so the draw
            // sequence (and therefore a budget-capped history prefix)
            // is independent of the budget.  The draw *count* is capped
            // at the session allowance — drawing a huge strategy budget
            // that could never be measured would be pure waste, and
            // stopping the draws early keeps the submitted sequence an
            // exact prefix of the uncapped one (draws never depend on
            // measurements).
            let target = budget.min(sink.remaining());
            let mut rng = Rng::seed_from(seed);
            // Hoisted sampler: bit-identical draw stream to
            // `space.sample`, without the per-draw zone divisions and
            // key allocations (`ConfigSpace::sampler`).
            let mut sampler = space.sampler(w);
            let mut picked: Vec<Config> = Vec::new();
            let mut stall = 0;
            while picked.len() < target && stall < budget.saturating_mul(10) {
                let Some(cfg) = sampler.sample(&mut rng, 200) else { break };
                if !sink.mark_seen(&cfg) {
                    stall += 1;
                    continue;
                }
                picked.push(cfg);
            }
            for chunk in picked.chunks(EVAL_BATCH) {
                if sink.out_of_budget() {
                    return;
                }
                sink.submit(chunk);
            }
        }
        _ => unreachable!("only order-deterministic strategies share a trajectory"),
    }
}

fn hill_climb(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    restarts: usize,
    budget: usize,
    rec: &mut Recorder<'_>,
) {
    let mut rng = Rng::seed_from(seed);
    let mut sampler = space.sampler(w);
    'restart: for _ in 0..restarts.max(1) {
        // Keep sampling until a platform-valid starting point is found.
        let (mut cur, mut cur_lat) = loop {
            if rec.len() >= budget || rec.out_of_budget() {
                return;
            }
            let Some(c) = sampler.sample(&mut rng, 200) else { continue 'restart };
            if !rec.mark_seen(&c) {
                continue;
            }
            if let Some(l) = rec.eval(eval, &c, 1.0) {
                break (c, l);
            }
        };
        loop {
            if rec.len() >= budget || rec.out_of_budget() {
                return;
            }
            // Best improving neighbour (steepest descent).
            let mut improved = false;
            for n in space.neighbors(&cur, w) {
                if rec.len() >= budget || rec.out_of_budget() {
                    return;
                }
                if !rec.mark_seen(&n) {
                    continue;
                }
                if let Some(l) = rec.eval(eval, &n, 1.0) {
                    if l < cur_lat {
                        cur = n;
                        cur_lat = l;
                        improved = true;
                    }
                }
            }
            if !improved {
                break; // local optimum
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn anneal(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    budget: usize,
    t0: f64,
    alpha: f64,
    rec: &mut Recorder<'_>,
) {
    let mut rng = Rng::seed_from(seed);
    let mut sampler = space.sampler(w);
    // Initial point: keep sampling until one is valid on this platform.
    let mut start = None;
    for _ in 0..budget.max(20) {
        if rec.out_of_budget() {
            return;
        }
        let Some(c) = sampler.sample(&mut rng, 200) else { break };
        if let Some(l) = rec.eval(eval, &c, 1.0) {
            start = Some((c, l));
            break;
        }
    }
    let Some((mut cur, mut cur_lat)) = start else { return };
    let mut temp = t0;
    while rec.len() < budget && !rec.out_of_budget() {
        let neighbors = space.neighbors(&cur, w);
        if neighbors.is_empty() {
            break;
        }
        let cand = rng.choose(&neighbors).unwrap().clone();
        if let Some(l) = rec.eval(eval, &cand, 1.0) {
            // Accept improvements always; regressions with Boltzmann prob
            // on the *relative* slowdown (scale-free).
            let accept = l < cur_lat || {
                let delta = (l / cur_lat).ln();
                rng.f64() < (-delta / temp.max(1e-6)).exp()
            };
            if accept {
                cur = cand;
                cur_lat = l;
            }
        }
        temp *= alpha;
    }
}

fn successive_halving(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    initial: usize,
    eta: usize,
    rec: &mut Recorder<'_>,
) {
    let mut rng = Rng::seed_from(seed);
    let eta = eta.max(2);
    // Rung 0: distinct random configs at low fidelity.  The draw target
    // is capped by the space cardinality (asking for more distinct
    // configs than exist can only stall), and the guard counts
    // *consecutive* failed draws, scaled to the target but bounded —
    // the previous `initial * 20` total-iteration guard overflowed in
    // debug builds for large `initial`, while an unscaled constant
    // would burn thousands of draws on spaces whose workload-valid
    // region is smaller than the grid.
    let target = initial.min(space.cardinality()).max(1);
    let stall_limit = target.saturating_mul(20).clamp(100, 10_000);
    let mut sampler = space.sampler(w);
    let mut pool: Vec<Config> = Vec::new();
    let mut stall = 0usize;
    while pool.len() < target && stall < stall_limit {
        match sampler.sample(&mut rng, 200) {
            Some(c) if rec.mark_seen(&c) => {
                pool.push(c);
                stall = 0;
            }
            _ => stall += 1,
        }
    }
    // Fidelity schedule in f64 (integer `eta.pow(rungs - 1)` overflowed
    // for extreme eta), floored at MIN_SHA_FIDELITY.
    let rungs = (pool.len().max(1) as f64).log(eta as f64).ceil().max(1.0) as i32;
    let mut fidelity = (1.0 / (eta as f64).powi(rungs - 1)).max(MIN_SHA_FIDELITY);
    // Best valid config of the most recent rung that had any valid
    // result, with the fidelity it was measured at — the fallback
    // candidate when a later rung invalidates the whole pool (without
    // it, an all-invalid rung would end the search with nothing to
    // confirm even though earlier rungs found valid configs).
    let mut best_survivor: Option<(Config, f64)> = None;
    // Fidelity of the rung the current pool survived (0.0 = no rung ran).
    let mut pool_fidelity = 0.0;
    while pool.len() > 1 {
        // Whole rung in one batch: every member is measured at the same
        // fidelity regardless of the others' results.
        let rung_fidelity = fidelity;
        rec.rung(rung_fidelity, pool.len());
        let latencies = rec.eval_batch(eval, &pool, rung_fidelity);
        let mut scored: Vec<(Config, f64)> = pool
            .drain(..)
            .zip(latencies)
            .filter_map(|(c, l)| l.map(|l| (c, l)))
            .collect();
        scored.sort_by(|a, b| a.1.total_cmp(&b.1));
        if let Some((c, _)) = scored.first() {
            best_survivor = Some((c.clone(), rung_fidelity));
        }
        let keep = (scored.len() / eta).max(1);
        pool = scored.into_iter().take(keep).map(|(c, _)| c).collect();
        pool_fidelity = rung_fidelity;
        fidelity = (fidelity * eta as f64).min(1.0);
        if pool.len() == 1 {
            break;
        }
    }
    // Full-fidelity confirmation — the sole source of SHA's reported
    // best: rungs run at reduced fidelity, and only fidelity-1.0
    // measurements may update the recorder's best.  When a rung
    // invalidated the whole pool, confirm the best earlier survivor
    // instead of returning nothing.  If the survivor's rung already ran
    // at full fidelity, its measurement IS the confirmation (and the
    // fidelity-gated best already holds it) — re-measuring would pay a
    // second full measurement on a real evaluator for nothing.
    let survivor = match pool.into_iter().next() {
        Some(cfg) => Some((cfg, pool_fidelity)),
        None => best_survivor,
    };
    if let Some((cfg, measured_at)) = survivor {
        if measured_at < 1.0 {
            rec.eval(eval, &cfg, 1.0);
        }
    }
}

/// Drive one *shared* search trajectory over `space` for the whole
/// fleet: every submitted batch is measured on every distinct platform
/// via [`MultiDeviceEvaluator::evaluate_batch_everywhere`], and each
/// platform's results fold into its own recorder (`recs` is aligned
/// with [`MultiDeviceEvaluator::platforms`]).
///
/// Only the strategies whose evaluation *order* is independent of
/// measured latencies can share a trajectory — exhaustive enumeration
/// and seeded random sampling.  For those, each platform's recorder
/// ends up bit-identical to tuning that platform alone: the config
/// sequence is a pure function of (space, workload, seed), and the
/// per-platform measurements are pure functions of the config.  The
/// adaptive strategies (hill climb, annealing, successive halving)
/// branch on latencies, so their per-platform trajectories genuinely
/// diverge; fleet sessions ([`crate::autotuner::TuningSession::fleet`])
/// run those once per
/// platform instead.
pub(crate) fn run_fleet_shared(
    space: &ConfigSpace,
    w: &Workload,
    fleet: &mut MultiDeviceEvaluator,
    strategy: &Strategy,
    seed: u64,
    recs: &mut [Recorder<'_>],
) {
    let mut sink = FleetSink { fleet, recs };
    run_deterministic(space, w, strategy, seed, &mut sink);
}

/// Measure `cfgs` on every distinct platform of the fleet and fold each
/// platform's results into its recorder, in submission order.
fn record_everywhere(
    fleet: &mut MultiDeviceEvaluator,
    cfgs: &[Config],
    fidelity: f64,
    recs: &mut [Recorder<'_>],
) {
    // Session budgets apply to fleet runs too: truncate the batch to
    // the tightest per-platform allowance (the recorders are configured
    // uniformly, so this keeps their logs in lockstep — and with no
    // budget the allowance is unlimited and nothing changes).
    let allowed =
        recs.iter().map(|r| r.remaining_evals()).min().unwrap_or(0).min(cfgs.len());
    let cfgs = &cfgs[..allowed];
    if cfgs.is_empty() {
        return;
    }
    let results = fleet.evaluate_batch_everywhere(cfgs, fidelity);
    assert_eq!(
        results.len(),
        recs.len(),
        "evaluate_batch_everywhere returned {} platforms for {} recorders",
        results.len(),
        recs.len()
    );
    for (rec, platform_results) in recs.iter_mut().zip(results) {
        assert_eq!(
            platform_results.len(),
            cfgs.len(),
            "evaluate_batch_everywhere broke its contract: {} results for {} configs",
            platform_results.len(),
            cfgs.len()
        );
        for (cfg, res) in cfgs.iter().zip(platform_results) {
            rec.record(cfg, res, fidelity);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::model::InvalidConfig;

    /// Synthetic evaluator with a known optimum at (a=4, b=20).
    struct Quadratic;

    impl Evaluator for Quadratic {
        fn name(&self) -> String {
            "quadratic".into()
        }

        fn evaluate_fidelity(&mut self, cfg: &Config, _f: f64) -> Result<f64, InvalidConfig> {
            let a = cfg.req("a") as f64;
            let b = cfg.req("b") as f64;
            if a == 8.0 {
                return Err(InvalidConfig { reason: "a=8 unsupported".into() });
            }
            Ok(10.0 + (a - 4.0).powi(2) + 0.1 * (b - 20.0).powi(2))
        }
    }

    fn space() -> ConfigSpace {
        ConfigSpace::new("quad")
            .param("a", &[1, 2, 4, 8, 16])
            .param("b", &[5, 10, 20, 40])
    }

    fn w() -> Workload {
        Workload::VectorAdd { n: 64, dtype: crate::workload::DType::F32 }
    }

    #[test]
    fn observer_sees_faults_with_their_reasons() {
        #[derive(Default)]
        struct FaultWatcher {
            faults: Vec<(u64, String)>,
            evals: usize,
        }
        impl Observer for FaultWatcher {
            fn on_eval(&mut self, _r: &EvalRecord) {
                self.evals += 1;
            }
            fn on_fault(&mut self, fingerprint: u64, reason: &str) {
                self.faults.push((fingerprint, reason.to_string()));
            }
        }
        let mut watcher = FaultWatcher::default();
        {
            let mut rec = Recorder::default();
            rec.observe(&mut watcher);
            let good = Config::new(&[("a", 4), ("b", 20)]);
            let bad = Config::new(&[("a", 8), ("b", 20)]);
            rec.eval(&mut Quadratic, &good, 1.0);
            rec.eval(&mut Quadratic, &bad, 1.0);
            rec.record(
                &good,
                Err(InvalidConfig { reason: "injected transient fault".into() }),
                1.0,
            );
        }
        assert_eq!(watcher.evals, 3, "on_eval fires for every record, valid or not");
        assert_eq!(watcher.faults.len(), 2, "on_fault fires only for failures");
        assert_eq!(watcher.faults[0].1, "a=8 unsupported");
        assert_eq!(watcher.faults[1].1, "injected transient fault");
    }

    #[test]
    fn exhaustive_hits_known_optimum() {
        let mut rec = Recorder::default();
        Strategy::Exhaustive.run(&space(), &w(), &mut Quadratic, 0, &mut rec);
        let (best, lat) = rec.best().unwrap();
        assert_eq!(best, Config::new(&[("a", 4), ("b", 20)]));
        assert!((lat - 10.0).abs() < 1e-9);
        assert_eq!(rec.invalid, 4); // a=8 x 4 b-choices
    }

    #[test]
    fn hill_climb_descends_convex_surface() {
        let mut rec = Recorder::default();
        Strategy::HillClimb { restarts: 2, budget: 100 }.run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        let (_, lat) = rec.best().unwrap();
        assert!((lat - 10.0).abs() < 1e-9, "convex surface must be solved exactly");
    }

    #[test]
    fn anneal_finds_good_solution() {
        let mut rec = Recorder::default();
        Strategy::Anneal { budget: 60, t0: 1.0, alpha: 0.9 }.run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        let (_, lat) = rec.best().unwrap();
        assert!(lat < 12.0);
    }

    /// Latency depends on fidelity: cheap measurements are *optimistic*
    /// (report a fraction of the true latency), full fidelity is the
    /// truth.  This is the shape that exposed the fidelity-blind best
    /// bug: a rung-0 measurement always looked faster than any
    /// full-fidelity one, so the recorder crowned a number no real run
    /// could reproduce.
    struct FidelitySensitive;

    impl FidelitySensitive {
        fn truth(cfg: &Config) -> f64 {
            let a = cfg.req("a") as f64;
            let b = cfg.req("b") as f64;
            10.0 + (a - 4.0).powi(2) + 0.1 * (b - 20.0).powi(2)
        }
    }

    impl Evaluator for FidelitySensitive {
        fn name(&self) -> String {
            "fidelity-sensitive".into()
        }

        fn evaluate_fidelity(&mut self, cfg: &Config, f: f64) -> Result<f64, InvalidConfig> {
            if cfg.req("a") == 8 {
                return Err(InvalidConfig { reason: "a=8 unsupported".into() });
            }
            // f = 1.0 reports the truth; lower fidelities under-report.
            Ok(Self::truth(cfg) * (0.25 + 0.75 * f))
        }
    }

    #[test]
    fn sha_promotes_to_full_fidelity() {
        let mut rec = Recorder::default();
        Strategy::SuccessiveHalving { initial: 8, eta: 2 }.run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        assert!(rec.best().is_some());
        // History must contain at least one full-fidelity evaluation.
        assert!(rec.evals.iter().any(|r| r.is_full_fidelity()));
    }

    #[test]
    fn sha_best_is_a_full_fidelity_measurement() {
        // With an optimistic low-fidelity evaluator, a fidelity-blind
        // recorder would report a rung-0 latency as `best`.  The
        // reported best must instead be the config's true full-fidelity
        // latency.
        let mut rec = Recorder::default();
        Strategy::SuccessiveHalving { initial: 8, eta: 2 }
            .run(&space(), &w(), &mut FidelitySensitive, 5, &mut rec);
        let (cfg, lat) = rec.best().expect("sha must confirm a survivor");
        assert!(
            (lat - FidelitySensitive::truth(&cfg)).abs() < 1e-9,
            "reported best {lat} is not the full-fidelity latency {} of {cfg}",
            FidelitySensitive::truth(&cfg)
        );
        // And it must literally appear in the log as a fidelity-1.0
        // measurement.
        assert!(rec
            .evals
            .iter()
            .any(|r| r.is_full_fidelity() && r.latency_us == Some(lat)));
        // Low-fidelity rungs did report smaller numbers — they must not
        // have leaked into `best`.
        let cheapest = rec
            .evals
            .iter()
            .filter(|r| !r.is_full_fidelity())
            .filter_map(|r| r.latency_us)
            .fold(f64::INFINITY, f64::min);
        assert!(cheapest < lat, "the trap never armed: low fidelity was not optimistic");
    }

    /// Valid at rung-0 fidelity and at full fidelity, invalid in
    /// between — models a platform where mid-length measurement windows
    /// hit a driver bug.  Drives a whole SHA rung invalid.
    struct MidFidelityInvalid;

    impl Evaluator for MidFidelityInvalid {
        fn name(&self) -> String {
            "mid-fidelity-invalid".into()
        }

        fn evaluate_fidelity(&mut self, cfg: &Config, f: f64) -> Result<f64, InvalidConfig> {
            if f > 0.3 && f < 1.0 {
                return Err(InvalidConfig { reason: "mid-fidelity window".into() });
            }
            let a = cfg.req("a") as f64;
            let b = cfg.req("b") as f64;
            Ok(10.0 + (a - 4.0).powi(2) + 0.1 * (b - 20.0).powi(2))
        }
    }

    #[test]
    fn sha_all_invalid_rung_falls_back_to_best_survivor() {
        // initial=8, eta=2 → 3 rungs at fidelities 0.25 / 0.5 / 1.0.
        // The 0.5 rung is all-invalid, emptying the pool; the search
        // must confirm the best rung-0 survivor at full fidelity rather
        // than return nothing.
        let mut rec = Recorder::default();
        Strategy::SuccessiveHalving { initial: 8, eta: 2 }
            .run(&space(), &w(), &mut MidFidelityInvalid, 5, &mut rec);
        let (cfg, lat) = rec.best().expect("fallback survivor must be confirmed");
        assert!(space().contains(&cfg, &w()));
        assert!(lat > 0.0);
        let last = rec.evals.last().unwrap();
        assert!(last.is_full_fidelity(), "run must end on the full-fidelity confirmation");
        assert_eq!(last.latency_us, Some(lat));
    }

    #[test]
    fn sha_extreme_eta_and_initial_do_not_overflow() {
        // `eta.pow(rungs - 1)` and the `initial * 20` sampling guard
        // both overflowed in debug builds; the f64 schedule and the
        // consecutive-stall guard must survive the extremes.
        for (initial, eta) in [(usize::MAX, 2), (64, usize::MAX), (usize::MAX, usize::MAX)] {
            let mut rec = Recorder::default();
            Strategy::SuccessiveHalving { initial, eta }.run(&space(), &w(), &mut Quadratic, 5, &mut rec);
            assert!(rec.best().is_some(), "initial={initial} eta={eta} found nothing");
        }
    }

    #[test]
    fn sha_fidelity_schedule_is_floored() {
        // A deep schedule can never ask for fidelity below the floor.
        let mut rec = Recorder::default();
        Strategy::SuccessiveHalving { initial: 16, eta: 2 }
            .run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        for r in &rec.evals {
            assert!(r.fidelity >= MIN_SHA_FIDELITY);
            assert!(r.fidelity <= 1.0);
        }
    }

    #[test]
    fn random_respects_budget() {
        let mut rec = Recorder::default();
        Strategy::Random { budget: 7 }.run(&space(), &w(), &mut Quadratic, 1, &mut rec);
        assert!(rec.len() <= 7);
    }

    #[test]
    fn recorder_tracks_invalid() {
        let mut rec = Recorder::default();
        let bad = Config::new(&[("a", 8), ("b", 5)]);
        assert!(rec.eval(&mut Quadratic, &bad, 1.0).is_none());
        assert_eq!(rec.invalid, 1);
        assert!(rec.best().is_none());
    }

    #[test]
    fn recorder_log_is_fingerprint_keyed() {
        let mut rec = Recorder::default();
        let good = Config::new(&[("a", 4), ("b", 20)]);
        let bad = Config::new(&[("a", 8), ("b", 5)]);
        rec.eval(&mut Quadratic, &good, 1.0);
        rec.eval(&mut Quadratic, &bad, 1.0);
        assert_eq!(rec.evals.len(), 2);
        assert_eq!(
            rec.evals[0],
            EvalRecord { fingerprint: good.fingerprint(), latency_us: Some(10.0), fidelity: 1.0 }
        );
        assert_eq!(
            rec.evals[1],
            EvalRecord { fingerprint: bad.fingerprint(), latency_us: None, fidelity: 1.0 }
        );
    }

    #[test]
    fn recorder_low_fidelity_never_updates_best() {
        let mut rec = Recorder::default();
        let cfg = Config::new(&[("a", 4), ("b", 20)]);
        rec.eval(&mut FidelitySensitive, &cfg, 0.25);
        assert!(rec.best().is_none(), "a cheap measurement must not become best");
        assert_eq!(rec.len(), 1);
        rec.eval(&mut FidelitySensitive, &cfg, 1.0);
        let (_, lat) = rec.best().unwrap();
        assert!((lat - FidelitySensitive::truth(&cfg)).abs() < 1e-9);
    }

    #[test]
    fn recorder_capture_retains_configs() {
        let mut plain = Recorder::default();
        let mut cap = Recorder::capturing();
        let cfg = Config::new(&[("a", 4), ("b", 20)]);
        plain.eval(&mut Quadratic, &cfg, 1.0);
        cap.eval(&mut Quadratic, &cfg, 1.0);
        assert!(plain.captured_config(cfg.fingerprint()).is_none());
        assert_eq!(cap.captured_config(cfg.fingerprint()), Some(&cfg));
        assert_eq!(cap.full_fidelity_latencies().get(&cfg.fingerprint()), Some(&10.0));
    }

    #[test]
    fn recorder_eval_batch_matches_sequential() {
        let cfgs: Vec<Config> = space().enumerate(&w()).collect();
        let mut seq = Recorder::default();
        for c in &cfgs {
            seq.eval(&mut Quadratic, c, 1.0);
        }
        let mut bat = Recorder::default();
        bat.eval_batch(&mut Quadratic, &cfgs, 1.0);
        assert_eq!(seq.evals, bat.evals);
        assert_eq!(seq.invalid, bat.invalid);
        assert_eq!(seq.best(), bat.best());
    }

    #[test]
    fn rung_compacts_superseded_reduced_fidelity_records() {
        let mut rec = Recorder::default();
        let c1 = Config::new(&[("a", 1), ("b", 5)]);
        let c2 = Config::new(&[("a", 2), ("b", 5)]);
        rec.record(&c1, Ok(5.0), 0.25);
        rec.record(&c2, Ok(6.0), 0.25);
        rec.record(&c1, Ok(5.5), 0.5); // supersedes c1 @ 0.25
        rec.record(&c1, Ok(7.0), 1.0); // full fidelity: always kept
        assert_eq!(rec.len(), 4);
        rec.rung(1.0, 1);
        // c1 @ 0.25 is dropped; c2's only record, c1's latest reduced
        // record and the full-fidelity record survive, in log order.
        assert_eq!(rec.evals.len(), 3);
        assert_eq!(rec.len(), 4, "compaction must not refund budget");
        assert_eq!(
            rec.evals[0],
            EvalRecord { fingerprint: c2.fingerprint(), latency_us: Some(6.0), fidelity: 0.25 }
        );
        assert_eq!(
            rec.evals[1],
            EvalRecord { fingerprint: c1.fingerprint(), latency_us: Some(5.5), fidelity: 0.5 }
        );
        assert!(rec.evals[2].is_full_fidelity());
        // The consumers of the log see nothing change.
        assert_eq!(rec.best().map(|(_, l)| l), Some(7.0));
        assert_eq!(rec.full_fidelity_latencies().get(&c1.fingerprint()), Some(&7.0));
    }

    #[test]
    fn sha_log_is_compacted_but_counts_are_monotone() {
        // Deep-enough SHA run: promoted configs accumulate superseded
        // rung records, so the surviving log must be strictly shorter
        // than the performed count — which budgets and `evaluated`
        // reporting keep using.
        let mut rec = Recorder::default();
        Strategy::SuccessiveHalving { initial: 16, eta: 2 }
            .run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        assert!(
            rec.evals.len() < rec.len(),
            "no compaction happened: {} records for {} evaluations",
            rec.evals.len(),
            rec.len()
        );
        // Each config retains at most one reduced-fidelity record per
        // compaction epoch; in particular the best is still the
        // full-fidelity confirmation.
        assert!(rec.best().is_some());
    }
}
