//! Search strategies over configuration spaces.
//!
//! The paper's Q4.2 calls for "advanced search methods to reduce
//! autotuning time and reliably identify optimal configurations".
//! Implemented here:
//!
//! - [`Strategy::Exhaustive`] — the ground truth (what the 24 h budget in
//!   the paper's method buys);
//! - [`Strategy::Random`] — the classic cheap baseline;
//! - [`Strategy::HillClimb`] — restarted greedy local search over
//!   one-parameter neighbourhoods;
//! - [`Strategy::Anneal`] — simulated annealing (escapes the local optima
//!   hill-climbing gets stuck in);
//! - [`Strategy::SuccessiveHalving`] — multi-fidelity racing: evaluate
//!   many configs cheaply, promote the best survivors to full fidelity.
//!
//! Every strategy records through a [`Recorder`] so outcomes are
//! comparable (#evaluated, #invalid, best).

use std::collections::HashSet;

use crate::util::rng::Rng;
use super::Evaluator;
use crate::config::{Config, ConfigSpace};
use crate::workload::Workload;

/// Search strategy selector (all deterministic given a seed).
#[derive(Debug, Clone, PartialEq)]
pub enum Strategy {
    Exhaustive,
    Random { budget: usize },
    HillClimb { restarts: usize, budget: usize },
    Anneal { budget: usize, t0: f64, alpha: f64 },
    SuccessiveHalving { initial: usize, eta: usize },
}

impl Strategy {
    pub fn label(&self) -> String {
        match self {
            Strategy::Exhaustive => "exhaustive".into(),
            Strategy::Random { budget } => format!("random({budget})"),
            Strategy::HillClimb { restarts, budget } => format!("hillclimb({restarts},{budget})"),
            Strategy::Anneal { budget, .. } => format!("anneal({budget})"),
            Strategy::SuccessiveHalving { initial, eta } => format!("sha({initial},{eta})"),
        }
    }
}

/// Records every evaluation a strategy performs.
#[derive(Debug, Default)]
pub struct Recorder {
    pub history: Vec<(Config, Option<f64>)>,
    pub invalid: usize,
    seen: HashSet<String>,
}

impl Recorder {
    /// Evaluate through the recorder (dedup + bookkeeping).
    /// Returns the latency if the config is valid.
    fn eval(&mut self, eval: &mut dyn Evaluator, cfg: &Config, fidelity: f64) -> Option<f64> {
        // Re-evaluations at higher fidelity are allowed; plain repeats of
        // the same config+fidelity are served from history implicitly by
        // strategies tracking `seen` themselves where needed.
        match eval.evaluate_fidelity(cfg, fidelity) {
            Ok(us) => {
                self.history.push((cfg.clone(), Some(us)));
                Some(us)
            }
            Err(_) => {
                self.invalid += 1;
                self.history.push((cfg.clone(), None));
                None
            }
        }
    }

    fn mark_seen(&mut self, cfg: &Config) -> bool {
        self.seen.insert(cfg.key())
    }

    /// Best valid (config, latency) seen so far.
    pub fn best(&self) -> Option<(Config, f64)> {
        self.history
            .iter()
            .filter_map(|(c, l)| l.map(|l| (c.clone(), l)))
            .min_by(|a, b| a.1.total_cmp(&b.1))
    }
}

impl Strategy {
    pub fn run(
        &self,
        space: &ConfigSpace,
        w: &Workload,
        eval: &mut dyn Evaluator,
        seed: u64,
        rec: &mut Recorder,
    ) {
        match *self {
            Strategy::Exhaustive => exhaustive(space, w, eval, rec),
            Strategy::Random { budget } => random(space, w, eval, seed, budget, rec),
            Strategy::HillClimb { restarts, budget } => {
                hill_climb(space, w, eval, seed, restarts, budget, rec)
            }
            Strategy::Anneal { budget, t0, alpha } => {
                anneal(space, w, eval, seed, budget, t0, alpha, rec)
            }
            Strategy::SuccessiveHalving { initial, eta } => {
                successive_halving(space, w, eval, seed, initial, eta, rec)
            }
        }
    }
}

fn exhaustive(space: &ConfigSpace, w: &Workload, eval: &mut dyn Evaluator, rec: &mut Recorder) {
    for cfg in space.enumerate(w) {
        rec.eval(eval, &cfg, 1.0);
    }
}

fn random(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    budget: usize,
    rec: &mut Recorder,
) {
    let mut rng = Rng::seed_from(seed);
    let mut tried = 0;
    let mut stall = 0;
    while tried < budget && stall < budget * 10 {
        let Some(cfg) = space.sample(w, &mut rng, 200) else { break };
        if !rec.mark_seen(&cfg) {
            stall += 1;
            continue;
        }
        rec.eval(eval, &cfg, 1.0);
        tried += 1;
    }
}

fn hill_climb(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    restarts: usize,
    budget: usize,
    rec: &mut Recorder,
) {
    let mut rng = Rng::seed_from(seed);
    'restart: for _ in 0..restarts.max(1) {
        // Keep sampling until a platform-valid starting point is found.
        let (mut cur, mut cur_lat) = loop {
            if rec.history.len() >= budget {
                return;
            }
            let Some(c) = space.sample(w, &mut rng, 200) else { continue 'restart };
            if !rec.mark_seen(&c) {
                continue;
            }
            if let Some(l) = rec.eval(eval, &c, 1.0) {
                break (c, l);
            }
        };
        loop {
            if rec.history.len() >= budget {
                return;
            }
            // Best improving neighbour (steepest descent).
            let mut improved = false;
            for n in space.neighbors(&cur, w) {
                if rec.history.len() >= budget {
                    return;
                }
                if !rec.mark_seen(&n) {
                    continue;
                }
                if let Some(l) = rec.eval(eval, &n, 1.0) {
                    if l < cur_lat {
                        cur = n;
                        cur_lat = l;
                        improved = true;
                    }
                }
            }
            if !improved {
                break; // local optimum
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn anneal(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    budget: usize,
    t0: f64,
    alpha: f64,
    rec: &mut Recorder,
) {
    let mut rng = Rng::seed_from(seed);
    // Initial point: keep sampling until one is valid on this platform.
    let mut start = None;
    for _ in 0..budget.max(20) {
        let Some(c) = space.sample(w, &mut rng, 200) else { break };
        if let Some(l) = rec.eval(eval, &c, 1.0) {
            start = Some((c, l));
            break;
        }
    }
    let Some((mut cur, mut cur_lat)) = start else { return };
    let mut temp = t0;
    while rec.history.len() < budget {
        let neighbors = space.neighbors(&cur, w);
        if neighbors.is_empty() {
            break;
        }
        let cand = rng.choose(&neighbors).unwrap().clone();
        if let Some(l) = rec.eval(eval, &cand, 1.0) {
            // Accept improvements always; regressions with Boltzmann prob
            // on the *relative* slowdown (scale-free).
            let accept = l < cur_lat || {
                let delta = (l / cur_lat).ln();
                rng.f64() < (-delta / temp.max(1e-6)).exp()
            };
            if accept {
                cur = cand;
                cur_lat = l;
            }
        }
        temp *= alpha;
    }
}

fn successive_halving(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    initial: usize,
    eta: usize,
    rec: &mut Recorder,
) {
    let mut rng = Rng::seed_from(seed);
    let eta = eta.max(2);
    // Rung 0: distinct random configs at low fidelity.
    let mut pool: Vec<Config> = Vec::new();
    let mut guard = 0;
    while pool.len() < initial && guard < initial * 20 {
        guard += 1;
        if let Some(c) = space.sample(w, &mut rng, 200) {
            if rec.mark_seen(&c) {
                pool.push(c);
            }
        }
    }
    let rungs = (pool.len() as f64).log(eta as f64).ceil() as usize;
    let mut fidelity = 1.0 / eta.pow(rungs.max(1) as u32 - 1).max(1) as f64;
    while pool.len() > 1 {
        let mut scored: Vec<(Config, f64)> = pool
            .drain(..)
            .filter_map(|c| rec.eval(eval, &c, fidelity).map(|l| (c, l)))
            .collect();
        scored.sort_by(|a, b| a.1.total_cmp(&b.1));
        let keep = (scored.len() / eta).max(1);
        pool = scored.into_iter().take(keep).map(|(c, _)| c).collect();
        fidelity = (fidelity * eta as f64).min(1.0);
        if pool.len() == 1 {
            break;
        }
    }
    // Final full-fidelity confirmation of the survivor.
    if let Some(cfg) = pool.first().cloned() {
        rec.eval(eval, &cfg, 1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::model::InvalidConfig;

    /// Synthetic evaluator with a known optimum at (a=4, b=20).
    struct Quadratic;

    impl Evaluator for Quadratic {
        fn name(&self) -> String {
            "quadratic".into()
        }

        fn evaluate_fidelity(&mut self, cfg: &Config, _f: f64) -> Result<f64, InvalidConfig> {
            let a = cfg.req("a") as f64;
            let b = cfg.req("b") as f64;
            if a == 8.0 {
                return Err(InvalidConfig { reason: "a=8 unsupported".into() });
            }
            Ok(10.0 + (a - 4.0).powi(2) + 0.1 * (b - 20.0).powi(2))
        }
    }

    fn space() -> ConfigSpace {
        ConfigSpace::new("quad")
            .param("a", &[1, 2, 4, 8, 16])
            .param("b", &[5, 10, 20, 40])
    }

    fn w() -> Workload {
        Workload::VectorAdd { n: 64, dtype: crate::workload::DType::F32 }
    }

    #[test]
    fn exhaustive_hits_known_optimum() {
        let mut rec = Recorder::default();
        Strategy::Exhaustive.run(&space(), &w(), &mut Quadratic, 0, &mut rec);
        let (best, lat) = rec.best().unwrap();
        assert_eq!(best, Config::new(&[("a", 4), ("b", 20)]));
        assert!((lat - 10.0).abs() < 1e-9);
        assert_eq!(rec.invalid, 4); // a=8 x 4 b-choices
    }

    #[test]
    fn hill_climb_descends_convex_surface() {
        let mut rec = Recorder::default();
        Strategy::HillClimb { restarts: 2, budget: 100 }.run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        let (_, lat) = rec.best().unwrap();
        assert!((lat - 10.0).abs() < 1e-9, "convex surface must be solved exactly");
    }

    #[test]
    fn anneal_finds_good_solution() {
        let mut rec = Recorder::default();
        Strategy::Anneal { budget: 60, t0: 1.0, alpha: 0.9 }.run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        let (_, lat) = rec.best().unwrap();
        assert!(lat < 12.0);
    }

    #[test]
    fn sha_promotes_to_full_fidelity() {
        let mut rec = Recorder::default();
        Strategy::SuccessiveHalving { initial: 8, eta: 2 }.run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        assert!(rec.best().is_some());
        // History must contain at least one full-fidelity evaluation.
        assert!(!rec.history.is_empty());
    }

    #[test]
    fn random_respects_budget() {
        let mut rec = Recorder::default();
        Strategy::Random { budget: 7 }.run(&space(), &w(), &mut Quadratic, 1, &mut rec);
        assert!(rec.history.len() <= 7);
    }

    #[test]
    fn recorder_tracks_invalid() {
        let mut rec = Recorder::default();
        let bad = Config::new(&[("a", 8), ("b", 5)]);
        assert!(rec.eval(&mut Quadratic, &bad, 1.0).is_none());
        assert_eq!(rec.invalid, 1);
        assert!(rec.best().is_none());
    }
}
