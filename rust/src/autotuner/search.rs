//! Search strategies over configuration spaces.
//!
//! The paper's Q4.2 calls for "advanced search methods to reduce
//! autotuning time and reliably identify optimal configurations".
//! Implemented here:
//!
//! - [`Strategy::Exhaustive`] — the ground truth (what the 24 h budget in
//!   the paper's method buys);
//! - [`Strategy::Random`] — the classic cheap baseline;
//! - [`Strategy::HillClimb`] — restarted greedy local search over
//!   one-parameter neighbourhoods;
//! - [`Strategy::Anneal`] — simulated annealing (escapes the local optima
//!   hill-climbing gets stuck in);
//! - [`Strategy::SuccessiveHalving`] — multi-fidelity racing: evaluate
//!   many configs cheaply, promote the best survivors to full fidelity.
//!
//! Every strategy records through a [`Recorder`] so outcomes are
//! comparable (#evaluated, #invalid, best).
//!
//! **Batched evaluation**: the strategies whose evaluation order does not
//! depend on earlier results (exhaustive, random, each successive-halving
//! rung) submit work through [`Evaluator::evaluate_batch`] so a parallel
//! evaluator can fan the batch across a worker pool.  Results are folded
//! back into the [`Recorder`] in submission order, which keeps the
//! evaluation history — and therefore `best()` and per-seed
//! reproducibility — bit-identical to sequential evaluation.  The
//! inherently sequential strategies (hill climb, annealing: every step
//! depends on the previous measurement) stay on the one-at-a-time path.

use std::collections::HashSet;

use super::Evaluator;
use crate::config::{Config, ConfigSpace};
use crate::util::rng::Rng;
use crate::workload::Workload;

/// How many configurations the batching strategies submit per
/// [`Evaluator::evaluate_batch`] call.  Large enough to amortize a
/// thread-pool dispatch across every worker, small enough to keep
/// streaming (lazy enumeration never materializes more than one batch).
pub const EVAL_BATCH: usize = 256;

/// Search strategy selector (all deterministic given a seed).
#[derive(Debug, Clone, PartialEq)]
pub enum Strategy {
    /// Measure every valid configuration (the ground truth).
    Exhaustive,
    /// `budget` distinct uniform samples.
    Random {
        /// Maximum number of evaluations.
        budget: usize,
    },
    /// Restarted steepest-descent over one-parameter neighbourhoods.
    HillClimb {
        /// Number of random restarts.
        restarts: usize,
        /// Maximum number of evaluations across all restarts.
        budget: usize,
    },
    /// Simulated annealing over the neighbourhood graph.
    Anneal {
        /// Maximum number of evaluations.
        budget: usize,
        /// Initial temperature.
        t0: f64,
        /// Per-step geometric cooling factor.
        alpha: f64,
    },
    /// Multi-fidelity racing: start `initial` configs cheap, promote the
    /// best `1/eta` fraction per rung.
    SuccessiveHalving {
        /// Rung-0 population size.
        initial: usize,
        /// Promotion ratio between rungs (≥ 2).
        eta: usize,
    },
}

impl Strategy {
    /// Compact human-readable identifier (used in reports and caches).
    pub fn label(&self) -> String {
        match self {
            Strategy::Exhaustive => "exhaustive".into(),
            Strategy::Random { budget } => format!("random({budget})"),
            Strategy::HillClimb { restarts, budget } => format!("hillclimb({restarts},{budget})"),
            Strategy::Anneal { budget, .. } => format!("anneal({budget})"),
            Strategy::SuccessiveHalving { initial, eta } => format!("sha({initial},{eta})"),
        }
    }
}

/// Records every evaluation a strategy performs.
///
/// The recorder keeps the evaluation log as `(fingerprint, latency)`
/// pairs rather than cloning every [`Config`]: strategies only ever
/// re-read the *count* and the *best*, so the single running-best clone
/// is the only config the recorder owns.
#[derive(Debug, Default)]
pub struct Recorder {
    /// (config fingerprint, latency µs) in evaluation order; `None` =
    /// invalid on this platform.
    pub evals: Vec<(u64, Option<f64>)>,
    pub invalid: usize,
    seen: HashSet<u64>,
    best: Option<(Config, f64)>,
}

impl Recorder {
    /// Number of evaluations performed so far (valid + invalid).
    pub fn len(&self) -> usize {
        self.evals.len()
    }

    /// True when nothing has been evaluated yet.
    pub fn is_empty(&self) -> bool {
        self.evals.is_empty()
    }

    /// Fold one evaluation result into the log (dedup-independent).
    fn record(
        &mut self,
        cfg: &Config,
        res: Result<f64, crate::platform::model::InvalidConfig>,
    ) -> Option<f64> {
        match res {
            Ok(us) => {
                if self.best.as_ref().map(|(_, b)| us < *b).unwrap_or(true) {
                    self.best = Some((cfg.clone(), us));
                }
                self.evals.push((cfg.fingerprint(), Some(us)));
                Some(us)
            }
            Err(_) => {
                self.invalid += 1;
                self.evals.push((cfg.fingerprint(), None));
                None
            }
        }
    }

    /// Evaluate through the recorder (bookkeeping + best tracking).
    /// Returns the latency if the config is valid.
    pub(crate) fn eval(
        &mut self,
        eval: &mut dyn Evaluator,
        cfg: &Config,
        fidelity: f64,
    ) -> Option<f64> {
        let res = eval.evaluate_fidelity(cfg, fidelity);
        self.record(cfg, res)
    }

    /// Batched counterpart of [`Recorder::eval`]: submit `cfgs` in one
    /// evaluator call, fold results back in submission order.  The
    /// returned latencies line up index-for-index with `cfgs`.
    pub(crate) fn eval_batch(
        &mut self,
        eval: &mut dyn Evaluator,
        cfgs: &[Config],
        fidelity: f64,
    ) -> Vec<Option<f64>> {
        let results = eval.evaluate_batch(cfgs, fidelity);
        // A short/long result vector would silently misattribute
        // latencies to configs via zip — fail loudly instead.
        assert_eq!(
            results.len(),
            cfgs.len(),
            "evaluate_batch broke its contract: {} results for {} configs",
            results.len(),
            cfgs.len()
        );
        results
            .into_iter()
            .zip(cfgs)
            .map(|(res, cfg)| self.record(cfg, res))
            .collect()
    }

    fn mark_seen(&mut self, cfg: &Config) -> bool {
        self.seen.insert(cfg.fingerprint())
    }

    /// Best valid (config, latency) seen so far.
    pub fn best(&self) -> Option<(Config, f64)> {
        self.best.clone()
    }
}

impl Strategy {
    /// Execute the strategy over `space` for `w`, recording every
    /// evaluation into `rec`.  Works with any [`Evaluator`] — batching
    /// strategies submit through `evaluate_batch`, so parallel and
    /// multi-device evaluators are used transparently.
    pub fn run(
        &self,
        space: &ConfigSpace,
        w: &Workload,
        eval: &mut dyn Evaluator,
        seed: u64,
        rec: &mut Recorder,
    ) {
        match *self {
            Strategy::Exhaustive => exhaustive(space, w, eval, rec),
            Strategy::Random { budget } => random(space, w, eval, seed, budget, rec),
            Strategy::HillClimb { restarts, budget } => {
                hill_climb(space, w, eval, seed, restarts, budget, rec)
            }
            Strategy::Anneal { budget, t0, alpha } => {
                anneal(space, w, eval, seed, budget, t0, alpha, rec)
            }
            Strategy::SuccessiveHalving { initial, eta } => {
                successive_halving(space, w, eval, seed, initial, eta, rec)
            }
        }
    }
}

/// Stream the lazy enumeration into evaluation batches: at most one
/// batch of configs is resident at a time.
fn exhaustive(space: &ConfigSpace, w: &Workload, eval: &mut dyn Evaluator, rec: &mut Recorder) {
    let mut batch: Vec<Config> = Vec::with_capacity(EVAL_BATCH);
    for cfg in space.enumerate(w) {
        batch.push(cfg);
        if batch.len() == EVAL_BATCH {
            rec.eval_batch(eval, &batch, 1.0);
            batch.clear();
        }
    }
    if !batch.is_empty() {
        rec.eval_batch(eval, &batch, 1.0);
    }
}

/// Sampling is independent of measurement, so the whole budget is drawn
/// (and deduped) first, then measured in batches — identical history to
/// the old sample-measure-sample loop.
fn random(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    budget: usize,
    rec: &mut Recorder,
) {
    let mut rng = Rng::seed_from(seed);
    let mut picked: Vec<Config> = Vec::new();
    let mut stall = 0;
    while picked.len() < budget && stall < budget * 10 {
        let Some(cfg) = space.sample(w, &mut rng, 200) else { break };
        if !rec.mark_seen(&cfg) {
            stall += 1;
            continue;
        }
        picked.push(cfg);
    }
    for chunk in picked.chunks(EVAL_BATCH) {
        rec.eval_batch(eval, chunk, 1.0);
    }
}

fn hill_climb(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    restarts: usize,
    budget: usize,
    rec: &mut Recorder,
) {
    let mut rng = Rng::seed_from(seed);
    'restart: for _ in 0..restarts.max(1) {
        // Keep sampling until a platform-valid starting point is found.
        let (mut cur, mut cur_lat) = loop {
            if rec.len() >= budget {
                return;
            }
            let Some(c) = space.sample(w, &mut rng, 200) else { continue 'restart };
            if !rec.mark_seen(&c) {
                continue;
            }
            if let Some(l) = rec.eval(eval, &c, 1.0) {
                break (c, l);
            }
        };
        loop {
            if rec.len() >= budget {
                return;
            }
            // Best improving neighbour (steepest descent).
            let mut improved = false;
            for n in space.neighbors(&cur, w) {
                if rec.len() >= budget {
                    return;
                }
                if !rec.mark_seen(&n) {
                    continue;
                }
                if let Some(l) = rec.eval(eval, &n, 1.0) {
                    if l < cur_lat {
                        cur = n;
                        cur_lat = l;
                        improved = true;
                    }
                }
            }
            if !improved {
                break; // local optimum
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn anneal(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    budget: usize,
    t0: f64,
    alpha: f64,
    rec: &mut Recorder,
) {
    let mut rng = Rng::seed_from(seed);
    // Initial point: keep sampling until one is valid on this platform.
    let mut start = None;
    for _ in 0..budget.max(20) {
        let Some(c) = space.sample(w, &mut rng, 200) else { break };
        if let Some(l) = rec.eval(eval, &c, 1.0) {
            start = Some((c, l));
            break;
        }
    }
    let Some((mut cur, mut cur_lat)) = start else { return };
    let mut temp = t0;
    while rec.len() < budget {
        let neighbors = space.neighbors(&cur, w);
        if neighbors.is_empty() {
            break;
        }
        let cand = rng.choose(&neighbors).unwrap().clone();
        if let Some(l) = rec.eval(eval, &cand, 1.0) {
            // Accept improvements always; regressions with Boltzmann prob
            // on the *relative* slowdown (scale-free).
            let accept = l < cur_lat || {
                let delta = (l / cur_lat).ln();
                rng.f64() < (-delta / temp.max(1e-6)).exp()
            };
            if accept {
                cur = cand;
                cur_lat = l;
            }
        }
        temp *= alpha;
    }
}

fn successive_halving(
    space: &ConfigSpace,
    w: &Workload,
    eval: &mut dyn Evaluator,
    seed: u64,
    initial: usize,
    eta: usize,
    rec: &mut Recorder,
) {
    let mut rng = Rng::seed_from(seed);
    let eta = eta.max(2);
    // Rung 0: distinct random configs at low fidelity.
    let mut pool: Vec<Config> = Vec::new();
    let mut guard = 0;
    while pool.len() < initial && guard < initial * 20 {
        guard += 1;
        if let Some(c) = space.sample(w, &mut rng, 200) {
            if rec.mark_seen(&c) {
                pool.push(c);
            }
        }
    }
    let rungs = (pool.len() as f64).log(eta as f64).ceil() as usize;
    let mut fidelity = 1.0 / eta.pow(rungs.max(1) as u32 - 1).max(1) as f64;
    while pool.len() > 1 {
        // Whole rung in one batch: every member is measured at the same
        // fidelity regardless of the others' results.
        let latencies = rec.eval_batch(eval, &pool, fidelity);
        let mut scored: Vec<(Config, f64)> = pool
            .drain(..)
            .zip(latencies)
            .filter_map(|(c, l)| l.map(|l| (c, l)))
            .collect();
        scored.sort_by(|a, b| a.1.total_cmp(&b.1));
        let keep = (scored.len() / eta).max(1);
        pool = scored.into_iter().take(keep).map(|(c, _)| c).collect();
        fidelity = (fidelity * eta as f64).min(1.0);
        if pool.len() == 1 {
            break;
        }
    }
    // Final full-fidelity confirmation of the survivor.
    if let Some(cfg) = pool.first().cloned() {
        rec.eval(eval, &cfg, 1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::model::InvalidConfig;

    /// Synthetic evaluator with a known optimum at (a=4, b=20).
    struct Quadratic;

    impl Evaluator for Quadratic {
        fn name(&self) -> String {
            "quadratic".into()
        }

        fn evaluate_fidelity(&mut self, cfg: &Config, _f: f64) -> Result<f64, InvalidConfig> {
            let a = cfg.req("a") as f64;
            let b = cfg.req("b") as f64;
            if a == 8.0 {
                return Err(InvalidConfig { reason: "a=8 unsupported".into() });
            }
            Ok(10.0 + (a - 4.0).powi(2) + 0.1 * (b - 20.0).powi(2))
        }
    }

    fn space() -> ConfigSpace {
        ConfigSpace::new("quad")
            .param("a", &[1, 2, 4, 8, 16])
            .param("b", &[5, 10, 20, 40])
    }

    fn w() -> Workload {
        Workload::VectorAdd { n: 64, dtype: crate::workload::DType::F32 }
    }

    #[test]
    fn exhaustive_hits_known_optimum() {
        let mut rec = Recorder::default();
        Strategy::Exhaustive.run(&space(), &w(), &mut Quadratic, 0, &mut rec);
        let (best, lat) = rec.best().unwrap();
        assert_eq!(best, Config::new(&[("a", 4), ("b", 20)]));
        assert!((lat - 10.0).abs() < 1e-9);
        assert_eq!(rec.invalid, 4); // a=8 x 4 b-choices
    }

    #[test]
    fn hill_climb_descends_convex_surface() {
        let mut rec = Recorder::default();
        Strategy::HillClimb { restarts: 2, budget: 100 }.run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        let (_, lat) = rec.best().unwrap();
        assert!((lat - 10.0).abs() < 1e-9, "convex surface must be solved exactly");
    }

    #[test]
    fn anneal_finds_good_solution() {
        let mut rec = Recorder::default();
        Strategy::Anneal { budget: 60, t0: 1.0, alpha: 0.9 }.run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        let (_, lat) = rec.best().unwrap();
        assert!(lat < 12.0);
    }

    #[test]
    fn sha_promotes_to_full_fidelity() {
        let mut rec = Recorder::default();
        Strategy::SuccessiveHalving { initial: 8, eta: 2 }.run(&space(), &w(), &mut Quadratic, 5, &mut rec);
        assert!(rec.best().is_some());
        // History must contain at least one full-fidelity evaluation.
        assert!(!rec.is_empty());
    }

    #[test]
    fn random_respects_budget() {
        let mut rec = Recorder::default();
        Strategy::Random { budget: 7 }.run(&space(), &w(), &mut Quadratic, 1, &mut rec);
        assert!(rec.len() <= 7);
    }

    #[test]
    fn recorder_tracks_invalid() {
        let mut rec = Recorder::default();
        let bad = Config::new(&[("a", 8), ("b", 5)]);
        assert!(rec.eval(&mut Quadratic, &bad, 1.0).is_none());
        assert_eq!(rec.invalid, 1);
        assert!(rec.best().is_none());
    }

    #[test]
    fn recorder_log_is_fingerprint_keyed() {
        let mut rec = Recorder::default();
        let good = Config::new(&[("a", 4), ("b", 20)]);
        let bad = Config::new(&[("a", 8), ("b", 5)]);
        rec.eval(&mut Quadratic, &good, 1.0);
        rec.eval(&mut Quadratic, &bad, 1.0);
        assert_eq!(rec.evals.len(), 2);
        assert_eq!(rec.evals[0], (good.fingerprint(), Some(10.0)));
        assert_eq!(rec.evals[1], (bad.fingerprint(), None));
    }

    #[test]
    fn recorder_eval_batch_matches_sequential() {
        let cfgs: Vec<Config> = space().enumerate(&w()).collect();
        let mut seq = Recorder::default();
        for c in &cfgs {
            seq.eval(&mut Quadratic, c, 1.0);
        }
        let mut bat = Recorder::default();
        bat.eval_batch(&mut Quadratic, &cfgs, 1.0);
        assert_eq!(seq.evals, bat.evals);
        assert_eq!(seq.invalid, bat.invalid);
        assert_eq!(seq.best(), bat.best());
    }
}
