//! Evaluators: attach latencies to configurations.
//!
//! - [`SimEvaluator`] asks an analytical platform model (instant,
//!   deterministic) — used for the paper-figure reproductions.  It is
//!   `Send + Sync` and overrides [`Evaluator::evaluate_batch`] with a
//!   `std::thread::scope` worker pool sized by `available_parallelism`,
//!   so batching strategies evaluate configurations on every core while
//!   results merge back in submission order (bit-identical to the
//!   sequential path).
//! - [`PjrtEvaluator`] (feature `pjrt`) compiles and *actually executes*
//!   the AOT artifact for a configuration on the PJRT CPU client and
//!   reports measured wall-clock — the real autotuning loop (compile
//!   cost dominates, just as the paper notes: "compilation time accounts
//!   for around 80 % of the autotuning time").  PJRT handles are not
//!   `Send`, so it relies on the trait's sequential `evaluate_batch`
//!   default.

use crate::autotuner::Evaluator;
use crate::config::Config;
use crate::platform::model::{Codegen, InvalidConfig, SimGpu};
use crate::workload::Workload;

/// Evaluate against an analytical GPU model.
pub struct SimEvaluator {
    pub gpu: SimGpu,
    pub workload: Workload,
    pub codegen: Codegen,
    /// Count of model evaluations performed (profiling aid).
    pub calls: usize,
    /// Fan batches across a worker pool (on by default; the merge is
    /// deterministic, so the only observable difference is wall-clock).
    parallel: bool,
    /// Synthetic per-evaluation work (spin iterations) standing in for
    /// the compile+measure cost a real evaluator pays.  0 = pure model.
    /// The autotuner bench uses this to measure thread-pool scaling at a
    /// realistic per-config cost; it never changes the returned latency.
    eval_cost: u32,
}

impl SimEvaluator {
    pub fn new(gpu: SimGpu, workload: Workload, codegen: Codegen) -> Self {
        SimEvaluator { gpu, workload, codegen, calls: 0, parallel: true, eval_cost: 0 }
    }

    /// Disable the worker pool: every evaluation runs on the caller's
    /// thread.  Used as the baseline in equivalence tests and benches.
    pub fn sequential(mut self) -> Self {
        self.parallel = false;
        self
    }

    /// Attach a synthetic per-evaluation cost (spin iterations).
    pub fn with_eval_cost(mut self, iters: u32) -> Self {
        self.eval_cost = iters;
        self
    }
}

/// The model query itself, free of `&mut self` so worker threads can
/// share the evaluator state immutably.
fn eval_config(
    gpu: &SimGpu,
    workload: &Workload,
    codegen: &Codegen,
    cost: u32,
    cfg: &Config,
    _fidelity: f64,
) -> Result<f64, InvalidConfig> {
    burn(cost, cfg);
    gpu.latency_us(cfg, workload, codegen)
}

/// Deterministic spin standing in for per-config compile/measure time.
/// Serial sqrt chain: the compiler cannot collapse it, and the result
/// feeds `black_box`, so `cost` iterations really execute.
fn burn(cost: u32, cfg: &Config) {
    if cost == 0 {
        return;
    }
    let mut x = 1.0 + (cfg.fingerprint() & 0x3FF) as f64 * 1e-12;
    for _ in 0..cost {
        x = (x * 1.000_000_1).sqrt();
    }
    std::hint::black_box(x);
}

impl Evaluator for SimEvaluator {
    fn name(&self) -> String {
        // Matches PlatformId::fingerprint for the sim platforms.
        format!(
            "sim-{}/model-v{}",
            match self.gpu.spec.vendor {
                crate::platform::Vendor::Nvidia => "a100",
                crate::platform::Vendor::Amd => "mi250",
            },
            crate::platform::model::MODEL_VERSION
        )
    }

    fn evaluate_fidelity(&mut self, cfg: &Config, fidelity: f64) -> Result<f64, InvalidConfig> {
        self.calls += 1;
        eval_config(&self.gpu, &self.workload, &self.codegen, self.eval_cost, cfg, fidelity)
    }

    /// Parallel batched evaluation: contiguous chunks of the batch go to
    /// scoped worker threads; each worker writes into its own disjoint
    /// slice of the result vector, so the merge is in submission order
    /// by construction.
    fn evaluate_batch(
        &mut self,
        cfgs: &[Config],
        fidelity: f64,
    ) -> Vec<Result<f64, InvalidConfig>> {
        self.calls += cfgs.len();
        let pool = if self.parallel {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            1
        };
        let workers = pool.min(cfgs.len());
        let (gpu, workload, codegen) = (&self.gpu, &self.workload, &self.codegen);
        let cost = self.eval_cost;
        if workers <= 1 {
            return cfgs
                .iter()
                .map(|c| eval_config(gpu, workload, codegen, cost, c, fidelity))
                .collect();
        }
        let mut results: Vec<Option<Result<f64, InvalidConfig>>> = vec![None; cfgs.len()];
        let chunk = cfgs.len().div_ceil(workers);
        std::thread::scope(|s| {
            for (cfg_chunk, out_chunk) in cfgs.chunks(chunk).zip(results.chunks_mut(chunk)) {
                s.spawn(move || {
                    for (cfg, slot) in cfg_chunk.iter().zip(out_chunk.iter_mut()) {
                        *slot = Some(eval_config(gpu, workload, codegen, cost, cfg, fidelity));
                    }
                });
            }
        });
        results.into_iter().map(|r| r.expect("worker filled every slot")).collect()
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEvaluator;

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;

    use super::*;
    use crate::runtime::{Engine, Executable, Manifest, TensorF32};

    /// Evaluate by executing the real AOT artifact for a configuration.
    ///
    /// Compiled executables are memoized under the config's u64
    /// fingerprint (no per-lookup string allocation), so re-evaluations
    /// (e.g. at higher fidelity) only pay the execution cost.
    pub struct PjrtEvaluator<'a> {
        engine: &'a Engine,
        manifest: &'a Manifest,
        workload: Workload,
        /// Inputs pre-uploaded as device buffers: conversions stay off the
        /// measurement hot path (§Perf L3).
        buffers: Vec<xla::PjRtBuffer>,
        warmup: usize,
        iters: usize,
        compiled: HashMap<u64, Executable>,
        /// Cumulative compile count (the dominant tuning cost).
        pub compiles: usize,
    }

    impl<'a> PjrtEvaluator<'a> {
        /// `iters` at fidelity 1.0; lower fidelity proportionally reduces the
        /// measured iterations (min 1).
        pub fn new(
            engine: &'a Engine,
            manifest: &'a Manifest,
            workload: Workload,
            warmup: usize,
            iters: usize,
        ) -> crate::Result<Self> {
            let entry = manifest
                .candidates_for(&workload)
                .into_iter()
                .next()
                .ok_or_else(|| anyhow::anyhow!("no artifacts for workload {}", workload.key()))?;
            let buffers = entry
                .inputs
                .iter()
                .enumerate()
                .map(|(i, spec)| {
                    engine.upload(&TensorF32::random(&spec.shape, 0xC0FFEE + i as u64))
                })
                .collect::<crate::Result<Vec<_>>>()?;
            Ok(PjrtEvaluator {
                engine,
                manifest,
                workload,
                buffers,
                warmup,
                iters,
                compiled: HashMap::new(),
                compiles: 0,
            })
        }

        fn executable(&mut self, cfg: &Config) -> Result<&Executable, InvalidConfig> {
            let key = cfg.fingerprint();
            if !self.compiled.contains_key(&key) {
                let entry = self.manifest.find(&self.workload, cfg).ok_or_else(|| InvalidConfig {
                    reason: format!("no AOT artifact for config {cfg} on {}", self.workload.key()),
                })?;
                let exe = self
                    .engine
                    .load_artifact(&self.manifest.root, entry)
                    .map_err(|e| InvalidConfig { reason: format!("compile failed: {e}") })?;
                self.compiles += 1;
                self.compiled.insert(key, exe);
            }
            Ok(&self.compiled[&key])
        }
    }

    impl Evaluator for PjrtEvaluator<'_> {
        fn name(&self) -> String {
            crate::platform::PlatformId::CpuPjrt.fingerprint()
        }

        fn evaluate_fidelity(&mut self, cfg: &Config, fidelity: f64) -> Result<f64, InvalidConfig> {
            let warmup = self.warmup;
            let iters = ((self.iters as f64 * fidelity).round() as usize).max(1);
            self.executable(cfg)?; // borrow dance: compile first
            let args: Vec<&xla::PjRtBuffer> = self.buffers.iter().collect();
            let exe = &self.compiled[&cfg.fingerprint()];
            exe.time_us_buffers(&args, warmup, iters)
                .map_err(|e| InvalidConfig { reason: format!("execute: {e}") })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::baselines::HAND_TUNED;

    #[test]
    fn sim_evaluator_counts_calls() {
        let w = Workload::llama3_attention(4, 512);
        let mut e = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let cfg = Config::new(&[
            ("BLOCK_M", 64),
            ("BLOCK_N", 64),
            ("num_warps", 4),
            ("num_stages", 2),
            ("waves_per_eu", 0),
        ]);
        assert!(e.evaluate(&cfg).is_ok());
        assert_eq!(e.calls, 1);
    }

    #[test]
    fn sim_evaluator_name_is_platform_fingerprint() {
        let w = Workload::llama3_attention(4, 512);
        let e = SimEvaluator::new(SimGpu::mi250(), w, HAND_TUNED);
        assert_eq!(e.name(), crate::platform::PlatformId::SimMi250.fingerprint());
    }

    #[test]
    fn sim_evaluator_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SimEvaluator>();
    }

    #[test]
    fn parallel_batch_is_bit_identical_to_sequential() {
        let w = Workload::llama3_attention(8, 512);
        let space = crate::config::spaces::attention_sim_space();
        let cfgs: Vec<Config> = space.enumerate(&w).collect();
        assert!(cfgs.len() > 100, "need a real batch");
        let mut par = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut seq = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).sequential();
        let a = par.evaluate_batch(&cfgs, 1.0);
        let b = seq.evaluate_batch(&cfgs, 1.0);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            match (x, y) {
                (Ok(p), Ok(q)) => assert_eq!(p.to_bits(), q.to_bits(), "cfg {i} latency differs"),
                (Err(_), Err(_)) => {}
                _ => panic!("cfg {i}: validity differs between parallel and sequential"),
            }
        }
        assert_eq!(par.calls, cfgs.len());
        assert_eq!(seq.calls, cfgs.len());
    }

    #[test]
    fn eval_cost_does_not_change_results() {
        let w = Workload::llama3_attention(4, 512);
        let cfg = Config::new(&[
            ("BLOCK_M", 64),
            ("BLOCK_N", 64),
            ("num_warps", 4),
            ("num_stages", 2),
            ("waves_per_eu", 0),
        ]);
        let mut plain = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut costly = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).with_eval_cost(500);
        let a = plain.evaluate(&cfg).unwrap();
        let b = costly.evaluate(&cfg).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
