//! Evaluators: attach latencies to configurations.
//!
//! - [`SimEvaluator`] asks an analytical platform model (instant,
//!   deterministic) — used for the paper-figure reproductions.
//! - [`PjrtEvaluator`] compiles and *actually executes* the AOT artifact
//!   for a configuration on the PJRT CPU client and reports measured
//!   wall-clock — the real autotuning loop (compile cost dominates, just
//!   as the paper notes: "compilation time accounts for around 80 % of
//!   the autotuning time").

use std::collections::HashMap;

use crate::autotuner::Evaluator;
use crate::config::Config;
use crate::platform::model::{Codegen, InvalidConfig, SimGpu};
use crate::runtime::{Engine, Executable, Manifest, TensorF32};
use crate::workload::Workload;

/// Evaluate against an analytical GPU model.
pub struct SimEvaluator {
    pub gpu: SimGpu,
    pub workload: Workload,
    pub codegen: Codegen,
    /// Count of model evaluations performed (profiling aid).
    pub calls: usize,
}

impl SimEvaluator {
    pub fn new(gpu: SimGpu, workload: Workload, codegen: Codegen) -> Self {
        SimEvaluator { gpu, workload, codegen, calls: 0 }
    }
}

impl Evaluator for SimEvaluator {
    fn name(&self) -> String {
        // Matches PlatformId::fingerprint for the sim platforms.
        format!(
            "sim-{}/model-v{}",
            match self.gpu.spec.vendor {
                crate::platform::Vendor::Nvidia => "a100",
                crate::platform::Vendor::Amd => "mi250",
            },
            crate::platform::model::MODEL_VERSION
        )
    }

    fn evaluate_fidelity(&mut self, cfg: &Config, _fidelity: f64) -> Result<f64, InvalidConfig> {
        self.calls += 1;
        self.gpu.latency_us(cfg, &self.workload, &self.codegen)
    }
}

/// Evaluate by executing the real AOT artifact for a configuration.
///
/// Compiled executables are memoized, so re-evaluations (e.g. at higher
/// fidelity) only pay the execution cost.
pub struct PjrtEvaluator<'a> {
    engine: &'a Engine,
    manifest: &'a Manifest,
    workload: Workload,
    /// Inputs pre-uploaded as device buffers: conversions stay off the
    /// measurement hot path (§Perf L3).
    buffers: Vec<xla::PjRtBuffer>,
    warmup: usize,
    iters: usize,
    compiled: HashMap<String, Executable>,
    /// Cumulative compile count (the dominant tuning cost).
    pub compiles: usize,
}

impl<'a> PjrtEvaluator<'a> {
    /// `iters` at fidelity 1.0; lower fidelity proportionally reduces the
    /// measured iterations (min 1).
    pub fn new(engine: &'a Engine, manifest: &'a Manifest, workload: Workload, warmup: usize, iters: usize) -> crate::Result<Self> {
        let entry = manifest
            .candidates_for(&workload)
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("no artifacts for workload {}", workload.key()))?;
        let buffers = entry
            .inputs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                engine.upload(&TensorF32::random(&spec.shape, 0xC0FFEE + i as u64))
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(PjrtEvaluator {
            engine,
            manifest,
            workload,
            buffers,
            warmup,
            iters,
            compiled: HashMap::new(),
            compiles: 0,
        })
    }

    fn executable(&mut self, cfg: &Config) -> Result<&Executable, InvalidConfig> {
        let key = cfg.key();
        if !self.compiled.contains_key(&key) {
            let entry = self.manifest.find(&self.workload, cfg).ok_or_else(|| InvalidConfig {
                reason: format!("no AOT artifact for config {cfg} on {}", self.workload.key()),
            })?;
            let exe = self
                .engine
                .load_artifact(&self.manifest.root, entry)
                .map_err(|e| InvalidConfig { reason: format!("compile failed: {e}") })?;
            self.compiles += 1;
            self.compiled.insert(key.clone(), exe);
        }
        Ok(&self.compiled[&key])
    }
}

impl Evaluator for PjrtEvaluator<'_> {
    fn name(&self) -> String {
        crate::platform::PlatformId::CpuPjrt.fingerprint()
    }

    fn evaluate_fidelity(&mut self, cfg: &Config, fidelity: f64) -> Result<f64, InvalidConfig> {
        let warmup = self.warmup;
        let iters = ((self.iters as f64 * fidelity).round() as usize).max(1);
        self.executable(cfg)?; // borrow dance: compile first
        let args: Vec<&xla::PjRtBuffer> = self.buffers.iter().collect();
        let exe = &self.compiled[&cfg.key()];
        exe.time_us_buffers(&args, warmup, iters)
            .map_err(|e| InvalidConfig { reason: format!("execute: {e}") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::baselines::HAND_TUNED;

    #[test]
    fn sim_evaluator_counts_calls() {
        let w = Workload::llama3_attention(4, 512);
        let mut e = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let cfg = Config::new(&[
            ("BLOCK_M", 64),
            ("BLOCK_N", 64),
            ("num_warps", 4),
            ("num_stages", 2),
            ("waves_per_eu", 0),
        ]);
        assert!(e.evaluate(&cfg).is_ok());
        assert_eq!(e.calls, 1);
    }

    #[test]
    fn sim_evaluator_name_is_platform_fingerprint() {
        let w = Workload::llama3_attention(4, 512);
        let e = SimEvaluator::new(SimGpu::mi250(), w, HAND_TUNED);
        assert_eq!(e.name(), crate::platform::PlatformId::SimMi250.fingerprint());
    }
}
