//! Evaluators: attach latencies to configurations.
//!
//! - [`SimEvaluator`] asks an analytical platform model (instant,
//!   deterministic) — used for the paper-figure reproductions.  It is
//!   `Send + Sync` and overrides [`Evaluator::evaluate_batch`]: by
//!   default batches fan out over the persistent shared
//!   [`WorkerPool`](crate::util::pool::WorkerPool) ([`BatchMode::Pool`]);
//!   the PR 1 per-batch `std::thread::scope` path is kept as
//!   [`BatchMode::ScopedThreads`] so the bench can measure what the pool
//!   buys.  All modes merge results in submission order, so every mode
//!   is bit-identical to the sequential path.
//! - [`MultiDeviceEvaluator`] shards each batch across N per-device
//!   evaluators (simulated device replicas for now) — the
//!   placement-agnostic step toward the ROADMAP's multi-GPU evaluator —
//!   and tracks per-device utilization via [`crate::metrics::DeviceUtil`].
//! - [`SurrogatePrior`](crate::surrogate::SurrogatePrior) (in
//!   [`crate::surrogate`]) is the *learned* evaluator: a fitted
//!   [`CostModel`](crate::surrogate::CostModel) borrowed as an
//!   [`Evaluator`], so it plugs straight into
//!   [`TuningSession::guided`](crate::autotuner::TuningSession::guided)
//!   as a self-generated prior.
//! - `PjrtEvaluator` (feature `pjrt`) compiles and *actually executes*
//!   the AOT artifact for a configuration on the PJRT CPU client and
//!   reports measured wall-clock — the real autotuning loop (compile
//!   cost dominates, just as the paper notes: "compilation time accounts
//!   for around 80 % of the autotuning time").  PJRT handles are not
//!   `Send`, so it relies on the trait's sequential `evaluate_batch`
//!   default.

use std::time::Instant;

use crate::autotuner::{BatchSlot, Evaluator};
use crate::config::Config;
use crate::metrics::DeviceUtil;
use crate::platform::model::{Codegen, InvalidConfig, SimGpu};
use crate::serving::chaos::FaultPlan;
use crate::util::pool;
use crate::workload::Workload;

/// How a [`SimEvaluator`] executes [`Evaluator::evaluate_batch`].
///
/// Every mode produces bit-identical results (the merge is in
/// submission order and the model is deterministic); they differ only
/// in wall-clock cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Every evaluation on the caller's thread — the equivalence
    /// baseline for tests and benches.
    Sequential,
    /// One `std::thread::scope` per batch (the PR 1 engine): threads
    /// are re-spawned for every batch.  Kept as the bench baseline the
    /// persistent pool is measured against.
    ScopedThreads,
    /// The persistent shared worker pool (`util::pool::global`) —
    /// the default: no per-batch thread spawn, one thread set shared by
    /// every evaluator in the process.  Work-stealing scheduling
    /// ([`crate::util::pool::Discipline::WorkStealing`]).
    Pool,
    /// The previous pool scheduling discipline
    /// ([`crate::util::pool::global_v1`]): one shared mutex-guarded
    /// queue.  Kept so the bench ladder can measure what work-stealing
    /// buys over it (seq → scoped → pool-v1 → pool-v2); results are
    /// bit-identical to every other mode.
    PoolV1,
}

/// Evaluate against an analytical GPU model.
#[derive(Debug, Clone)]
pub struct SimEvaluator {
    /// The modeled device.
    pub gpu: SimGpu,
    /// The workload being tuned.
    pub workload: Workload,
    /// Codegen-quality knobs of the software stack under test.
    pub codegen: Codegen,
    /// Count of model evaluations performed (profiling aid).
    pub calls: usize,
    /// Batch execution mode (default [`BatchMode::Pool`]).
    mode: BatchMode,
    /// Synthetic per-evaluation work (spin iterations) standing in for
    /// the compile+measure cost a real evaluator pays.  0 = pure model.
    /// The autotuner bench uses this to measure thread-pool scaling at a
    /// realistic per-config cost; it never changes the returned latency.
    eval_cost: u32,
}

impl SimEvaluator {
    /// A pool-parallel evaluator (the default mode) for `workload` on
    /// the modeled `gpu` at `codegen` quality.
    pub fn new(gpu: SimGpu, workload: Workload, codegen: Codegen) -> Self {
        SimEvaluator { gpu, workload, codegen, calls: 0, mode: BatchMode::Pool, eval_cost: 0 }
    }

    /// Disable parallelism: every evaluation runs on the caller's
    /// thread.  Used as the baseline in equivalence tests and benches.
    pub fn sequential(mut self) -> Self {
        self.mode = BatchMode::Sequential;
        self
    }

    /// Use a fresh `std::thread::scope` per batch (the PR 1 engine) —
    /// the bench baseline the persistent pool is compared against.
    pub fn scoped_threads(mut self) -> Self {
        self.mode = BatchMode::ScopedThreads;
        self
    }

    /// Use the persistent shared worker pool (the default for new
    /// evaluators; this restores it on a clone whose mode was changed,
    /// e.g. a fleet device pinned to sequential).
    pub fn pooled(mut self) -> Self {
        self.mode = BatchMode::Pool;
        self
    }

    /// Use the mutex-queue worker pool (the pre-work-stealing engine) —
    /// the bench baseline [`BatchMode::Pool`] is compared against.
    pub fn pool_v1(mut self) -> Self {
        self.mode = BatchMode::PoolV1;
        self
    }

    /// Current batch execution mode.
    pub fn mode(&self) -> BatchMode {
        self.mode
    }

    /// Attach a synthetic per-evaluation cost (spin iterations).
    pub fn with_eval_cost(mut self, iters: u32) -> Self {
        self.eval_cost = iters;
        self
    }
}

/// Smallest batch chunk worth scheduling on its own worker.  Below
/// this, the fixed cost of submitting and merging a task exceeds the
/// model work inside it, so small batches use proportionally fewer
/// workers (a 4-config batch runs on the caller's thread instead of
/// fanning four 1-config tasks across the pool).
const MIN_CHUNK: usize = 16;

/// The model query itself, free of `&mut self` so worker threads can
/// share the evaluator state immutably.
fn eval_config(
    gpu: &SimGpu,
    workload: &Workload,
    codegen: &Codegen,
    cost: u32,
    cfg: &Config,
    _fidelity: f64,
) -> Result<f64, InvalidConfig> {
    burn(cost, cfg);
    gpu.latency_us(cfg, workload, codegen)
}

/// Deterministic spin standing in for per-config compile/measure time.
/// Serial sqrt chain: the compiler cannot collapse it, and the result
/// feeds `black_box`, so `cost` iterations really execute.
fn burn(cost: u32, cfg: &Config) {
    if cost == 0 {
        return;
    }
    let mut x = 1.0 + (cfg.fingerprint() & 0x3FF) as f64 * 1e-12;
    for _ in 0..cost {
        x = (x * 1.000_000_1).sqrt();
    }
    std::hint::black_box(x);
}

impl Evaluator for SimEvaluator {
    fn name(&self) -> String {
        // Matches PlatformId::fingerprint for the sim platforms.  The
        // identity is the GPU *model* slug ([`GpuSpec::model`]), not
        // the vendor: fleets key `platforms()`/`platform_evaluator()`
        // on this name, so two different models must never alias (an
        // H100 is not an A100, even though both are NVIDIA).
        format!(
            "sim-{}/model-v{}",
            self.gpu.spec.model,
            crate::platform::model::MODEL_VERSION
        )
    }

    fn evaluate_fidelity(&mut self, cfg: &Config, fidelity: f64) -> Result<f64, InvalidConfig> {
        self.calls += 1;
        eval_config(&self.gpu, &self.workload, &self.codegen, self.eval_cost, cfg, fidelity)
    }

    /// Parallel batched evaluation straight into the caller's slab:
    /// contiguous chunks of the batch go to worker threads (persistent
    /// pool by default, per-batch scoped threads in
    /// [`BatchMode::ScopedThreads`]); each worker writes into its own
    /// disjoint slice of `out`, so the merge is in submission order by
    /// construction.  The `Vec`-returning [`Evaluator::evaluate_batch`]
    /// derives from this, so both spellings share one engine.
    ///
    /// Chunks are sized adaptively ([`MIN_CHUNK`]): a batch smaller
    /// than `MIN_CHUNK × workers` uses fewer workers rather than paying
    /// fan-out overhead per config.
    fn evaluate_batch_into(&mut self, cfgs: &[Config], fidelity: f64, out: &mut [BatchSlot]) {
        assert!(out.len() >= cfgs.len(), "output slab shorter than batch");
        self.calls += cfgs.len();
        let out = &mut out[..cfgs.len()];
        let workers = match self.mode {
            BatchMode::Sequential => 1,
            BatchMode::ScopedThreads | BatchMode::Pool | BatchMode::PoolV1 => {
                pool::default_workers()
            }
        }
        .min(cfgs.len().div_ceil(MIN_CHUNK));
        let (gpu, workload, codegen) = (&self.gpu, &self.workload, &self.codegen);
        let cost = self.eval_cost;
        if workers <= 1 {
            for (cfg, slot) in cfgs.iter().zip(out.iter_mut()) {
                *slot = Some(eval_config(gpu, workload, codegen, cost, cfg, fidelity));
            }
            return;
        }
        let chunk = cfgs.len().div_ceil(workers);
        // One worker body shared by every engine — the engines differ
        // only in who runs it, so they can never diverge behaviorally.
        let run_chunk = |cfg_chunk: &[Config], out_chunk: &mut [BatchSlot]| {
            for (cfg, slot) in cfg_chunk.iter().zip(out_chunk.iter_mut()) {
                *slot = Some(eval_config(gpu, workload, codegen, cost, cfg, fidelity));
            }
        };
        let run_chunk = &run_chunk;
        match self.mode {
            BatchMode::ScopedThreads => {
                std::thread::scope(|s| {
                    for (cfg_chunk, out_chunk) in cfgs.chunks(chunk).zip(out.chunks_mut(chunk)) {
                        s.spawn(move || run_chunk(cfg_chunk, out_chunk));
                    }
                });
            }
            BatchMode::Pool | BatchMode::PoolV1 => {
                let pool = match self.mode {
                    BatchMode::Pool => pool::global(),
                    _ => pool::global_v1(),
                };
                pool.scope(|s| {
                    for (cfg_chunk, out_chunk) in cfgs.chunks(chunk).zip(out.chunks_mut(chunk)) {
                        s.spawn(move || run_chunk(cfg_chunk, out_chunk));
                    }
                });
            }
            BatchMode::Sequential => unreachable!("workers > 1 implies a parallel mode"),
        }
    }
}

/// Shards each evaluation batch across a fleet of per-device
/// evaluators — the placement-agnostic multi-device evaluator the batch
/// API was designed for (ROADMAP: "wire `evaluate_batch` into a future
/// multi-GPU evaluator").
///
/// Each device receives one contiguous shard of the batch and evaluates
/// it *sequentially* (a device is serial hardware); shards run
/// concurrently on the shared worker pool.  Results merge back in
/// submission order, so for a fleet of identical replicas the outcome is
/// bit-identical to a single sequential evaluator — pinned by
/// `tests/parallel_equiv.rs`.
///
/// **Heterogeneous fleets**: in the sharded mode, *which platform
/// measures a config* is determined by the config's position in the
/// batch — deterministic and reproducible (the cache key encodes the
/// exact device layout), but a search over such a fleet optimizes
/// "fastest (config, placement)" over one logical mixed pool, not any
/// single platform; adaptive strategies additionally confirm through
/// the single-eval path (device 0) and would rank cross-platform
/// measurements against each other.  The per-platform argmin the paper
/// calls for is [`crate::autotuner::TuningSession::fleet`], which drives the
/// measure-everywhere merge
/// ([`MultiDeviceEvaluator::evaluate_batch_everywhere`]) instead.
///
/// Per-device work counters ([`crate::metrics::DeviceUtil`]) record how
/// many configurations and shards each device processed and how long it
/// was busy; [`MultiDeviceEvaluator::utilization`] exposes them together
/// with the fleet wall-clock ([`MultiDeviceEvaluator::wall_us`]).
///
/// The real-execution path (`PjrtEvaluator`) stays sequential behind the
/// `pjrt` feature: PJRT handles are not `Send`, so a per-device-thread
/// engine story is a prerequisite (see ROADMAP).
pub struct MultiDeviceEvaluator {
    devices: Vec<SimEvaluator>,
    util: Vec<DeviceUtil>,
    /// Distinct platform names, sorted — the row order of
    /// [`MultiDeviceEvaluator::evaluate_batch_everywhere`].  Built once
    /// at construction; platform names are stable for a fleet's
    /// lifetime, so the old per-call collect/sort/dedup (and the
    /// per-call `d.name()` string formatting it forced) was pure churn.
    platform_names: Vec<String>,
    /// Index into `platform_names` per device, fleet order.
    device_platform: Vec<usize>,
    /// Replica count per platform, aligned with `platform_names`.
    platform_replicas: Vec<usize>,
    wall_us: f64,
}

impl MultiDeviceEvaluator {
    /// Build a fleet from per-device evaluators.  Each device is forced
    /// into sequential mode — intra-device parallelism would nest
    /// scopes for no benefit; the fleet's parallelism is across devices.
    ///
    /// # Panics
    /// Panics when `devices` is empty, or when two devices share a
    /// platform name but differ in workload or codegen: the platform
    /// name is the cache and argmin identity, so same-name devices must
    /// be true replicas (otherwise a platform's sharded results would
    /// mix two different models and change with shard boundaries).
    pub fn new(devices: Vec<SimEvaluator>) -> Self {
        assert!(!devices.is_empty(), "a device fleet needs at least one device");
        // One name() formatting pass for the whole constructor; the
        // replica-identity check and the platform index both read it.
        let names: Vec<String> = devices.iter().map(|d| d.name()).collect();
        for (i, a) in devices.iter().enumerate() {
            for (j, b) in devices.iter().enumerate().skip(i + 1) {
                if names[i] == names[j] {
                    assert!(
                        a.codegen == b.codegen && a.workload == b.workload,
                        "devices sharing platform {} must be identical replicas \
                         (same workload and codegen): the platform name is the \
                         cache/argmin identity",
                        names[i]
                    );
                }
            }
        }
        let mut platform_names = names.clone();
        platform_names.sort();
        platform_names.dedup();
        let device_platform: Vec<usize> = names
            .iter()
            .map(|n| platform_names.binary_search(n).expect("index covers every device"))
            .collect();
        let mut platform_replicas = vec![0usize; platform_names.len()];
        for &p in &device_platform {
            platform_replicas[p] += 1;
        }
        let devices: Vec<SimEvaluator> = devices.into_iter().map(|d| d.sequential()).collect();
        let util = names
            .into_iter()
            .map(|device| DeviceUtil { device, ..DeviceUtil::default() })
            .collect();
        MultiDeviceEvaluator {
            devices,
            util,
            platform_names,
            device_platform,
            platform_replicas,
            wall_us: 0.0,
        }
    }

    /// A fleet of `n` identical replicas of `proto` — the homogeneous
    /// case (tuning one platform faster).  Heterogeneous fleets (one
    /// evaluator per distinct device model) go through
    /// [`MultiDeviceEvaluator::new`].
    pub fn replicate(proto: &SimEvaluator, n: usize) -> Self {
        assert!(n > 0, "a device fleet needs at least one device");
        Self::new((0..n).map(|_| proto.clone()).collect())
    }

    /// Number of devices in the fleet.
    pub fn devices(&self) -> usize {
        self.devices.len()
    }

    /// The *distinct* device platforms in the fleet, sorted by name —
    /// the row order of [`MultiDeviceEvaluator::evaluate_batch_everywhere`]
    /// and of fleet tuning's per-platform outcomes
    /// ([`crate::autotuner::FleetOutcome::outcomes`]).  Borrowed from
    /// the index built at construction; `.to_vec()` it when the names
    /// must outlive a later mutable use of the fleet.
    pub fn platforms(&self) -> &[String] {
        &self.platform_names
    }

    /// A standalone sequential evaluator for one platform of the fleet
    /// (a clone of its first device) — used by `tune_fleet` to run the
    /// adaptive strategies once per platform, and handy for re-checking
    /// a fleet result against a single device.
    pub fn platform_evaluator(&self, platform: &str) -> Option<SimEvaluator> {
        let i = self.util.iter().position(|u| u.device == platform)?;
        Some(self.devices[i].clone())
    }

    /// Credit work performed outside the fleet's own batch paths (e.g.
    /// `tune_fleet`'s per-platform adaptive searches) to the first
    /// device of `platform`, so utilization reports cover the whole run.
    pub(crate) fn credit_platform(&mut self, platform: &str, evaluated: usize, busy_us: f64) {
        if let Some(i) = self.util.iter().position(|u| u.device == platform) {
            self.util[i].evaluated += evaluated;
            self.util[i].busy_us += busy_us;
            self.wall_us += busy_us;
        }
    }

    /// **Measure-everywhere** merge (the "A Few Fit Most" regime): the
    /// whole batch is evaluated on *every distinct platform* of the
    /// fleet, concurrently on the shared worker pool.  `out[p][i]` is
    /// platform `p`'s result for `cfgs[i]`, with `p` indexing
    /// [`MultiDeviceEvaluator::platforms`] order.
    ///
    /// Replicas of the same platform split their platform's copy of the
    /// batch into contiguous shards (more replicas of a platform finish
    /// its copy faster); each shard is evaluated sequentially, so every
    /// platform row is bit-identical to a single sequential evaluator
    /// of that platform — the property `tune_fleet` builds its
    /// per-platform argmin on.
    ///
    /// This is the *other* merge over the same batch API: sharded
    /// [`MultiDeviceEvaluator::evaluate_batch`] splits a batch across
    /// the fleet for throughput (each config measured once), while this
    /// mode replicates it for coverage (each config measured once per
    /// platform, counted in [`DeviceUtil::replicated`]).
    pub fn evaluate_batch_everywhere(
        &mut self,
        cfgs: &[Config],
        fidelity: f64,
    ) -> Vec<Vec<Result<f64, InvalidConfig>>> {
        if cfgs.is_empty() {
            return vec![Vec::new(); self.platform_names.len()];
        }
        let t0 = Instant::now();
        let mut rows: Vec<Vec<BatchSlot>> =
            self.platform_names.iter().map(|_| vec![None; cfgs.len()]).collect();
        {
            // Destructure so the borrow checker sees the disjoint
            // fields (devices/util mutably, the platform index shared).
            let MultiDeviceEvaluator { devices, util, device_platform, platform_replicas, .. } =
                self;
            // Each platform's copy of the batch splits into one
            // contiguous shard per replica; replicas consume their
            // platform's shards in fleet order, which is exactly the
            // assignment the old partition-based merge produced — so
            // every platform row stays bit-identical to a solo
            // sequential evaluator of that platform.
            let mut shards: Vec<_> = platform_replicas
                .iter()
                .zip(rows.iter_mut())
                .map(|(&replicas, row)| {
                    let shard = cfgs.len().div_ceil(replicas);
                    (cfgs.chunks(shard), row.chunks_mut(shard))
                })
                .collect();
            pool::global().scope(|s| {
                for ((dev, util), &p) in
                    devices.iter_mut().zip(util.iter_mut()).zip(device_platform.iter())
                {
                    let (cfg_chunks, out_chunks) = &mut shards[p];
                    // More replicas than shards: trailing replicas of a
                    // platform idle (a 1-config batch occupies one).
                    if let (Some(cfg_chunk), Some(out_chunk)) =
                        (cfg_chunks.next(), out_chunks.next())
                    {
                        s.spawn(move || {
                            let t = Instant::now();
                            dev.evaluate_batch_into(cfg_chunk, fidelity, out_chunk);
                            util.evaluated += cfg_chunk.len();
                            util.replicated += cfg_chunk.len();
                            util.shards += 1;
                            util.busy_us += t.elapsed().as_secs_f64() * 1e6;
                        });
                    }
                }
            });
        }
        self.wall_us += t0.elapsed().as_secs_f64() * 1e6;
        rows.into_iter()
            .map(|per| {
                per.into_iter().map(|r| r.expect("platform filled every slot")).collect()
            })
            .collect()
    }

    /// Per-device work counters, index-aligned with the fleet.
    pub fn utilization(&self) -> &[DeviceUtil] {
        &self.util
    }

    /// Total wall-clock time spent inside batch evaluation, µs (the
    /// denominator for [`crate::metrics::DeviceUtil::utilization`]).
    pub fn wall_us(&self) -> f64 {
        self.wall_us
    }
}

impl Evaluator for MultiDeviceEvaluator {
    /// Fleet platform identity.  A **homogeneous** fleet shares its
    /// cache key (and persisted winners) with a single device of the
    /// same platform: sharded results are bit-identical to a single
    /// device regardless of replica count or order, so cached entries
    /// are interchangeable.  A **heterogeneous** fleet's sharded
    /// results, however, depend on which platform each contiguous
    /// shard lands on — i.e. on the exact device sequence — so its
    /// `multi[...]` key encodes the layout verbatim, replicas and
    /// order included: two different orderings of the same device set
    /// are NOT interchangeable and must not share a cache entry.
    /// (Fleet *tuning* sidesteps all of this: `tune_fleet_cached`
    /// persists per-platform winners under each platform's own key.)
    fn name(&self) -> String {
        if self.platform_names.len() == 1 {
            self.platform_names[0].clone()
        } else {
            let names: Vec<&str> = self.util.iter().map(|u| u.device.as_str()).collect();
            format!("multi[{}]", names.join("+"))
        }
    }

    /// Single evaluations route to device 0 (no fan-out to pay for).
    fn evaluate_fidelity(&mut self, cfg: &Config, fidelity: f64) -> Result<f64, InvalidConfig> {
        let t0 = Instant::now();
        let res = self.devices[0].evaluate_fidelity(cfg, fidelity);
        let dt = t0.elapsed().as_secs_f64() * 1e6;
        self.util[0].evaluated += 1;
        self.util[0].busy_us += dt;
        self.wall_us += dt;
        res
    }

    /// Shard the batch into one contiguous chunk per device and
    /// evaluate the shards concurrently on the shared worker pool,
    /// writing straight into the caller's slab; results merge in
    /// submission order.  The `Vec` form derives from this.
    fn evaluate_batch_into(&mut self, cfgs: &[Config], fidelity: f64, out: &mut [BatchSlot]) {
        assert!(out.len() >= cfgs.len(), "output slab shorter than batch");
        if cfgs.is_empty() {
            return;
        }
        let out = &mut out[..cfgs.len()];
        let n = self.devices.len().min(cfgs.len());
        let t0 = Instant::now();
        let chunk = cfgs.len().div_ceil(n);
        if n <= 1 {
            self.devices[0].evaluate_batch_into(cfgs, fidelity, out);
            let dt = t0.elapsed().as_secs_f64() * 1e6;
            self.util[0].evaluated += cfgs.len();
            self.util[0].shards += 1;
            self.util[0].busy_us += dt;
            self.wall_us += dt;
            return;
        }
        pool::global().scope(|s| {
            for ((dev, util), (cfg_chunk, out_chunk)) in self
                .devices
                .iter_mut()
                .zip(self.util.iter_mut())
                .zip(cfgs.chunks(chunk).zip(out.chunks_mut(chunk)))
            {
                s.spawn(move || {
                    let t = Instant::now();
                    dev.evaluate_batch_into(cfg_chunk, fidelity, out_chunk);
                    util.evaluated += cfg_chunk.len();
                    util.shards += 1;
                    util.busy_us += t.elapsed().as_secs_f64() * 1e6;
                });
            }
        });
        self.wall_us += t0.elapsed().as_secs_f64() * 1e6;
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEvaluator;

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;

    use super::*;
    use crate::runtime::{Engine, Executable, Manifest, TensorF32};

    /// Evaluate by executing the real AOT artifact for a configuration.
    ///
    /// Compiled executables are memoized under the config's u64
    /// fingerprint (no per-lookup string allocation), so re-evaluations
    /// (e.g. at higher fidelity) only pay the execution cost.
    pub struct PjrtEvaluator<'a> {
        engine: &'a Engine,
        manifest: &'a Manifest,
        workload: Workload,
        /// Inputs pre-uploaded as device buffers: conversions stay off the
        /// measurement hot path (§Perf L3).
        buffers: Vec<xla::PjRtBuffer>,
        warmup: usize,
        iters: usize,
        compiled: HashMap<u64, Executable>,
        /// Cumulative compile count (the dominant tuning cost).
        pub compiles: usize,
    }

    impl<'a> PjrtEvaluator<'a> {
        /// `iters` at fidelity 1.0; lower fidelity proportionally reduces the
        /// measured iterations (min 1).
        pub fn new(
            engine: &'a Engine,
            manifest: &'a Manifest,
            workload: Workload,
            warmup: usize,
            iters: usize,
        ) -> crate::Result<Self> {
            let entry = manifest
                .candidates_for(&workload)
                .into_iter()
                .next()
                .ok_or_else(|| anyhow::anyhow!("no artifacts for workload {}", workload.key()))?;
            let buffers = entry
                .inputs
                .iter()
                .enumerate()
                .map(|(i, spec)| {
                    engine.upload(&TensorF32::random(&spec.shape, 0xC0FFEE + i as u64))
                })
                .collect::<crate::Result<Vec<_>>>()?;
            Ok(PjrtEvaluator {
                engine,
                manifest,
                workload,
                buffers,
                warmup,
                iters,
                compiled: HashMap::new(),
                compiles: 0,
            })
        }

        fn executable(&mut self, cfg: &Config) -> Result<&Executable, InvalidConfig> {
            let key = cfg.fingerprint();
            if !self.compiled.contains_key(&key) {
                let entry = self.manifest.find(&self.workload, cfg).ok_or_else(|| InvalidConfig {
                    reason: format!("no AOT artifact for config {cfg} on {}", self.workload.key()),
                })?;
                let exe = self
                    .engine
                    .load_artifact(&self.manifest.root, entry)
                    .map_err(|e| InvalidConfig { reason: format!("compile failed: {e}") })?;
                self.compiles += 1;
                self.compiled.insert(key, exe);
            }
            Ok(&self.compiled[&key])
        }
    }

    impl Evaluator for PjrtEvaluator<'_> {
        fn name(&self) -> String {
            crate::platform::PlatformId::CpuPjrt.fingerprint()
        }

        fn evaluate_fidelity(&mut self, cfg: &Config, fidelity: f64) -> Result<f64, InvalidConfig> {
            let warmup = self.warmup;
            let iters = ((self.iters as f64 * fidelity).round() as usize).max(1);
            self.executable(cfg)?; // borrow dance: compile first
            let args: Vec<&xla::PjRtBuffer> = self.buffers.iter().collect();
            let exe = &self.compiled[&cfg.fingerprint()];
            exe.time_us_buffers(&args, warmup, iters)
                .map_err(|e| InvalidConfig { reason: format!("execute: {e}") })
        }
    }
}

/// Fault-injecting decorator over any [`Evaluator`] — the tuning-side
/// sibling of [`crate::serving::ChaosBackend`], sharing its
/// [`FaultPlan`] so `TuningSession` runs can be stressed the same way
/// the serving plane is.
///
/// Per evaluation, a single seeded draw (a pure function of the plan
/// seed, the config fingerprint, and a per-config attempt ordinal)
/// decides the fate: a transient fault surfaces as an
/// [`InvalidConfig`] (exactly how strategies already treat
/// platform-rejected configs, so every search survives it by
/// construction), and a latency outlier spikes one of three virtual
/// samples and is absorbed bit-for-bit by the
/// [`crate::metrics::median`] aggregate.  Clean evaluations pass the
/// inner latency through untouched, so chaos runs stay bit-reproducible
/// per seed.
pub struct ChaosEvaluator<E: Evaluator> {
    inner: E,
    plan: FaultPlan,
    /// Per-config attempt ordinals (the re-roll axis).
    attempts: std::collections::HashMap<u64, u64>,
    injected: usize,
}

impl<E: Evaluator> ChaosEvaluator<E> {
    /// Wrap `inner` with the fault schedule `plan` (only the
    /// `transient.measure`, `outlier_rate`/`outlier_mult` and
    /// `max_injected` fields apply — an evaluator has one verb).
    pub fn new(inner: E, plan: FaultPlan) -> Self {
        ChaosEvaluator { inner, plan, attempts: std::collections::HashMap::new(), injected: 0 }
    }

    /// Faults injected so far.
    pub fn injected(&self) -> usize {
        self.injected
    }

    /// The wrapped evaluator.
    pub fn inner(&self) -> &E {
        &self.inner
    }
}

impl<E: Evaluator> Evaluator for ChaosEvaluator<E> {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn evaluate_fidelity(&mut self, cfg: &Config, fidelity: f64) -> Result<f64, InvalidConfig> {
        let fp = cfg.fingerprint();
        let attempt = {
            let a = self.attempts.entry(fp).or_insert(0);
            let v = *a;
            *a += 1;
            v
        };
        let healed = matches!(self.plan.max_injected, Some(max) if self.injected >= max);
        if !healed {
            let r = crate::util::rng::Rng::seed_from(
                self.plan.seed ^ fp.rotate_left(7) ^ attempt.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            )
            .f64();
            if r < self.plan.transient.measure {
                self.injected += 1;
                return Err(InvalidConfig {
                    reason: format!("injected transient fault (chaos, attempt {attempt})"),
                });
            }
            if r < self.plan.transient.measure + self.plan.outlier_rate {
                self.injected += 1;
                let base = self.inner.evaluate_fidelity(cfg, fidelity)?;
                let mult = self.plan.outlier_mult;
                return Ok(crate::metrics::median(&[base * mult, base, base]));
            }
        }
        self.inner.evaluate_fidelity(cfg, fidelity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::baselines::HAND_TUNED;

    #[test]
    fn chaos_evaluator_sessions_complete_and_are_deterministic() {
        use crate::autotuner::{SessionOutcome, Strategy, TuningSession};
        use crate::serving::VerbRates;
        let w = Workload::llama3_attention(8, 1024);
        let space = crate::config::spaces::attention_sim_space();
        let run = || {
            let mut eval = ChaosEvaluator::new(
                SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).sequential(),
                FaultPlan {
                    seed: 3,
                    transient: VerbRates { measure: 0.3, ..VerbRates::default() },
                    ..FaultPlan::default()
                },
            );
            let out = TuningSession::new(&space, &w)
                .strategy(Strategy::Random { budget: 40 })
                .seed(3)
                .evaluator(&mut eval)
                .run()
                .and_then(SessionOutcome::into_solo)
                .expect("a 0.3 transient rate cannot sink all 40 evaluations");
            (out.best.fingerprint(), out.best_latency_us.to_bits(), eval.injected())
        };
        let (fp1, lat1, inj1) = run();
        let (fp2, lat2, inj2) = run();
        assert!(inj1 > 0, "rate 0.3 over a 40-eval session must inject faults");
        assert_eq!(fp1, fp2, "chaos tuning must be reproducible per seed");
        assert_eq!(lat1, lat2, "best latency must be bit-identical across reruns");
        assert_eq!(inj1, inj2, "fault schedule must be bit-reproducible");
    }

    #[test]
    fn surrogate_prior_plugs_into_guided_sessions() {
        // The tentpole contract: a fitted CostModel, borrowed as an
        // Evaluator, IS a `.guided()` prior — no adapter code beyond
        // `model.prior(w)`.
        use crate::autotuner::{SessionOutcome, TuningSession};
        use crate::surrogate::{CostModel, RIDGE_LAMBDA};
        let w = Workload::llama3_attention(1, 256);
        let space = crate::config::spaces::attention_sim_space();
        let mut target = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).sequential();
        // Train on a cheap seed sample measured at full fidelity.
        let samples: Vec<(Config, Workload, f64)> = space
            .equally_spaced(&w, 48)
            .into_iter()
            .filter_map(|c| target.evaluate(&c).ok().map(|us| (c, w, us)))
            .collect();
        let model = CostModel::fit(&target.name(), &samples, RIDGE_LAMBDA)
            .expect("48 full-fidelity samples must fit the attention schema");
        let mut prior = model.prior(w);
        let guided = TuningSession::new(&space, &w)
            .guided(&mut prior, 32)
            .evaluator(&mut SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).sequential())
            .run()
            .and_then(SessionOutcome::into_solo)
            .expect("guided session completes");
        let exhaustive = TuningSession::new(&space, &w)
            .evaluator(&mut SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).sequential())
            .run()
            .and_then(SessionOutcome::into_solo)
            .expect("exhaustive session completes");
        assert!(
            guided.best_latency_us <= exhaustive.best_latency_us * 1.10,
            "learned-prior top-32 winner ({} µs) must be within 10% of exhaustive ({} µs)",
            guided.best_latency_us,
            exhaustive.best_latency_us
        );
    }

    #[test]
    fn sim_evaluator_counts_calls() {
        let w = Workload::llama3_attention(4, 512);
        let mut e = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let cfg = Config::new(&[
            ("BLOCK_M", 64),
            ("BLOCK_N", 64),
            ("num_warps", 4),
            ("num_stages", 2),
            ("waves_per_eu", 0),
        ]);
        assert!(e.evaluate(&cfg).is_ok());
        assert_eq!(e.calls, 1);
    }

    #[test]
    fn sim_evaluator_name_is_platform_fingerprint() {
        let w = Workload::llama3_attention(4, 512);
        let e = SimEvaluator::new(SimGpu::mi250(), w, HAND_TUNED);
        assert_eq!(e.name(), crate::platform::PlatformId::SimMi250.fingerprint());
        let a = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        assert_eq!(a.name(), crate::platform::PlatformId::SimA100.fingerprint());
    }

    #[test]
    fn distinct_gpu_models_never_alias_as_one_platform() {
        // The platform identity is the GPU *model*, not the vendor: an
        // H100 device in a fleet must form its own platform row, not be
        // merged into the A100's (which would mix two models' latencies
        // under one argmin).
        let w = Workload::llama3_attention(4, 512);
        let a = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let h = SimEvaluator::new(SimGpu::h100(), w, HAND_TUNED);
        assert_ne!(a.name(), h.name(), "an H100 is not an A100");
        let fleet = MultiDeviceEvaluator::new(vec![a, h]);
        assert_eq!(fleet.platforms().len(), 2);
        assert!(fleet.name().starts_with("multi["), "{}", fleet.name());
    }

    #[test]
    fn sim_evaluator_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SimEvaluator>();
    }

    #[test]
    fn default_mode_is_pool() {
        let w = Workload::llama3_attention(4, 512);
        let e = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        assert_eq!(e.mode(), BatchMode::Pool);
        assert_eq!(e.sequential().mode(), BatchMode::Sequential);
    }

    #[test]
    fn every_parallel_mode_is_bit_identical_to_sequential() {
        let w = Workload::llama3_attention(8, 512);
        let space = crate::config::spaces::attention_sim_space();
        let cfgs: Vec<Config> = space.enumerate(&w).collect();
        assert!(cfgs.len() > 100, "need a real batch");
        let mut seq = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).sequential();
        let baseline = seq.evaluate_batch(&cfgs, 1.0);
        for par in [
            SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED), // pool default
            SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).scoped_threads(),
            SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).pool_v1(),
        ] {
            let mut par = par;
            let a = par.evaluate_batch(&cfgs, 1.0);
            assert_eq!(a.len(), baseline.len());
            for (i, (x, y)) in a.iter().zip(&baseline).enumerate() {
                match (x, y) {
                    (Ok(p), Ok(q)) => {
                        assert_eq!(p.to_bits(), q.to_bits(), "cfg {i} latency differs")
                    }
                    (Err(_), Err(_)) => {}
                    _ => panic!("cfg {i}: validity differs between parallel and sequential"),
                }
            }
            assert_eq!(par.calls, cfgs.len());
        }
        assert_eq!(seq.calls, cfgs.len());
    }

    #[test]
    fn pool_evaluator_is_reusable_across_batches() {
        // The persistent pool must give the same answers batch after
        // batch (no state leaks between scopes).
        let w = Workload::llama3_attention(8, 512);
        let space = crate::config::spaces::attention_sim_space();
        let cfgs: Vec<Config> = space.enumerate(&w).collect();
        let mut pooled = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let first = pooled.evaluate_batch(&cfgs, 1.0);
        for _ in 0..2 {
            let again = pooled.evaluate_batch(&cfgs, 1.0);
            for (a, b) in first.iter().zip(&again) {
                match (a, b) {
                    (Ok(p), Ok(q)) => assert_eq!(p.to_bits(), q.to_bits()),
                    (Err(_), Err(_)) => {}
                    _ => panic!("validity flapped across batches"),
                }
            }
        }
        assert_eq!(pooled.calls, 3 * cfgs.len());
    }

    #[test]
    fn multi_device_matches_single_device_bitwise() {
        let w = Workload::llama3_attention(8, 512);
        let space = crate::config::spaces::attention_sim_space();
        let cfgs: Vec<Config> = space.enumerate(&w).collect();
        let mut single = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).sequential();
        let mut fleet =
            MultiDeviceEvaluator::replicate(&SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED), 3);
        let a = single.evaluate_batch(&cfgs, 1.0);
        let b = fleet.evaluate_batch(&cfgs, 1.0);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            match (x, y) {
                (Ok(p), Ok(q)) => assert_eq!(p.to_bits(), q.to_bits(), "cfg {i} differs"),
                (Err(_), Err(_)) => {}
                _ => panic!("cfg {i}: validity differs between fleet and single device"),
            }
        }
    }

    #[test]
    fn multi_device_utilization_counters_add_up() {
        let w = Workload::llama3_attention(8, 512);
        let space = crate::config::spaces::attention_sim_space();
        let cfgs: Vec<Config> = space.enumerate(&w).collect();
        let mut fleet =
            MultiDeviceEvaluator::replicate(&SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED), 3);
        assert_eq!(fleet.devices(), 3);
        let _ = fleet.evaluate_batch(&cfgs, 1.0);
        let total: usize = fleet.utilization().iter().map(|u| u.evaluated).sum();
        assert_eq!(total, cfgs.len(), "every config lands on exactly one device");
        for u in fleet.utilization() {
            assert!(u.evaluated > 0, "batch larger than fleet must reach every device");
            assert_eq!(u.shards, 1);
            assert!(!u.device.is_empty());
        }
        assert!(fleet.wall_us() > 0.0);
    }

    #[test]
    fn multi_device_small_batch_reaches_fewer_devices() {
        let w = Workload::llama3_attention(4, 512);
        let cfg = Config::new(&[
            ("BLOCK_M", 64),
            ("BLOCK_N", 64),
            ("num_warps", 4),
            ("num_stages", 2),
            ("waves_per_eu", 0),
        ]);
        let mut fleet =
            MultiDeviceEvaluator::replicate(&SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED), 4);
        let out = fleet.evaluate_batch(std::slice::from_ref(&cfg), 1.0);
        assert_eq!(out.len(), 1);
        assert!(out[0].is_ok());
        let reached: usize = fleet.utilization().iter().filter(|u| u.evaluated > 0).count();
        assert_eq!(reached, 1, "a 1-config batch occupies exactly one device");
    }

    #[test]
    fn homogeneous_fleet_shares_cache_key_with_single_device() {
        // Fleet results are bit-identical to a single device's, so a
        // replica fleet must hit the same cache entries (same name).
        let w = Workload::llama3_attention(4, 512);
        let base = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let fleet = MultiDeviceEvaluator::replicate(&base, 4);
        assert_eq!(fleet.name(), base.name());
    }

    #[test]
    fn measure_everywhere_matches_each_platform_alone() {
        // out[p][i] must be bit-identical to platform p's sequential
        // evaluator on cfgs[i] — the property tune_fleet's per-platform
        // argmin is built on.
        let w = Workload::llama3_attention(8, 512);
        let space = crate::config::spaces::attention_sim_space();
        let cfgs: Vec<Config> = space.enumerate(&w).collect();
        let a = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let m = SimEvaluator::new(SimGpu::mi250(), w, crate::kernels::baselines::TRITON_AMD);
        // Two a100 replicas: the a100 copy of the batch is sharded.
        let mut fleet = MultiDeviceEvaluator::new(vec![a.clone(), m.clone(), a.clone()]);
        let platforms = fleet.platforms().to_vec();
        assert_eq!(platforms.len(), 2, "two distinct platforms expected");
        let everywhere = fleet.evaluate_batch_everywhere(&cfgs, 1.0);
        assert_eq!(everywhere.len(), platforms.len());
        for (platform, got) in platforms.iter().zip(&everywhere) {
            let mut solo = fleet.platform_evaluator(platform).unwrap();
            let want = solo.evaluate_batch(&cfgs, 1.0);
            assert_eq!(got.len(), want.len());
            for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
                match (g, w_) {
                    (Ok(p), Ok(q)) => {
                        assert_eq!(p.to_bits(), q.to_bits(), "{platform} cfg {i} differs")
                    }
                    (Err(_), Err(_)) => {}
                    _ => panic!("{platform} cfg {i}: validity differs from solo evaluation"),
                }
            }
        }
    }

    #[test]
    fn measure_everywhere_counts_replicated_work_per_platform() {
        let w = Workload::llama3_attention(8, 512);
        let space = crate::config::spaces::attention_sim_space();
        let cfgs: Vec<Config> = space.enumerate(&w).collect();
        let a = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let m = SimEvaluator::new(SimGpu::mi250(), w, crate::kernels::baselines::TRITON_AMD);
        let mut fleet = MultiDeviceEvaluator::new(vec![a.clone(), m, a]);
        let _ = fleet.evaluate_batch_everywhere(&cfgs, 1.0);
        // Every platform measured the whole batch once, split across its
        // replicas.
        for platform in fleet.platforms().to_vec() {
            let on_platform: usize = fleet
                .utilization()
                .iter()
                .filter(|u| u.device == platform)
                .map(|u| u.evaluated)
                .sum();
            assert_eq!(on_platform, cfgs.len(), "{platform} must see the whole batch");
        }
        let replicated: usize = fleet.utilization().iter().map(|u| u.replicated).sum();
        assert_eq!(replicated, 2 * cfgs.len(), "each config measured once per platform");
        // The two a100 replicas split the a100 copy.
        let a100_shards: Vec<usize> = fleet
            .utilization()
            .iter()
            .filter(|u| u.device.starts_with("sim-a100"))
            .map(|u| u.evaluated)
            .collect();
        assert_eq!(a100_shards.len(), 2);
        assert!(a100_shards.iter().all(|&n| n > 0), "both replicas must share the copy");
        assert!(fleet.wall_us() > 0.0);
    }

    #[test]
    fn measure_everywhere_empty_batch_is_empty_per_platform() {
        let w = Workload::llama3_attention(4, 512);
        let a = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let m = SimEvaluator::new(SimGpu::mi250(), w, crate::kernels::baselines::TRITON_AMD);
        let mut fleet = MultiDeviceEvaluator::new(vec![a, m]);
        let out = fleet.evaluate_batch_everywhere(&[], 1.0);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(Vec::is_empty));
    }

    #[test]
    fn heterogeneous_fleet_name_encodes_exact_layout() {
        // Sharded heterogeneous results depend on which platform each
        // contiguous shard lands on, so the cache identity must encode
        // the device sequence verbatim: reordering (or re-replicating)
        // the same platform set changes the results and must change
        // the key.
        let w = Workload::llama3_attention(4, 512);
        let a = || SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let m = || SimEvaluator::new(SimGpu::mi250(), w, HAND_TUNED);
        let h1 = MultiDeviceEvaluator::new(vec![a(), m(), a()]);
        let h2 = MultiDeviceEvaluator::new(vec![m(), a(), a()]);
        assert_ne!(h1.name(), h2.name(), "different layouts must not share a cache key");
        assert!(h1.name().starts_with("multi["), "{}", h1.name());
        assert_ne!(h1.name(), a().name(), "mixed fleets must not alias a single platform");
        // Every component platform appears, so invalidate_platform's
        // component matching covers the entry.
        assert!(h1.name().contains(&a().name()) && h1.name().contains(&m().name()));
    }

    #[test]
    fn eval_cost_does_not_change_results() {
        let w = Workload::llama3_attention(4, 512);
        let cfg = Config::new(&[
            ("BLOCK_M", 64),
            ("BLOCK_N", 64),
            ("num_warps", 4),
            ("num_stages", 2),
            ("waves_per_eu", 0),
        ]);
        let mut plain = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED);
        let mut costly = SimEvaluator::new(SimGpu::a100(), w, HAND_TUNED).with_eval_cost(500);
        let a = plain.evaluate(&cfg).unwrap();
        let b = costly.evaluate(&cfg).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
