//! portatune CLI — leader entrypoint for the Layer-3 coordinator.
//!
//! ```text
//! portatune bench <fig1|fig2|fig3|fig4|fig5|tables|all> [--out-dir D]
//! portatune tune  [--kernel K] [--platform P] [--batch N] [--seq N]
//!                 [--strategy S] [--budget N] [--cache F] [--seed N]
//!                 [--devices N]
//! portatune serve [--requests N] [--seed N] [--no-tuning]
//!                 [--platform a100|mi250|h100|cpu-pjrt[,P2,...]]
//!                 [--shards N] [--placement bucket-affinity|least-loaded]
//!                 [--scenario steady|burst|diurnal]
//!                 [--chaos SEED [--fault-rate P]]
//! portatune space --stats [--kernel K]
//! portatune surrogate --report [--k N] [--check] [--from-log F]
//! portatune analyze <kernels|hlo> [path]
//! portatune cache <show|clear> [--file F]
//! ```

use anyhow::{anyhow, Result};

#[cfg(feature = "pjrt")]
use portatune::autotuner::PjrtEvaluator;
use portatune::autotuner::{
    Budget, EvalRecord, Evaluator, MultiDeviceEvaluator, Observer, SessionOutcome, SimEvaluator,
    Strategy, TuningSession,
};
use portatune::cache::TuningCache;
use portatune::config::Config;
use portatune::codegen::hlo;
use portatune::config::spaces;
use portatune::experiments;
use portatune::kernels::baselines::triton_codegen;
use portatune::platform::PlatformId;
use portatune::report::Report;
#[cfg(feature = "pjrt")]
use portatune::runtime::Engine;
use portatune::runtime::Manifest;
use portatune::serving::{
    router::synth_trace, ChaosBackend, EvalLogBackend, FaultPlan, PlacementPolicy, Router,
    Scenario, ServeReport, ServerConfig, SimBackend, TimedRequest,
};
use portatune::surrogate::{
    load_eval_log, r_squared, rank_correlation, CostModel, EvalLogWriter, LoggingEvaluator,
    RIDGE_LAMBDA, SEED_SAMPLE,
};
use portatune::util::cli::Args;
use portatune::workload::{DType, Workload};

const USAGE: &str = "\
portatune — performance-portable LLM kernels via autotuning

USAGE:
  portatune bench <fig1|fig2|fig3|fig4|fig5|tables|ablation|hopper|all> [--out-dir D]
  portatune tune  [--kernel attention|rms_norm|vector_add]
                  [--platform sim-a100|sim-mi250|sim-h100|cpu-pjrt]
                  [--batch N] [--seq N]
                  [--strategy exhaustive|random|hillclimb|anneal|sha]
                  [--surrogate-k N] (replaces --strategy: measure a seed
                                        sample, fit a learned cost model,
                                        then measure only its top-N
                                        predictions)
                  [--log-evals F] (append every full-fidelity measurement
                                        to F as a JSONL eval record for
                                        offline surrogate refits)
                  [--budget N] [--cache FILE] [--seed N] [--space FILE.json]
                  [--devices N]   (shard evaluation across N simulated devices)
                  [--fleet P1,P2,...]  (measure every config on every listed
                                        platform; per-platform winners +
                                        portability table; sim platforms only)
                  [--max-evals N | --wall-secs S]  (session budget: cap ANY
                                        strategy, exhaustive included)
                  [--progress]    (stream evaluations/new bests as they happen)
  portatune serve [--requests N] [--seed N] [--no-tuning]
                  [--platform a100|mi250|h100|cpu-pjrt[,P2,...]]
                                  (sim platforms serve in default builds;
                                   a comma list replays the same trace on
                                   each platform and prints a comparison;
                                   cpu-pjrt needs --features pjrt)
                  [--shards N]    (N executor shards per platform, each with
                                   its own backend/tuner; sim platforms only)
                  [--placement bucket-affinity|least-loaded]
                                  (how formed batches are routed to shards;
                                   default bucket-affinity)
                  [--scenario steady|burst|diurnal]
                                  (replayable scenario trace — seeded arrival
                                   process x seq-length mixes x tenant
                                   classes — instead of the all-at-once
                                   synthetic trace)
                  [--chaos SEED]  (deterministic fault injection: wrap each
                                   shard's backend in ChaosBackend with a
                                   per-shard decorrelated seed derived from
                                   SEED; sim platforms only)
                  [--fault-rate P] (uniform per-verb fault rate for --chaos;
                                   default 0.1)
                  [--log-evals F] (append every full-fidelity backend
                                   measurement to F as a JSONL eval record;
                                   sim platforms only)
  portatune space --stats [--kernel attention|rms_norm|vector_add|all]
                                  (enumerate the built-in hierarchical
                                   spaces and report the valid/invalid/
                                   pruned-subtree split per workload)
  portatune surrogate --report [--k N] [--kernel K] [--batch N] [--seq N]
                                  (fit quality — R2, rank correlation —
                                   and surrogate-vs-exhaustive winner
                                   agreement per sim platform)
                  [--check]       (exit nonzero unless the surrogate
                                   winner is within 10% of the exhaustive
                                   winner on every platform)
                  [--from-log F]  (refit from a recorded --log-evals file
                                   and report fit quality instead of
                                   running fresh measurements)
  portatune analyze kernels
  portatune analyze hlo <path>
  portatune cache <show|clear> [--file F]
";

/// `--progress`: an [`Observer`] streaming tuning events to stderr (so
/// piped stdout still carries only the report tables).
#[derive(Default)]
struct Progress {
    evals: usize,
}

impl Observer for Progress {
    fn on_eval(&mut self, _record: &EvalRecord) {
        self.evals += 1;
    }

    fn on_new_best(&mut self, config: &Config, latency_us: f64) {
        eprintln!("  [eval {:>5}] new best {config} @ {latency_us:.2} us", self.evals);
    }

    fn on_rung(&mut self, fidelity: f64, pool: usize) {
        eprintln!("  [eval {:>5}] sha rung: {pool} configs @ fidelity {fidelity:.3}", self.evals);
    }

    fn on_platform(&mut self, platform: &str) {
        eprintln!("  [eval {:>5}] tuning platform {platform}", self.evals);
    }
}

/// `--max-evals N` / `--wall-secs S` → the session [`Budget`].
fn parse_budget(args: &Args) -> Result<Option<Budget>> {
    match (args.flag("max-evals"), args.flag("wall-secs")) {
        (Some(_), Some(_)) => {
            Err(anyhow!("--max-evals and --wall-secs are mutually exclusive"))
        }
        (Some(n), None) => Ok(Some(Budget::Evals(
            n.parse().map_err(|e| anyhow!("--max-evals: {e}"))?,
        ))),
        (None, Some(s)) => Ok(Some(Budget::WallSecs(
            s.parse().map_err(|e| anyhow!("--wall-secs: {e}"))?,
        ))),
        (None, None) => Ok(None),
    }
}

fn parse_strategy(name: &str, budget: usize) -> Result<Strategy> {
    Ok(match name {
        "exhaustive" => Strategy::Exhaustive,
        "random" => Strategy::Random { budget },
        "hillclimb" => Strategy::HillClimb { restarts: 4, budget },
        "anneal" => Strategy::Anneal { budget, t0: 2.0, alpha: 0.95 },
        "sha" => Strategy::SuccessiveHalving { initial: budget.max(8), eta: 2 },
        other => return Err(anyhow!("unknown strategy {other}")),
    })
}

fn workload_for(kernel: &str, batch: usize, seq: usize) -> Result<Workload> {
    Ok(match kernel {
        "attention" => Workload::llama3_attention(batch, seq),
        "rms_norm" => Workload::llama3_rms(batch, seq),
        "vector_add" => Workload::VectorAdd { n: batch * seq, dtype: DType::F32 },
        other => return Err(anyhow!("unknown kernel {other}")),
    })
}

fn print_reports(reports: Vec<(String, Report)>, out_dir: Option<&str>) -> Result<()> {
    for (slug, rep) in reports {
        println!("{}", rep.to_markdown());
        if let Some(dir) = out_dir {
            rep.save_tsv(dir, &slug)?;
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    use experiments::*;
    use portatune::platform::SimGpu;
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("bench needs an experiment name\n{USAGE}"))?;
    let reports: Vec<(String, Report)> = match which {
        "all" => run_all(),
        "fig1" => vec![
            ("fig1a".into(), fig1::throughput(&SimGpu::a100())),
            ("fig1b".into(), fig1::throughput(&SimGpu::mi250())),
            ("fig1c".into(), fig1::porting_effort()),
        ],
        "fig2" => vec![
            ("fig2a".into(), fig2::latency_sweep(&SimGpu::a100())),
            ("fig2b".into(), fig2::latency_sweep(&SimGpu::mi250())),
            ("fig2_summary".into(), fig2::summary()),
        ],
        "fig3" => vec![("fig3".into(), fig3::rms_cdf())],
        "fig4" => vec![("fig4".into(), fig4::cross_gpu_reuse())],
        "fig5" => vec![
            ("fig5a".into(), fig5::triton_sweep()),
            ("fig5b".into(), fig5::cuda_templates()),
            ("fig5_real_hlo".into(), fig5::real_hlo_corpus()),
        ],
        "hopper" => vec![("ext_hopper_day0".into(), hopper::day0_report())],
        "ablation" => vec![
            ("ablation_search".into(), ablation::search_strategies()),
            ("ablation_guided".into(), ablation::guided_pruning()),
            ("ablation_cache".into(), ablation::cache_reuse()),
        ],
        "tables" | "table1" | "table2" => vec![
            ("table1".into(), tables::table1()),
            ("table2".into(), tables::table2()),
        ],
        other => return Err(anyhow!("unknown experiment {other}")),
    };
    print_reports(reports, args.flag("out-dir"))
}

/// `tune --fleet P1,P2,...`: one search, every config measured on every
/// listed platform, per-platform winners + the portability table.
fn cmd_tune_fleet(args: &Args, fleet_spec: &str) -> Result<()> {
    if args.flag("platform").is_some() || args.flag("devices").is_some() {
        return Err(anyhow!(
            "--fleet replaces --platform/--devices: list the fleet's platforms \
             (repeats allowed, e.g. --fleet a100,a100,mi250)"
        ));
    }
    if args.flag("surrogate-k").is_some() || args.flag("log-evals").is_some() {
        return Err(anyhow!(
            "--surrogate-k/--log-evals apply to solo tuning only \
             (surrogate fleet tuning is not supported; see TuningSession::surrogate)"
        ));
    }
    let kernel = args.flag_or("kernel", "attention");
    let batch = args.flag_parse("batch", 8usize)?;
    let seq = args.flag_parse("seq", 1024usize)?;
    let budget = args.flag_parse("budget", 200usize)?;
    let seed = args.flag_parse("seed", 0u64)?;
    let strat = parse_strategy(&args.flag_or("strategy", "exhaustive"), budget)?;
    let w = workload_for(&kernel, batch, seq)?;
    let mut devices = Vec::new();
    for name in fleet_spec.split(',').filter(|s| !s.is_empty()) {
        let pid: PlatformId = name.parse().map_err(|e| anyhow!("--fleet: {e}"))?;
        let Some(gpu) = pid.sim() else {
            return Err(anyhow!(
                "--fleet supports sim platforms only (got {name}): the PJRT path \
                 is sequential (PJRT handles are not Send; see ROADMAP)"
            ));
        };
        let vendor = gpu.spec.vendor;
        devices.push(SimEvaluator::new(gpu, w, triton_codegen(vendor)));
    }
    if devices.is_empty() {
        return Err(anyhow!("--fleet needs at least one platform, e.g. --fleet a100,mi250"));
    }
    let space = match args.flag("space") {
        Some(path) => portatune::config::dsl::space_from_file(path)?,
        None => spaces::sim_space_for(&w),
    };
    let mut fleet = MultiDeviceEvaluator::new(devices);
    let mut cache = match args.flag("cache") {
        Some(p) => TuningCache::open(p)?,
        None => TuningCache::ephemeral(),
    };
    let mut progress = Progress::default();
    let mut session = TuningSession::new(&space, &w)
        .strategy(strat.clone())
        .seed(seed)
        .cache(&mut cache);
    if let Some(b) = parse_budget(args)? {
        session = session.budget(b);
    }
    if args.has("progress") {
        session = session.observe(&mut progress);
    }
    let out = session
        .fleet(&mut fleet)
        .run()
        .and_then(SessionOutcome::into_fleet)
        .ok_or_else(|| anyhow!("no valid configuration found on every platform"))?;

    println!("workload      : {}", w.key());
    println!("strategy      : {}", strat.label());
    println!("fleet         : {} devices, {} distinct platforms", fleet.devices(), out.outcomes.len());
    println!("from cache    : {}", out.from_cache);
    println!("wall time     : {:.2} s", out.wall_seconds);

    let mut winners = Report::new(
        "fleet tuning — per-platform winners",
        &["platform", "best config", "best_us", "evaluated", "invalid", "spread", "cached"],
    );
    winners.note(format!(
        "{} distinct winner(s) across {} platform(s){}",
        out.distinct_winners,
        out.outcomes.len(),
        if out.distinct_winners == 1 {
            " — one config wins everywhere"
        } else {
            " — per-platform multi-versioning pays (the paper's claim)"
        }
    ));
    for (platform, o) in &out.outcomes {
        winners.row(vec![
            platform.clone(),
            o.best.to_string(),
            format!("{:.2}", o.best_latency_us),
            o.evaluated.to_string(),
            o.invalid.to_string(),
            o.spread().map(|s| format!("{s:.1}x")).unwrap_or_else(|| "-".into()),
            o.from_cache.to_string(),
        ]);
    }
    println!("{}", winners.to_markdown());

    let mut port = Report::new(
        "portability — portable-best vs platform-best",
        &["platform", "platform best_us", "portable_us", "slowdown"],
    );
    match &out.portable {
        Some(pb) => {
            port.note(format!(
                "portable config {} (worst-case slowdown {:.2}x)",
                pb.config, pb.worst_slowdown
            ));
            for ((platform, o), (lat, slow)) in
                out.outcomes.iter().zip(pb.latency_us.iter().zip(&pb.slowdown))
            {
                port.row(vec![
                    platform.clone(),
                    format!("{:.2}", o.best_latency_us),
                    format!("{lat:.2}"),
                    format!("{slow:.2}x"),
                ]);
            }
        }
        None if out.from_cache => {
            port.note("cached winners carry no evaluation history; re-run without --cache (or clear it) for the portable-best analysis");
        }
        None => {
            port.note("no measured candidate is valid on every platform — nothing portable to report");
        }
    }
    println!("{}", port.to_markdown());

    // Utilization is only meaningful when the devices actually ran
    // (a full cache hit performs zero evaluations).
    if !out.from_cache {
        let wall = fleet.wall_us();
        for (i, u) in fleet.utilization().iter().enumerate() {
            println!(
                "  device {i} [{}]: {} cfgs ({} replicated) in {} shards, busy {:.0} us ({:.0}% util)",
                u.device,
                u.evaluated,
                u.replicated,
                u.shards,
                u.busy_us,
                100.0 * u.utilization(wall)
            );
        }
    }
    cache.save()?;
    if args.flag("cache").is_some() {
        println!("cache         : {} entries @ {}", cache.len(), cache.path().display());
    }
    Ok(())
}

/// One solo tuning run through the builder: cache always attached,
/// budget and progress observer when the flags ask for them,
/// `--surrogate-k` switching to the self-priming surrogate mode and
/// `--log-evals` wrapping the evaluator in a [`LoggingEvaluator`]
/// (results pass through bit-identical; successes are appended to the
/// eval log).
#[allow(clippy::too_many_arguments)]
fn run_session(
    space: &portatune::config::ConfigSpace,
    w: &Workload,
    cache: &mut TuningCache,
    strat: &Strategy,
    seed: u64,
    budget: Option<Budget>,
    surrogate_k: Option<usize>,
    log_evals: Option<&str>,
    progress: Option<&mut Progress>,
    eval: &mut dyn Evaluator,
) -> Result<Option<portatune::autotuner::TuneOutcome>> {
    let mut logged;
    let eval: &mut dyn Evaluator = match log_evals {
        Some(path) => {
            let log = EvalLogWriter::open(std::path::Path::new(path))?;
            logged = LoggingEvaluator::new(eval, *w, log);
            &mut logged
        }
        None => eval,
    };
    let mut session =
        TuningSession::new(space, w).strategy(strat.clone()).seed(seed).cache(cache);
    if let Some(k) = surrogate_k {
        session = session.surrogate(k);
    }
    if let Some(b) = budget {
        session = session.budget(b);
    }
    if let Some(p) = progress {
        session = session.observe(p);
    }
    Ok(session.evaluator(eval).run().and_then(SessionOutcome::into_solo))
}

fn cmd_tune(args: &Args) -> Result<()> {
    if let Some(fleet_spec) = args.flag("fleet") {
        return cmd_tune_fleet(args, fleet_spec);
    }
    let kernel = args.flag_or("kernel", "attention");
    let platform: PlatformId = args.flag_or("platform", "sim-a100").parse().map_err(|e| anyhow!("{e}"))?;
    let batch = args.flag_parse("batch", 8usize)?;
    let seq = args.flag_parse("seq", 1024usize)?;
    let budget = args.flag_parse("budget", 200usize)?;
    let seed = args.flag_parse("seed", 0u64)?;
    let devices = args.flag_parse_at_least("devices", 1, 1)?;
    let strat = parse_strategy(&args.flag_or("strategy", "exhaustive"), budget)?;
    let surrogate_k = args
        .flag("surrogate-k")
        .map(|s| s.parse::<usize>().map_err(|e| anyhow!("--surrogate-k: {e}")))
        .transpose()?;
    if surrogate_k == Some(0) {
        return Err(anyhow!("--surrogate-k must be >= 1"));
    }
    if surrogate_k.is_some() && args.flag("strategy").is_some() {
        return Err(anyhow!(
            "--surrogate-k replaces --strategy: the surrogate mode measures a seed \
             sample, fits the learned cost model, then measures only its top-k"
        ));
    }
    let log_evals = args.flag("log-evals").cloned();
    let w = workload_for(&kernel, batch, seq)?;
    let mut cache = match args.flag("cache") {
        Some(p) => TuningCache::open(p)?,
        None => TuningCache::ephemeral(),
    };
    let budget = parse_budget(args)?;
    let show_progress = args.has("progress");
    let mut progress = Progress::default();

    // Filled by the multi-device path: one line per device.
    let mut device_report: Vec<String> = Vec::new();
    let outcome = match platform {
        #[cfg(feature = "pjrt")]
        PlatformId::CpuPjrt => {
            if devices > 1 {
                return Err(anyhow!(
                    "--devices applies to sim platforms only: the PJRT path is sequential \
                     (PJRT handles are not Send; see ROADMAP)"
                ));
            }
            let space = spaces::aot_space_for(&w);
            let engine = Engine::cpu()?;
            let manifest = Manifest::load_default()?;
            let mut eval = PjrtEvaluator::new(&engine, &manifest, w, 1, 5)?;
            run_session(
                &space,
                &w,
                &mut cache,
                &strat,
                seed,
                budget,
                surrogate_k,
                log_evals.as_deref(),
                show_progress.then_some(&mut progress),
                &mut eval,
            )?
        }
        #[cfg(not(feature = "pjrt"))]
        PlatformId::CpuPjrt => {
            return Err(anyhow!(
                "platform cpu-pjrt requires a build with `--features pjrt`"
            ));
        }
        sim => {
            let gpu = sim.sim().unwrap();
            // Q4.1 in practice: a JSON space description may replace the
            // built-in space (`--space spaces/attention_sim.json`).
            let space = match args.flag("space") {
                Some(path) => portatune::config::dsl::space_from_file(path)?,
                None => spaces::sim_space_for(&w),
            };
            let cg = triton_codegen(gpu.spec.vendor);
            if devices > 1 {
                // Shard every evaluation batch across a fleet of
                // simulated device replicas; results are bit-identical
                // to a single device, only faster.  (Built here rather
                // than through `.devices(n)` so the utilization
                // counters stay reachable after the run.)
                let mut eval =
                    MultiDeviceEvaluator::replicate(&SimEvaluator::new(gpu, w, cg), devices);
                let outcome = run_session(
                    &space,
                    &w,
                    &mut cache,
                    &strat,
                    seed,
                    budget,
                    surrogate_k,
                    log_evals.as_deref(),
                    show_progress.then_some(&mut progress),
                    &mut eval,
                )?;
                // Utilization is only meaningful when the devices
                // actually ran (a cache hit performs zero evaluations).
                if outcome.as_ref().map(|o| !o.from_cache).unwrap_or(false) {
                    let wall = eval.wall_us();
                    device_report = eval
                        .utilization()
                        .iter()
                        .enumerate()
                        .map(|(i, u)| {
                            format!(
                                "  device {i} [{}]: {} cfgs in {} shards, busy {:.0} us ({:.0}% util)",
                                u.device,
                                u.evaluated,
                                u.shards,
                                u.busy_us,
                                100.0 * u.utilization(wall)
                            )
                        })
                        .collect();
                }
                outcome
            } else {
                let mut eval = SimEvaluator::new(gpu, w, cg);
                run_session(
                    &space,
                    &w,
                    &mut cache,
                    &strat,
                    seed,
                    budget,
                    surrogate_k,
                    log_evals.as_deref(),
                    show_progress.then_some(&mut progress),
                    &mut eval,
                )?
            }
        }
    }
    .ok_or_else(|| anyhow!("no valid configuration found"))?;

    println!("workload      : {}", w.key());
    println!("platform      : {}", platform.name());
    match surrogate_k {
        Some(k) => println!("strategy      : surrogate top-{k} ({SEED_SAMPLE}-config seed sample)"),
        None => println!("strategy      : {}", strat.label()),
    }
    println!("best config   : {}", outcome.best);
    println!("best latency  : {:.2} us", outcome.best_latency_us);
    println!("evaluated     : {} ({} invalid)", outcome.evaluated, outcome.invalid);
    if let Some(s) = outcome.spread() {
        println!("config spread : {s:.1}x (paper: ~20x for complex kernels)");
    }
    println!("from cache    : {}", outcome.from_cache);
    println!("wall time     : {:.2} s", outcome.wall_seconds);
    if !device_report.is_empty() {
        println!("devices       : {devices} (sharded simulated fleet)");
        for line in &device_report {
            println!("{line}");
        }
    }
    cache.save()?;
    if args.flag("cache").is_some() {
        println!("cache         : {} entries @ {}", cache.len(), cache.path().display());
    }
    Ok(())
}

/// Build the router for one serve platform: sim platforms go straight
/// to the always-available [`SimBackend`] (sharded when `--shards` asks
/// for it); `cpu-pjrt` needs the real PJRT executor behind the feature
/// flag and stays single-executor (PJRT handles are not `Send`).
fn serve_router(
    pid: PlatformId,
    seed: u64,
    cfg: &ServerConfig,
    chaos: Option<FaultPlan>,
    shards: usize,
    placement: PlacementPolicy,
    log_evals: Option<String>,
) -> Result<Router> {
    match (pid.sim(), chaos, log_evals) {
        (Some(gpu), Some(plan), Some(path)) => Router::with_shards(
            move |i| {
                let shard_plan =
                    FaultPlan { seed: plan.seed.wrapping_add(i as u64), ..plan.clone() };
                // The log decorator wraps outermost so it records the
                // chaos-affected latencies the executor actually sees.
                let log = EvalLogWriter::open(std::path::Path::new(&path))?;
                Ok(EvalLogBackend::new(
                    ChaosBackend::new(SimBackend::new(gpu.clone(), seed), shard_plan),
                    log,
                ))
            },
            shards,
            placement,
            cfg,
        ),
        (Some(gpu), Some(plan), None) => Router::with_shards(
            move |i| {
                // Decorrelated per-shard fault schedules: same rates,
                // different seeds, so shards fail independently but the
                // whole run stays deterministic.
                let shard_plan =
                    FaultPlan { seed: plan.seed.wrapping_add(i as u64), ..plan.clone() };
                Ok(ChaosBackend::new(SimBackend::new(gpu.clone(), seed), shard_plan))
            },
            shards,
            placement,
            cfg,
        ),
        (Some(gpu), None, Some(path)) => Router::with_shards(
            move |_| {
                let log = EvalLogWriter::open(std::path::Path::new(&path))?;
                Ok(EvalLogBackend::new(SimBackend::new(gpu.clone(), seed), log))
            },
            shards,
            placement,
            cfg,
        ),
        (Some(gpu), None, None) => Router::with_shards(
            move |_| Ok(SimBackend::new(gpu.clone(), seed)),
            shards,
            placement,
            cfg,
        ),
        (None, _, Some(_)) => Err(anyhow!(
            "--log-evals is supported on the sim platforms (a100|mi250|h100) only"
        )),
        (None, Some(_), None) => Err(anyhow!(
            "--chaos is supported on the sim platforms (a100|mi250|h100) only"
        )),
        (None, None, None) if shards > 1 => Err(anyhow!(
            "--shards applies to sim platforms only: the PJRT path is \
             single-executor (PJRT handles are not Send; see ROADMAP)"
        )),
        (None, None, None) => pjrt_serve_router(cfg),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_serve_router(cfg: &ServerConfig) -> Result<Router> {
    let manifest = Manifest::load_default()?;
    println!("starting PJRT router over {} model shapes ...", manifest.model_artifacts().len());
    Router::pjrt(manifest, cfg)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_serve_router(_cfg: &ServerConfig) -> Result<Router> {
    Err(anyhow!(
        "platform cpu-pjrt requires a build with `--features pjrt`; \
         the sim platforms (a100|mi250|h100) serve in default builds"
    ))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests = args.flag_parse("requests", 64usize)?;
    let seed = args.flag_parse("seed", 42u64)?;
    let no_tuning = args.has("no-tuning");
    let chaos_seed = args
        .flag("chaos")
        .map(|s| s.parse::<u64>().map_err(|e| anyhow!("--chaos {s:?}: {e}")))
        .transpose()?;
    let fault_rate = args.flag_parse("fault-rate", 0.1f64)?;
    if args.flag("fault-rate").is_some() && chaos_seed.is_none() {
        return Err(anyhow!("--fault-rate needs --chaos SEED to enable fault injection"));
    }
    if !(0.0..=1.0).contains(&fault_rate) {
        return Err(anyhow!("--fault-rate must be a probability in [0, 1] (got {fault_rate})"));
    }
    let chaos = chaos_seed.map(|s| FaultPlan::uniform(s, fault_rate));
    let log_evals = args.flag("log-evals").cloned();
    let shards = args.flag_parse_at_least("shards", 1, 1)?;
    let placement: PlacementPolicy = args
        .flag_or("placement", "bucket-affinity")
        .parse()
        .map_err(|e| anyhow!("--placement: {e}"))?;
    let scenario = args
        .flag("scenario")
        .map(|name| {
            Scenario::by_name(name)
                .ok_or_else(|| anyhow!("unknown scenario {name:?} (catalog: {})", Scenario::names()))
        })
        .transpose()?;
    let cfg = ServerConfig { idle_tuning: !no_tuning, ..Default::default() };
    let platforms: Vec<PlatformId> = args
        .flag_or("platform", "a100")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|name| name.parse().map_err(|e| anyhow!("--platform: {e}")))
        .collect::<Result<_>>()?;
    if platforms.is_empty() {
        return Err(anyhow!("--platform needs at least one platform, e.g. --platform a100,mi250"));
    }

    // One row per platform for the cross-platform summary: the same
    // seeded trace replayed cold (and tuned) on each.
    let mut rows: Vec<(String, ServeReport, Option<ServeReport>)> = Vec::new();
    for pid in platforms {
        println!("\n=== serving on {} ===", pid.name());
        if let Some(plan) = &chaos {
            println!(
                "(chaos: seed {} fault-rate {:.3} — deterministic fault injection active)",
                plan.seed, fault_rate
            );
        }
        let router =
            serve_router(pid, seed, &cfg, chaos.clone(), shards, placement, log_evals.clone())?;
        if shards > 1 {
            println!("({} executor shards, placement {})", shards, placement.name());
        }
        let max_tokens = router.policy().seq_buckets.last().copied().unwrap_or(128);
        let trace: Vec<TimedRequest> = match &scenario {
            Some(sc) => {
                println!("(scenario {}: {})", sc.name, sc.description);
                sc.generate(requests, max_tokens, seed)
            }
            None => synth_trace(requests, max_tokens, seed)
                .into_iter()
                .map(TimedRequest::immediate)
                .collect(),
        };

        println!("== phase 1: cold serve ({} requests) ==", trace.len());
        let before = router.serve_trace_timed(&trace)?;
        print_serve("cold", &before);

        let mut after = None;
        if !no_tuning {
            println!("\n== background tuning (idle-time, Q4.4) ==");
            router.finish_tuning()?;
            let stats = router.executor().stats()?;
            println!("variants measured: {}", stats.variants_measured);
            for s in &stats.swaps {
                println!("  swap b{}s{}: {} -> {} ({:.2}x)", s.shape.0, s.shape.1, s.from, s.to, s.gain);
            }

            println!("\n== phase 2: tuned serve ==");
            let tuned = router.serve_trace_timed(&trace)?;
            print_serve("tuned", &tuned);
            println!("\nexec p50 improvement: {:.2}x", before.exec_p50_us / tuned.exec_p50_us);
            after = Some(tuned);
        }
        if shards > 1 || scenario.is_some() {
            // One grep-able row per shard — CI's sharded smoke step
            // asserts the `| shard |` table renders with N rows.
            let last = after.as_ref().unwrap_or(&before);
            let mut rep = Report::new(
                &format!("per-shard utilization — {}", pid.name()),
                &["shard", "batches", "requests", "busy (ms)", "util %"],
            );
            rep.note(format!(
                "placement {} over {} shard(s); modeled makespan {:.2} ms, \
                 sim throughput {:.1} req/s",
                placement.name(),
                last.shards,
                last.sim_makespan_us / 1e3,
                last.sim_throughput_rps,
            ));
            for u in &last.shard_util {
                rep.row(vec![
                    u.shard.to_string(),
                    u.batches.to_string(),
                    u.requests.to_string(),
                    format!("{:.2}", u.busy_us / 1e3),
                    format!("{:.0}", 100.0 * u.utilization(last.sim_makespan_us)),
                ]);
            }
            println!("\n{}", rep.to_markdown());
        }
        if chaos.is_some() {
            // One grep-able row per counter — CI's chaos smoke step
            // asserts `| injected | N |` has N > 0.
            let last = after.as_ref().unwrap_or(&before);
            let mut rep = Report::new(
                &format!("chaos fault-tolerance counters — {}", pid.name()),
                &["counter", "value"],
            );
            for (label, value) in last.faults.rows() {
                rep.row(vec![label.to_string(), value.to_string()]);
            }
            println!("\n{}", rep.to_markdown());
        }
        rows.push((pid.name().to_string(), before, after));
    }

    if rows.len() > 1 {
        let mut rep = Report::new(
            "multi-platform serve — same trace, cold vs tuned",
            &["platform", "cold req/s", "tuned req/s", "cold exec p50 (us)", "tuned exec p50 (us)", "exec p50 gain"],
        );
        for (platform, before, after) in &rows {
            let opt = |f: &dyn Fn(&ServeReport) -> String| {
                after.as_ref().map(|a| f(a)).unwrap_or_else(|| "-".into())
            };
            rep.row(vec![
                platform.clone(),
                format!("{:.1}", before.throughput_rps),
                opt(&|a| format!("{:.1}", a.throughput_rps)),
                format!("{:.1}", before.exec_p50_us),
                opt(&|a| format!("{:.1}", a.exec_p50_us)),
                opt(&|a| format!("{:.2}x", before.exec_p50_us / a.exec_p50_us)),
            ]);
        }
        println!("\n{}", rep.to_markdown());
    }
    Ok(())
}

fn print_serve(tag: &str, r: &ServeReport) {
    println!(
        "[{tag}] served {} req ({} rejected) in {:.2}s  | {:.1} req/s  {:.0} tok/s",
        r.requests, r.rejected, r.wall_seconds, r.throughput_rps, r.tokens_per_second
    );
    println!(
        "[{tag}] latency p50/p95/p99: {:.1}/{:.1}/{:.1} ms   exec p50: {:.1} ms  occupancy {:.2}",
        r.latency_p50_us / 1e3,
        r.latency_p95_us / 1e3,
        r.latency_p99_us / 1e3,
        r.exec_p50_us / 1e3,
        r.mean_batch_occupancy
    );
    if r.faults.any() {
        println!(
            "[{tag}] faults: {} injected, {} failures, {} retries ({} recovered), \
             {} fallbacks, {} shed",
            r.faults.injected,
            r.faults.failures,
            r.faults.retries,
            r.faults.recovered,
            r.faults.fallbacks,
            r.shed
        );
    }
    if r.lost > 0 {
        println!("[{tag}] LOST {} in-flight request(s) to dead shards", r.lost);
    }
}

/// `space --stats`: enumerate the built-in hierarchical spaces and
/// report the (valid, invalid, pruned-subtree) split per workload —
/// the observable payoff of level-bound constraints (ISSUE 8).
fn cmd_space(args: &Args) -> Result<()> {
    if !args.has("stats") {
        return Err(anyhow!("space supports: portatune space --stats [--kernel K]\n{USAGE}"));
    }
    let kernel = args.flag_or("kernel", "all");
    if !["all", "attention", "rms_norm", "vector_add"].contains(&kernel.as_str()) {
        return Err(anyhow!("unknown kernel {kernel} (attention|rms_norm|vector_add|all)"));
    }
    let mut rep = Report::new(
        "config-space statistics — hierarchical subtree pruning",
        &["space", "workload", "raw", "valid", "invalid", "pruned", "pruned %"],
    );
    rep.note(
        "`pruned` counts raw cross-product configurations eliminated a whole subtree at a \
         time by level-bound constraints, before any per-config evaluation; `invalid` are \
         full-depth rejections",
    );
    let mut add = |space: &portatune::config::ConfigSpace, w: &Workload| {
        let s = space.count_valid(w);
        rep.row(vec![
            space.name.clone(),
            w.key(),
            s.total().to_string(),
            s.valid.to_string(),
            s.invalid.to_string(),
            s.pruned.to_string(),
            format!("{:.1}", 100.0 * s.pruned_fraction()),
        ]);
    };
    if kernel == "all" || kernel == "attention" {
        for seq in [32, 64, 128, 256, 512, 1024] {
            add(&spaces::attention_sim_space(), &Workload::llama3_attention(8, seq));
        }
        for seq in [64, 256, 1024] {
            add(&spaces::attention_aot_space(), &Workload::llama3_attention(1, seq));
        }
    }
    if kernel == "all" || kernel == "rms_norm" {
        for (batch, seq) in [(1usize, 64usize), (8, 512)] {
            add(&spaces::rms_sim_space(), &Workload::llama3_rms(batch, seq));
            add(&spaces::rms_aot_space(), &Workload::llama3_rms(batch, seq));
        }
    }
    if kernel == "all" || kernel == "vector_add" {
        for n in [100usize, 1 << 20] {
            add(&spaces::vecadd_aot_space(), &Workload::VectorAdd { n, dtype: DType::F32 });
        }
    }
    println!("{}", rep.to_markdown());
    Ok(())
}

/// `surrogate --report`: fit quality (R², Spearman rank correlation)
/// and surrogate-vs-exhaustive winner agreement per sim platform — the
/// observable payoff of the learned cost model (ISSUE 9).  With
/// `--check` the command exits nonzero unless the surrogate winner is
/// within 10% of the exhaustive winner everywhere (CI's smoke gate);
/// with `--from-log F` it refits from a recorded `--log-evals` file
/// instead of running fresh measurements.
fn cmd_surrogate(args: &Args) -> Result<()> {
    if let Some(path) = args.flag("from-log") {
        return surrogate_from_log(path);
    }
    if !args.has("report") {
        return Err(anyhow!(
            "surrogate supports: portatune surrogate --report [--k N] [--kernel K] \
             [--batch N] [--seq N] [--check] [--from-log F]\n{USAGE}"
        ));
    }
    let kernel = args.flag_or("kernel", "attention");
    let batch = args.flag_parse("batch", 8usize)?;
    let seq = args.flag_parse("seq", 1024usize)?;
    let k = args.flag_parse("k", 32usize)?;
    if k == 0 {
        return Err(anyhow!("--k must be >= 1"));
    }
    let w = workload_for(&kernel, batch, seq)?;
    let space = spaces::sim_space_for(&w);
    let mut rep = Report::new(
        &format!("surrogate vs exhaustive — {} (top-k = {k})", w.key()),
        &[
            "platform",
            "fit n",
            "R2",
            "rank corr",
            "exhaustive_us",
            "surrogate_us",
            "ratio",
            "within 10%",
            "measured",
            "|space|",
        ],
    );
    rep.note(format!(
        "fit quality scores a model trained on the {SEED_SAMPLE}-config seed sample \
         against full-fidelity latencies of the whole valid space (R2, Spearman rank \
         correlation); `measured` counts hardware measurements the surrogate mode spent \
         (seed sample + top-k) vs the exhaustive `|space|`"
    ));
    let mut worst_ratio = 1.0f64;
    for name in ["a100", "mi250"] {
        let pid: PlatformId = name.parse().map_err(|e| anyhow!("{e}"))?;
        let gpu = pid.sim().expect("sim platform");
        // Ground truth: every valid config at full fidelity.
        let mut truth_eval = SimEvaluator::new(gpu.clone(), w, triton_codegen(gpu.spec.vendor));
        let platform = truth_eval.name();
        let truth: Vec<(Config, f64)> = space
            .enumerate(&w)
            .filter_map(|c| truth_eval.evaluate(&c).ok().map(|us| (c, us)))
            .collect();
        let exhaustive_us = truth.iter().map(|(_, us)| *us).fold(f64::INFINITY, f64::min);
        if !exhaustive_us.is_finite() {
            return Err(anyhow!("no valid config in {} on {platform}", space.name));
        }
        // The model the surrogate mode fits: the seed sample only.
        let train: Vec<(Config, Workload, f64)> = space
            .equally_spaced(&w, SEED_SAMPLE)
            .into_iter()
            .filter_map(|c| truth_eval.evaluate(&c).ok().map(|us| (c, w, us)))
            .collect();
        let model = CostModel::fit(&platform, &train, RIDGE_LAMBDA)
            .ok_or_else(|| anyhow!("seed sample too small to fit a surrogate on {platform}"))?;
        let (pred, act): (Vec<f64>, Vec<f64>) =
            truth.iter().map(|(c, us)| (model.predict_us(c, &w), *us)).unzip();
        let r2 = r_squared(&pred, &act);
        let rank = rank_correlation(&pred, &act);
        // The actual surrogate-guided session: seed sample + top-k measured.
        let mut eval = SimEvaluator::new(gpu.clone(), w, triton_codegen(gpu.spec.vendor));
        let out = TuningSession::new(&space, &w)
            .surrogate(k)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .ok_or_else(|| anyhow!("surrogate session found no valid config on {platform}"))?;
        let ratio = out.best_latency_us / exhaustive_us;
        worst_ratio = worst_ratio.max(ratio);
        rep.row(vec![
            platform,
            model.fit.n.to_string(),
            format!("{r2:.3}"),
            format!("{rank:.3}"),
            format!("{exhaustive_us:.2}"),
            format!("{:.2}", out.best_latency_us),
            format!("{ratio:.3}"),
            if ratio <= 1.10 { "yes" } else { "NO" }.to_string(),
            out.evaluated.to_string(),
            truth.len().to_string(),
        ]);
    }
    println!("{}", rep.to_markdown());
    if args.has("check") && worst_ratio > 1.10 {
        return Err(anyhow!(
            "surrogate winner agreement check failed: worst ratio {worst_ratio:.3} > 1.10"
        ));
    }
    Ok(())
}

/// `surrogate --from-log F`: reload a `--log-evals` JSONL file, refit
/// one model per platform found in it, and report fit quality against
/// the recorded latencies.
fn surrogate_from_log(path: &str) -> Result<()> {
    let load = load_eval_log(std::path::Path::new(path))?;
    println!(
        "{path}: {} record(s) loaded ({} duplicate fingerprint(s) dropped, \
         {} rejected for model-version mismatch)",
        load.records.len(),
        load.deduped,
        load.version_rejected
    );
    let mut platforms: Vec<String> = load.records.iter().map(|r| r.platform.clone()).collect();
    platforms.sort();
    platforms.dedup();
    let mut rep = Report::new(
        "surrogate refit from eval log",
        &["platform", "kernel", "fit n", "R2", "rank corr"],
    );
    for p in &platforms {
        match CostModel::fit_logged(p, &load.records, RIDGE_LAMBDA) {
            Some(m) => rep.row(vec![
                p.clone(),
                m.kernel.clone(),
                m.fit.n.to_string(),
                format!("{:.3}", m.fit.r2),
                format!("{:.3}", m.fit.rank_corr),
            ]),
            None => rep.row(vec![
                p.clone(),
                "-".into(),
                "too few records".into(),
                "-".into(),
                "-".into(),
            ]),
        }
    }
    println!("{}", rep.to_markdown());
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("analyze needs a target\n{USAGE}"))?;
    match what {
        "kernels" => {
            // L1 §Perf report: VMEM footprint + MXU utilization estimate
            // per AOT attention config (DESIGN.md §8).
            let manifest = Manifest::load_default()?;
            let mut rep = Report::new(
                "L1 Pallas attention configs — VMEM/MXU structure estimates",
                &["bucket", "config", "vmem_bytes", "vmem_%_of_16MiB", "mxu_tile_util"],
            );
            for w in manifest.workload_buckets("attention") {
                let Workload::Attention { .. } = w else { continue };
                for a in manifest.candidates_for(&w) {
                    let c = a.config();
                    let (bq, bk) = (c.req("block_q") as usize, c.req("block_k") as usize);
                    // Config::mem_bytes IS the python vmem_bytes formula
                    // (pinned by the golden test in config::spaces), so
                    // the old hand-rolled mirror here is gone.
                    let vmem = c.mem_bytes(&w);
                    // MXU 128x128 systolic: how full are the matmul tiles?
                    let util = (bq.min(128) * bk.min(128)) as f64 / (128.0 * 128.0);
                    rep.row(vec![
                        w.key(),
                        c.key(),
                        vmem.to_string(),
                        format!("{:.1}%", vmem as f64 / (16.0 * 1024.0 * 1024.0) * 100.0),
                        format!("{util:.2}"),
                    ]);
                }
            }
            println!("{}", rep.to_markdown());
        }
        "hlo" => {
            let p = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("analyze hlo <path>"))?;
            let stats = hlo::analyze_file(p)?;
            println!("{p}: {stats:?}");
        }
        other => return Err(anyhow!("unknown analysis {other}")),
    }
    Ok(())
}

fn cmd_cache(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("cache needs an action\n{USAGE}"))?;
    let file = args.flag_or("file", "tuning_cache.json");
    match action {
        "show" => {
            let cache = TuningCache::open(&file)?;
            println!("{} entries in {file}", cache.len());
            for (k, e) in cache.entries() {
                println!("  {k}\n    -> {} @ {:.2}us ({} evaluated)", e.config, e.latency_us, e.evaluated);
            }
        }
        "clear" => {
            let p = std::path::Path::new(&file);
            if p.exists() {
                std::fs::remove_file(p)?;
                println!("removed {file}");
            }
        }
        other => return Err(anyhow!("unknown cache action {other}")),
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "bench" => {
            let args = Args::parse(rest, &[])?;
            args.ensure_known(&["out-dir"])?;
            cmd_bench(&args)
        }
        "tune" => {
            let args = Args::parse(rest, &["progress"])?;
            args.ensure_known(&[
                "kernel", "platform", "batch", "seq", "strategy", "budget", "cache", "seed",
                "space", "devices", "fleet", "max-evals", "wall-secs", "progress", "surrogate-k",
                "log-evals",
            ])?;
            cmd_tune(&args)
        }
        "serve" => {
            let args = Args::parse(rest, &["no-tuning"])?;
            args.ensure_known(&[
                "requests", "seed", "no-tuning", "platform", "chaos", "fault-rate", "shards",
                "placement", "scenario", "log-evals",
            ])?;
            cmd_serve(&args)
        }
        "space" => {
            let args = Args::parse(rest, &["stats"])?;
            args.ensure_known(&["stats", "kernel"])?;
            cmd_space(&args)
        }
        "surrogate" => {
            let args = Args::parse(rest, &["report", "check"])?;
            args.ensure_known(&["report", "check", "k", "kernel", "batch", "seq", "from-log"])?;
            cmd_surrogate(&args)
        }
        "analyze" => cmd_analyze(&Args::parse(rest, &[])?),
        "cache" => cmd_cache(&Args::parse(rest, &[])?),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command {other}\n{USAGE}")),
    }
}
