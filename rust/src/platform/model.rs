//! Analytical GPU latency models (occupancy + roofline + pipeline).
//!
//! One [`SimGpu`] wraps a [`GpuSpec`] and predicts kernel latency for a
//! (configuration, workload, codegen-quality) triple.  The model is
//! deliberately *mechanistic* — every term corresponds to a physical
//! effect, so the cross-platform phenomena the paper reports emerge from
//! the architecture sheets rather than from curve fitting:
//!
//! - configurations can be **invalid** per platform (shared-memory /
//!   register / thread-count ceilings) — Fig 4's missing bars;
//! - optimal block shapes differ per platform (MMA-vs-MFMA alignment,
//!   warp width, smem capacity) — Fig 4's cross-GPU slowdowns;
//! - small workloads under-fill the device, so big-tile templates lose
//!   to autotuned small tiles — Fig 2's best-case 2.3x;
//! - `num_stages` only pays off on hardware with async copies — code
//!   diversity in Fig 5.
//!
//! Nothing here claims absolute-microsecond fidelity to real silicon; the
//! goal (per DESIGN.md §2) is to preserve *who wins, by roughly what
//! factor, and where the crossovers fall*.

use super::spec::{GpuSpec, Vendor, A100, H100, MI250};
use crate::config::Config;
use crate::workload::Workload;

/// Bumped whenever model constants change: part of the cache fingerprint,
/// so stale tuning results are never reused across model revisions.
pub const MODEL_VERSION: u32 = 3;

/// Codegen quality of the software stack that produced the kernel —
/// how close generated code gets to the hardware ceilings.
///
/// These are the only per-implementation knobs; everything else is
/// architecture. Values are set in [`crate::kernels::baselines`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Codegen {
    /// Fraction of peak matrix throughput reachable (instruction
    /// selection, scheduling quality).
    pub compute_eff: f64,
    /// Fraction of peak DRAM bandwidth reachable (coalescing quality).
    pub mem_eff: f64,
    /// Does the backend emit packed 16-bit loads/math (half2 / v_pk)?
    /// The paper found Triton missing this on the RMS kernel (§Q1).
    pub f16_packed: bool,
}

/// Hand-tuned vendor library quality: the reference point.
pub const HAND_TUNED: Codegen = Codegen { compute_eff: 1.0, mem_eff: 1.0, f16_packed: true };

/// Why a configuration cannot run on this platform.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InvalidConfig {
    /// Human-readable explanation (which ceiling was exceeded).
    pub reason: String,
}

impl std::fmt::Display for InvalidConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid config: {}", self.reason)
    }
}

impl std::error::Error for InvalidConfig {}

fn invalid(reason: impl Into<String>) -> InvalidConfig {
    InvalidConfig { reason: reason.into() }
}

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// An analytically modeled GPU.
#[derive(Debug, Clone)]
pub struct SimGpu {
    /// The architecture sheet driving every model term.
    pub spec: GpuSpec,
}

impl SimGpu {
    /// A model around an arbitrary (possibly mutated) architecture sheet —
    /// used by capacity edge-case tests and what-if experiments.
    pub fn new(spec: GpuSpec) -> Self {
        SimGpu { spec }
    }

    /// The modeled NVIDIA A100-80GB ([`A100`]).
    pub fn a100() -> Self {
        SimGpu { spec: A100 }
    }

    /// The modeled AMD MI250 GCD ([`MI250`]).
    pub fn mi250() -> Self {
        SimGpu { spec: MI250 }
    }

    /// The modeled NVIDIA H100 ([`H100`], the day-0 Hopper experiment).
    pub fn h100() -> Self {
        SimGpu { spec: H100 }
    }

    /// Dispatch on the workload's kernel.
    pub fn latency_us(&self, cfg: &Config, w: &Workload, cg: &Codegen) -> Result<f64, InvalidConfig> {
        match w {
            Workload::Attention { .. } => self.attention_latency_us(cfg, w, cg),
            Workload::RmsNorm { .. } => self.rms_latency_us(cfg, w, cg),
            Workload::VectorAdd { .. } => self.vecadd_latency_us(cfg, w, cg),
        }
    }

    /// Central memory-capacity check: the configuration's modeled
    /// on-chip staging footprint ([`Config::mem_bytes`]) must fit this
    /// platform's per-block shared-memory / LDS budget.  Every kernel
    /// validator routes through here instead of hand-rolling its own
    /// footprint formula, so the memory dimension is rejected in one
    /// place with one reason string.
    pub fn validate_memory(&self, cfg: &Config, w: &Workload) -> Result<(), InvalidConfig> {
        let mem = cfg.mem_bytes(w);
        if mem > self.spec.smem_per_block {
            return Err(invalid(format!(
                "shared memory {mem} B exceeds {} B per block",
                self.spec.smem_per_block
            )));
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Flash attention
    // -----------------------------------------------------------------

    /// Architectural registers per thread for the accumulator + scores
    /// (f32), the dominant register consumer in flash attention.
    fn attn_regs_per_thread(&self, block_m: usize, block_n: usize, head_dim: usize, threads: usize) -> usize {
        let acc_f32_words = block_m * head_dim + block_m * block_n;
        ceil_div(acc_f32_words, threads) + 32 // +32 fixed overhead (addresses, softmax state)
    }

    /// Validity of a flash-attention config on this platform.
    pub fn validate_attention(&self, cfg: &Config, w: &Workload) -> Result<(), InvalidConfig> {
        let Workload::Attention { head_dim, .. } = *w else {
            return Err(invalid("workload is not attention"));
        };
        let s = &self.spec;
        let (bm, bn) = (cfg.req("BLOCK_M") as usize, cfg.req("BLOCK_N") as usize);
        let warps = cfg.req("num_warps") as usize;
        let threads = warps * s.warp_width;
        if threads > s.max_threads_per_block {
            return Err(invalid(format!(
                "{} threads exceed max {} ({} warps x {} lanes)",
                threads, s.max_threads_per_block, warps, s.warp_width
            )));
        }
        self.validate_memory(cfg, w)?;
        let regs = self.attn_regs_per_thread(bm, bn, head_dim, threads);
        if regs > s.max_regs_per_thread {
            return Err(invalid(format!(
                "{regs} registers/thread exceed {}",
                s.max_regs_per_thread
            )));
        }
        Ok(())
    }

    /// Predicted latency (µs) of one causal/full flash-attention launch.
    pub fn attention_latency_us(&self, cfg: &Config, w: &Workload, cg: &Codegen) -> Result<f64, InvalidConfig> {
        self.validate_attention(cfg, w)?;
        let Workload::Attention { batch, q_heads, kv_heads, seq_len, head_dim, dtype, causal } = *w else {
            unreachable!()
        };
        let s = &self.spec;
        let dtb = dtype.bytes();
        let (bm, bn) = (cfg.req("BLOCK_M") as usize, cfg.req("BLOCK_N") as usize);
        let stages = cfg.req("num_stages") as usize;
        let warps = cfg.req("num_warps") as usize;
        let waves_per_eu = cfg.get("waves_per_eu").unwrap_or(0);
        let threads = warps * s.warp_width;

        // ---- grid & occupancy -----------------------------------------
        let q_tiles = ceil_div(seq_len, bm);
        let total_blocks = batch * q_heads * q_tiles;
        let smem = cfg.mem_bytes(w);
        let regs = self.attn_regs_per_thread(bm, bn, head_dim, threads);
        let blocks_by_smem = (s.smem_per_cu / smem.max(1)).max(1);
        let blocks_by_warps = (s.max_warps_per_cu / warps).max(1);
        let blocks_by_regs = (s.regfile_per_cu / (regs * 4 * threads).max(1)).max(1);
        let mut blocks_per_cu = blocks_by_smem.min(blocks_by_warps).min(blocks_by_regs);
        if s.vendor == Vendor::Amd && waves_per_eu >= 2 {
            // CDNA scheduler hint: allow denser wave packing when the
            // kernel declares low register pressure.
            blocks_per_cu = (blocks_per_cu * 3).div_ceil(2);
        }
        let concurrent = s.cus * blocks_per_cu;
        let waves = ceil_div(total_blocks, concurrent).max(1);
        // Blocks sharing a CU share its matrix unit, so device throughput
        // is set by how evenly blocks cover the CUs, not by occupancy:
        // each CU serially runs ceil(total/cus) blocks, and the tail
        // round is partially empty (wave quantization).
        let rounds = ceil_div(total_blocks, s.cus);
        let wave_util = total_blocks as f64 / (rounds * s.cus) as f64;

        // ---- matrix-unit efficiency ------------------------------------
        // MMA/MFMA tile alignment: a 16-wide block on a 32-wide MFMA unit
        // pads half the lanes.
        let align = |b: usize| -> f64 {
            let native = s.mma_tile;
            let padded = ceil_div(b, native) * native;
            b as f64 / padded as f64
        };
        // Per-thread accumulator work: too little starves the pipelines;
        // more is better (deeper ILP) until register pressure bites,
        // which reg_eff below charges separately.
        let wpt = (bm * bn) as f64 / threads as f64;
        let ilp_eff = (wpt / 48.0).powf(0.5).min(1.0);
        // Register pressure: mild occupancy loss above half the budget,
        // then a spill cliff — past ~192 registers the compiler starts
        // spilling the f32 accumulator to local memory, which is
        // catastrophic. This is the cliff that makes wavefront-64-tuned
        // MI250 configs (half the threads when re-launched with 32-wide
        // warps) collapse on the A100 — Fig. 4's order-of-magnitude drops.
        let r = regs as f64;
        let reg_eff = if r <= 128.0 {
            1.0
        } else if r <= 192.0 {
            1.0 - 0.15 * (r - 128.0) / 64.0
        } else {
            0.85 - 0.80 * ((r - 192.0) / 63.0).min(1.0)
        };
        // Warps partition the M dimension of the tile; a warp owning
        // fewer rows than the native matrix-instruction tile pads the
        // rest away (the biggest single source of the ~20x config
        // spread the paper observes, and vendor-asymmetric: MFMA's
        // 32-row granule is twice MMA's).
        let rows_per_warp = (bm as f64 / warps as f64).max(1.0);
        let warp_split_eff = (rows_per_warp / s.mma_tile as f64).min(1.0);
        // Software pipelining: on Ampere cp.async overlaps K/V staging;
        // CDNA2 has no async copy, so extra stages barely help.
        let stage_eff = if s.has_async_copy {
            (0.80 + 0.10 * stages as f64).min(1.0)
        } else {
            (0.88 + 0.03 * stages as f64).min(1.0)
        };
        // Low resident-warp count exposes pipeline latency: residency is
        // bounded both by the occupancy limits AND by how many blocks
        // actually exist to co-schedule (small grids cannot fill a CU —
        // the effect that sinks big-tile templates on small workloads).
        let resident_blocks = blocks_per_cu.min(rounds).max(1);
        let resident = (resident_blocks * warps).min(s.max_warps_per_cu) as f64;
        // ~24 resident warps fully cover smem/MXU pipe latency; below
        // that the penalty is soft — even a single warp streaming MMAs
        // through a pipelined k-loop keeps the matrix unit half-busy.
        let lat_hide = 0.5 + 0.5 * (resident / 24.0).powf(0.4).min(1.0);
        let mxu_eff = align(bm)
            * align(bn)
            * ilp_eff
            * reg_eff
            * warp_split_eff
            * stage_eff
            * lat_hide
            * cg.compute_eff;

        let flops = w.flops();
        let compute_us =
            flops / (s.matrix_tflops(dtb) * 1e12 * mxu_eff.max(1e-3) * wave_util.max(1e-3)) * 1e6;

        // Load/compute overlap: multi-stage cp.async pipelines overlap
        // fully; single-stage (or non-async hardware) kernels only
        // overlap via warp/block switching, so part of the slower phase
        // serializes behind the faster one.
        let pipelined = s.has_async_copy && stages >= 2;
        let overlap = if pipelined {
            1.0
        } else {
            1.0 - 1.0 / (1.0 + 0.5 * resident)
        };

        // ---- memory ------------------------------------------------------
        let rep = q_heads / kv_heads.max(1);
        let kv_logical = (2 * batch * kv_heads * seq_len * head_dim * dtb) as f64;
        let q_out = (2 * batch * q_heads * seq_len * head_dim * dtb) as f64;
        // Each of the q_tiles*rep blocks per (batch, kv-head) streams the
        // full K/V; L2 absorbs re-reads while the per-head panels of all
        // concurrently *distinct* KV streams fit.
        let kv_rereads = (q_tiles * rep) as f64 * if causal { 0.5 } else { 1.0 };
        let distinct_kv = (batch * kv_heads).min(concurrent);
        let concurrent_ws = (distinct_kv * 2 * seq_len * head_dim * dtb) as f64;
        let l2_hit = (s.l2_bytes as f64 / concurrent_ws).clamp(0.0, 0.92);
        let hbm_traffic = q_out + kv_logical * (1.0 + (kv_rereads - 1.0).max(0.0) * (1.0 - l2_hit));
        let mem_us = hbm_traffic / (s.hbm_gbps * 1e9 * cg.mem_eff * wave_util.max(0.05)) * 1e6;

        // Causal work imbalance: the diagonal q-tile touches the whole
        // prefix (max/avg work = 2*q_tiles/(q_tiles+1) -> 2), and with few
        // serial rounds per CU the scheduler cannot rebalance it.
        let _ = waves;
        let imbalance = if causal {
            let skew = 2.0 * q_tiles as f64 / (q_tiles as f64 + 1.0) - 1.0;
            1.0 + skew / rounds as f64
        } else {
            1.0
        };

        let core_us = compute_us.max(mem_us) + compute_us.min(mem_us) * (1.0 - overlap);
        Ok(s.launch_overhead_us + core_us * imbalance)
    }

    /// The PyTorch-native (materialized softmax) attention baseline:
    /// four separate kernels and an S x S intermediate round-tripped
    /// through HBM — the paper's 6-13x-slower reference.
    pub fn native_attention_latency_us(&self, w: &Workload) -> Result<f64, InvalidConfig> {
        let Workload::Attention { batch, q_heads, seq_len, head_dim, dtype, .. } = *w else {
            return Err(invalid("workload is not attention"));
        };
        let s = &self.spec;
        let dtb = dtype.bytes();
        // Scores are materialized in f32 by eager softmax paths.
        let scores = (batch * q_heads * seq_len * seq_len) as f64;
        // write scores, read+write softmax (f32), read probs for P@V.
        let traffic = scores * (4.0 + 8.0 + 4.0)
            + (4 * batch * q_heads * seq_len * head_dim * dtb) as f64;
        // Eager ops on AMD go through hipified kernels with poorer
        // coalescing; the paper's MI250 native baseline is ~13x slower.
        let native_mem_eff = match s.vendor {
            Vendor::Nvidia => 0.85,
            Vendor::Amd => 0.55,
        };
        let mem_us = traffic / (s.hbm_gbps * 1e9 * native_mem_eff) * 1e6;
        // Two dense GEMMs via the vendor BLAS (near-peak matrix unit).
        let gemm_us = w.flops() / (s.matrix_tflops(dtb) * 1e12 * 0.85) * 1e6;
        // Four kernel launches (QK^T, mask, softmax, PV).
        Ok(4.0 * s.launch_overhead_us + mem_us + gemm_us)
    }

    // -----------------------------------------------------------------
    // RMS norm
    // -----------------------------------------------------------------

    /// Validity of an RMS-norm config on this platform.
    pub fn validate_rms(&self, cfg: &Config, w: &Workload) -> Result<(), InvalidConfig> {
        let Workload::RmsNorm { dtype, .. } = *w else {
            return Err(invalid("workload is not rms_norm"));
        };
        let s = &self.spec;
        let warps = cfg.req("num_warps") as usize;
        let threads = warps * s.warp_width;
        if threads > s.max_threads_per_block {
            return Err(invalid(format!("{threads} threads exceed max {}", s.max_threads_per_block)));
        }
        let vec_bytes = cfg.req("VEC") as usize * dtype.bytes();
        if vec_bytes > 16 {
            return Err(invalid(format!("{vec_bytes}-byte vector loads exceed 16B/lane")));
        }
        // The Triton row reduction stages one BLOCK through LDS/smem;
        // [`Config::mem_bytes`] models that staging buffer.
        self.validate_memory(cfg, w)
    }

    /// Predicted latency (µs) of one RMS-norm launch (one block per
    /// `rows_per_block` rows, hidden dim streamed in BLOCK chunks).
    pub fn rms_latency_us(&self, cfg: &Config, w: &Workload, cg: &Codegen) -> Result<f64, InvalidConfig> {
        self.validate_rms(cfg, w)?;
        let Workload::RmsNorm { n_rows, hidden, dtype } = *w else { unreachable!() };
        let s = &self.spec;
        let dtb = dtype.bytes();
        let block = cfg.req("BLOCK") as usize;
        let warps = cfg.req("num_warps") as usize;
        let vec = cfg.req("VEC") as usize;
        let threads = warps * s.warp_width;

        // ---- bandwidth term ---------------------------------------------
        let bytes = (2 * n_rows * hidden + hidden) as f64 * dtb as f64;
        // Transaction width: full DRAM rate once each lane moves >= 4 B
        // (a 32-lane warp then fills a 128 B transaction).
        let coalesce = ((vec * dtb) as f64 / 4.0).clamp(0.25, 1.0);
        // Device fill: one block per `rows_per_block` rows; few rows
        // leave CUs idle. Tail quantization as in the attention model.
        let rounds = ceil_div(n_rows.max(1), s.cus);
        let wave_util = n_rows as f64 / (rounds * s.cus) as f64;
        let bw = s.hbm_gbps * 1e9 * coalesce * cg.mem_eff * wave_util.max(0.02);
        let bw_us = bytes / bw * 1e6;

        // ---- instruction/latency term -------------------------------------
        // Each block streams its row(s) in ceil(hidden / (threads*VEC))
        // dependent vector iterations, twice (sum-of-squares pass, then
        // scale pass). Per-iteration cost is dominated by exposed memory
        // latency; packed 16-bit loads/math (half2) cut the instruction
        // count per iteration — the Triton FP16 gap the paper found, which
        // only shows on small (latency-bound) workloads because resident
        // blocks overlap and bandwidth dominates at scale.
        // Unpacked 16-bit code cannot issue wide vector loads (no half2
        // packing), so its iteration count is computed at <=2-wide; this
        // is a codegen property, not a tunable — exactly the paper's
        // finding that the A100 small-workload gap was "not due to the
        // choice of the kernel parameters".
        let vec_eff = if dtb == 2 && !cg.f16_packed { vec.min(2) } else { vec };
        // Beyond ~256 threads per row, reduction/barrier overheads eat
        // the gains; the latency path saturates there.
        let threads_eff = threads.min(256);
        let iters = ceil_div(hidden, threads_eff * vec_eff).max(1);
        // A BLOCK much wider than the row wastes lanes.
        let useful = ((hidden.min(block)) as f64 / block as f64).max(0.1);
        let unpack_penalty = if dtb == 2 && !cg.f16_packed { 1.6 } else { 1.0 };
        let iter_cycles = 220.0 * unpack_penalty / useful / cg.compute_eff;
        let block_us = 2.0 * iters as f64 * iter_cycles / 1.41e9 * 1e6;
        // Resident blocks per CU overlap their latency chains.
        let blocks_per_cu_cap = (s.max_warps_per_cu / warps).max(1);
        let resident = blocks_per_cu_cap.min(rounds).max(1);
        let ipc_us = rounds as f64 * block_us / resident as f64;

        // Row reduction across warps costs log2(warps) barrier rounds.
        // It lives on the same latency path as the streaming loop (and is
        // equally overlapped across resident blocks), so it never shows
        // once the kernel is bandwidth-bound.
        let reduce_us =
            (warps as f64).log2().max(0.0) * 0.25 * rounds as f64 / resident as f64;

        Ok(s.launch_overhead_us + bw_us.max(ipc_us + reduce_us))
    }

    // -----------------------------------------------------------------
    // Vector add
    // -----------------------------------------------------------------

    /// Predicted latency (µs) of one vector-add launch (pure bandwidth
    /// roofline + device-fill term).
    pub fn vecadd_latency_us(&self, cfg: &Config, w: &Workload, cg: &Codegen) -> Result<f64, InvalidConfig> {
        let Workload::VectorAdd { n, dtype } = *w else {
            return Err(invalid("workload is not vector_add"));
        };
        let s = &self.spec;
        let block = cfg.req("block_size") as usize;
        let blocks = ceil_div(n, block);
        let fill = (blocks as f64 / s.cus as f64).min(1.0);
        let bytes = 3.0 * (n * dtype.bytes()) as f64;
        let bw_us = bytes / (s.hbm_gbps * 1e9 * cg.mem_eff * fill.max(0.02)) * 1e6;
        Ok(s.launch_overhead_us + bw_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spaces;
    use crate::workload::DType;

    fn attn_cfg(bm: i64, bn: i64, warps: i64, stages: i64) -> Config {
        Config::new(&[
            ("BLOCK_M", bm),
            ("BLOCK_N", bn),
            ("num_warps", warps),
            ("num_stages", stages),
            ("waves_per_eu", 0),
        ])
    }

    fn paper_attn() -> Workload {
        Workload::llama3_attention(64, 1024)
    }

    #[test]
    fn big_staging_invalid_on_mi250_but_valid_on_a100() {
        // The exact effect behind Fig 4's missing bars: 164K vs 64K smem.
        let cfg = attn_cfg(128, 128, 4, 3); // smem(f16) = (128*128+3*2*128*128)*2 = 229KB -> invalid both
        let small = attn_cfg(128, 64, 4, 2); // (128*128 + 2*2*64*128)*2 = 98KB
        let w = paper_attn();
        assert!(SimGpu::a100().validate_attention(&small, &w).is_ok());
        assert!(SimGpu::mi250().validate_attention(&small, &w).is_err());
        assert!(SimGpu::mi250().validate_attention(&cfg, &w).is_err());
    }

    #[test]
    fn warp_count_ceiling_differs() {
        // 16 warps x 64 lanes = 1024 on AMD (ok), but a space with
        // num_warps up to 8 stays valid on both; 32 warps would not.
        let w = paper_attn();
        let cfg = attn_cfg(64, 64, 8, 1);
        assert!(SimGpu::a100().validate_attention(&cfg, &w).is_ok());
        assert!(SimGpu::mi250().validate_attention(&cfg, &w).is_ok());
    }

    #[test]
    fn latency_positive_and_finite() {
        let w = paper_attn();
        let gpu = SimGpu::a100();
        for cfg in spaces::attention_sim_space().enumerate(&w) {
            if let Ok(us) = gpu.attention_latency_us(&cfg, &w, &HAND_TUNED) {
                assert!(us.is_finite() && us > 0.0, "bad latency for {cfg}");
            }
        }
    }

    #[test]
    fn more_flops_more_time() {
        let gpu = SimGpu::a100();
        let cfg = attn_cfg(128, 64, 4, 2);
        let t1 = gpu
            .attention_latency_us(&cfg, &Workload::llama3_attention(16, 1024), &HAND_TUNED)
            .unwrap();
        let t2 = gpu
            .attention_latency_us(&cfg, &Workload::llama3_attention(64, 1024), &HAND_TUNED)
            .unwrap();
        assert!(t2 > t1 * 2.0, "batch 64 should be >2x batch 16: {t1} vs {t2}");
    }

    #[test]
    fn native_attention_is_paper_slower() {
        // Paper Fig 1: native is 6-13x slower than SOTA flash attention.
        let w = paper_attn();
        for gpu in [SimGpu::a100(), SimGpu::mi250()] {
            let native = gpu.native_attention_latency_us(&w).unwrap();
            let best = spaces::attention_sim_space()
                .enumerate(&w)
                .filter_map(|c| gpu.attention_latency_us(&c, &w, &HAND_TUNED).ok())
                .fold(f64::INFINITY, f64::min);
            let ratio = native / best;
            assert!(
                (4.0..20.0).contains(&ratio),
                "{}: native/flash = {ratio:.1} (native {native:.0}us best {best:.0}us)",
                gpu.spec.name
            );
        }
    }

    #[test]
    fn optimal_configs_differ_across_platforms() {
        let w = paper_attn();
        let space = spaces::attention_sim_space();
        let best = |gpu: &SimGpu| {
            space
                .enumerate(&w)
                .filter_map(|c| gpu.attention_latency_us(&c, &w, &HAND_TUNED).ok().map(|t| (c, t)))
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap()
        };
        let (ca, _) = best(&SimGpu::a100());
        let (cm, _) = best(&SimGpu::mi250());
        assert_ne!(ca, cm, "paper premise: per-platform optima differ");
    }

    #[test]
    fn config_spread_is_large() {
        // Paper §Q3: nearly 20x spread between best and worst valid config.
        let w = paper_attn();
        let gpu = SimGpu::a100();
        let times: Vec<f64> = spaces::attention_sim_space()
            .enumerate(&w)
            .filter_map(|c| gpu.attention_latency_us(&c, &w, &HAND_TUNED).ok())
            .collect();
        let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let worst = times.iter().cloned().fold(0.0, f64::max);
        assert!(worst / best > 5.0, "spread {:.1}", worst / best);
    }

    #[test]
    fn rms_fp16_unpacked_hurts_small_workloads_most() {
        // Paper §Q1: Triton reaches only 60-90% on *small* RMS workloads
        // because of missing FP16 packing; large ones are bandwidth-bound.
        let gpu = SimGpu::a100();
        let cfg = Config::new(&[("BLOCK", 1024), ("num_warps", 4), ("VEC", 4)]);
        let packed = Codegen { f16_packed: true, ..HAND_TUNED };
        let unpacked = Codegen { f16_packed: false, ..HAND_TUNED };
        let small = Workload::RmsNorm { n_rows: 64, hidden: 4096, dtype: DType::F16 };
        let large = Workload::RmsNorm { n_rows: 65536, hidden: 4096, dtype: DType::F16 };
        let ratio_small = gpu.rms_latency_us(&cfg, &small, &unpacked).unwrap()
            / gpu.rms_latency_us(&cfg, &small, &packed).unwrap();
        let ratio_large = gpu.rms_latency_us(&cfg, &large, &unpacked).unwrap()
            / gpu.rms_latency_us(&cfg, &large, &packed).unwrap();
        assert!(ratio_small >= ratio_large, "small {ratio_small:.2} vs large {ratio_large:.2}");
        assert!(ratio_small > 1.05, "penalty should be visible: {ratio_small:.2}");
    }

    #[test]
    fn rms_is_bandwidth_bound_at_scale() {
        let gpu = SimGpu::a100();
        let cfg = Config::new(&[("BLOCK", 4096), ("num_warps", 8), ("VEC", 4)]);
        let w = Workload::RmsNorm { n_rows: 65536, hidden: 4096, dtype: DType::F16 };
        let us = gpu.rms_latency_us(&cfg, &w, &HAND_TUNED).unwrap();
        let ideal_us = w.min_bytes() / (gpu.spec.hbm_gbps * 1e9) * 1e6;
        assert!(us < ideal_us * 3.0, "rms should track the bandwidth roofline");
    }

    #[test]
    fn vecadd_scales_linearly() {
        let gpu = SimGpu::mi250();
        let cfg = Config::new(&[("block_size", 256)]);
        let t1 = gpu
            .vecadd_latency_us(&cfg, &Workload::VectorAdd { n: 1 << 24, dtype: DType::F32 }, &HAND_TUNED)
            .unwrap();
        let t2 = gpu
            .vecadd_latency_us(&cfg, &Workload::VectorAdd { n: 1 << 25, dtype: DType::F32 }, &HAND_TUNED)
            .unwrap();
        assert!(t2 / t1 > 1.7 && t2 / t1 < 2.3);
    }

    #[test]
    fn invalid_reasons_are_descriptive() {
        let w = paper_attn();
        let err = SimGpu::mi250()
            .validate_attention(&attn_cfg(256, 256, 4, 5), &w)
            .unwrap_err();
        assert!(err.reason.contains("shared memory"), "{}", err.reason);
    }

    #[test]
    fn memory_invalid_configs_rejected_on_all_three_platforms() {
        // (256*128 + 5*2*256*128)*2 = 704 KiB staging: over every
        // platform's per-block budget, rejected centrally with the same
        // descriptive reason everywhere.
        let w = paper_attn();
        let cfg = attn_cfg(256, 256, 4, 5);
        for gpu in [SimGpu::a100(), SimGpu::mi250(), SimGpu::h100()] {
            let err = gpu.validate_attention(&cfg, &w).unwrap_err();
            assert!(
                err.reason.contains("shared memory"),
                "{}: {}",
                gpu.spec.name,
                err.reason
            );
        }
    }

    #[test]
    fn memory_check_uses_the_config_footprint_model() {
        // validate_memory and Config::mem_bytes must agree exactly —
        // the occupancy term in the latency model reads the same value.
        let w = paper_attn();
        let cfg = attn_cfg(64, 32, 4, 2);
        let mem = cfg.mem_bytes(&w);
        assert_eq!(mem, (64 * 128 + 2 * 2 * 32 * 128) * 2);
        for gpu in [SimGpu::a100(), SimGpu::mi250(), SimGpu::h100()] {
            assert_eq!(gpu.validate_memory(&cfg, &w).is_ok(), mem <= gpu.spec.smem_per_block);
        }
    }

    #[test]
    fn capacity_edge_cases_zero_exact_and_off_by_one() {
        let w = paper_attn();
        let cfg = attn_cfg(64, 32, 4, 2);
        let mem = cfg.mem_bytes(&w); // 49152 B
        let with_budget = |b: usize| {
            let mut spec = A100;
            spec.smem_per_block = b;
            SimGpu::new(spec)
        };
        // Zero capacity: everything with a footprint is invalid.
        let err = with_budget(0).validate_memory(&cfg, &w).unwrap_err();
        assert!(err.reason.contains("shared memory"), "{}", err.reason);
        // Exact fit: a footprint equal to the budget still runs.
        assert!(with_budget(mem).validate_memory(&cfg, &w).is_ok());
        // Off by one: one byte short rejects.
        assert!(with_budget(mem - 1).validate_memory(&cfg, &w).is_err());
        // Footprint-free configs survive even a zero budget.
        let free = Config::new(&[("block_size", 256)]);
        let vw = Workload::VectorAdd { n: 1 << 20, dtype: DType::F32 };
        assert!(with_budget(0).validate_memory(&free, &vw).is_ok());
    }
}
