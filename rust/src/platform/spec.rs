//! Architecture parameter sheets for the modeled GPUs.
//!
//! Values come from the public vendor datasheets:
//! - NVIDIA A100-80GB SXM: GA100, 108 SMs, 164 KiB configurable shared
//!   memory per SM, 2039 GB/s HBM2e, 312 TFLOP/s FP16 tensor core,
//!   19.5 TFLOP/s FP32, 40 MiB L2, warp = 32, mma.m16n8k16.
//! - AMD Instinct MI250 (one GCD of two): CDNA2, 104 CUs, 64 KiB LDS per
//!   workgroup, 1638 GB/s HBM2e, 181 TFLOP/s FP16 MFMA, 22.6 TFLOP/s
//!   FP32, 8 MiB L2, wavefront = 64, mfma_f32_32x32x8f16.
//!
//! The paper chose these two parts deliberately (comparable 6/7 nm nodes,
//! two major vendors); we model the same pair.

/// GPU vendor, which selects instruction-set-level modeling details.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Vendor {
    /// NVIDIA (warp = 32, mma.sync, cp.async on Ampere+).
    Nvidia,
    /// AMD (wavefront = 64, MFMA, no async copy on CDNA2).
    Amd,
}

impl Vendor {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Vendor::Nvidia => "NVIDIA",
            Vendor::Amd => "AMD",
        }
    }
}

/// Static architecture description used by the analytical models.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    /// Marketing name of the part.
    pub name: &'static str,
    /// Short lowercase model slug (`a100`, `mi250`, `h100`) — the
    /// platform *identity*: evaluator names, cache keys, and fleet
    /// platform rows are derived from this, so two distinct GPU models
    /// must never share a slug (an H100 is not an A100, even though
    /// both are NVIDIA).
    pub model: &'static str,
    /// The part's vendor.
    pub vendor: Vendor,
    /// Streaming multiprocessors (NVIDIA) / compute units (AMD).
    pub cus: usize,
    /// Threads per warp (NVIDIA) / wavefront (AMD).
    pub warp_width: usize,
    /// Hardware thread-block size ceiling.
    pub max_threads_per_block: usize,
    /// Resident warp contexts per CU.
    pub max_warps_per_cu: usize,
    /// Shared memory / LDS available to one block (bytes).
    pub smem_per_block: usize,
    /// Total shared memory per CU (bytes) — bounds block residency.
    pub smem_per_cu: usize,
    /// Register file per CU (bytes).
    pub regfile_per_cu: usize,
    /// Max architectural registers per thread (32-bit regs).
    pub max_regs_per_thread: usize,
    /// Dense FP16/BF16 matrix-unit throughput (TFLOP/s).
    pub fp16_matrix_tflops: f64,
    /// FP32 vector throughput (TFLOP/s).
    pub fp32_tflops: f64,
    /// HBM bandwidth (GB/s).
    pub hbm_gbps: f64,
    /// Device memory capacity (bytes) — the budget the serving plane
    /// tunes its bucket grid and resident KV cache against
    /// (SNIPPETS.md §3's vLLM memory tradeoff as a first-class
    /// dimension).
    pub hbm_bytes: usize,
    /// L2 cache (bytes).
    pub l2_bytes: usize,
    /// Kernel launch overhead (µs) — amortized by CUDA/HIP graphs in the
    /// paper's measurement setup, so kept small.
    pub launch_overhead_us: f64,
    /// Native matrix-instruction tile edge (M=N): 16 for mma.sync,
    /// 32 for MFMA. Blocks not aligned to this pad and waste lanes.
    pub mma_tile: usize,
    /// Does the memory pipeline support async staged copies
    /// (Ampere cp.async)?  Governs how much `num_stages` helps.
    pub has_async_copy: bool,
}

impl GpuSpec {
    /// Peak matmul throughput for a dtype (TFLOP/s).
    pub fn matrix_tflops(&self, dtype_bytes: usize) -> f64 {
        if dtype_bytes <= 2 {
            self.fp16_matrix_tflops
        } else {
            // TF32 tensor core on A100 (156), FP32 MFMA path on CDNA2.
            self.fp16_matrix_tflops / 2.0
        }
    }
}

/// NVIDIA A100-80GB SXM.
pub const A100: GpuSpec = GpuSpec {
    name: "A100-80GB",
    model: "a100",
    vendor: Vendor::Nvidia,
    cus: 108,
    warp_width: 32,
    max_threads_per_block: 1024,
    max_warps_per_cu: 64,
    smem_per_block: 164 * 1024 - 1024, // 163 KiB usable by one block
    smem_per_cu: 164 * 1024,
    regfile_per_cu: 256 * 1024,
    max_regs_per_thread: 255,
    fp16_matrix_tflops: 312.0,
    fp32_tflops: 19.5,
    hbm_gbps: 2039.0,
    hbm_bytes: 80 * 1024 * 1024 * 1024,
    l2_bytes: 40 * 1024 * 1024,
    launch_overhead_us: 3.0,
    mma_tile: 16,
    has_async_copy: true,
};

/// AMD Instinct MI250, one GCD (the paper's ROCm stack schedules kernels
/// per-GCD; peak numbers here are per-GCD halves of the card totals).
pub const MI250: GpuSpec = GpuSpec {
    name: "MI250-128GB",
    model: "mi250",
    vendor: Vendor::Amd,
    cus: 104,
    warp_width: 64,
    max_threads_per_block: 1024,
    max_warps_per_cu: 32,
    smem_per_block: 64 * 1024,
    smem_per_cu: 64 * 1024,
    regfile_per_cu: 512 * 1024,
    max_regs_per_thread: 256,
    fp16_matrix_tflops: 181.0,
    fp32_tflops: 22.6,
    hbm_gbps: 1638.0,
    hbm_bytes: 64 * 1024 * 1024 * 1024, // one GCD's half of the 128 GB card
    l2_bytes: 8 * 1024 * 1024,
    launch_overhead_us: 4.0,
    mma_tile: 32,
    has_async_copy: false,
};

/// NVIDIA H100 SXM (Hopper) — the "new hardware" case of the paper's
/// introduction: flash_attn needed over a year of manual work to exploit
/// Hopper, while an autotuned kernel adapts on day 0 (see
/// `experiments::hopper`).  Sheet: 132 SMs, 228 KiB smem, 989 TFLOP/s
/// dense FP16, 3.35 TB/s HBM3, 50 MiB L2, TMA async copies.
pub const H100: GpuSpec = GpuSpec {
    name: "H100-80GB",
    model: "h100",
    vendor: Vendor::Nvidia,
    cus: 132,
    warp_width: 32,
    max_threads_per_block: 1024,
    max_warps_per_cu: 64,
    smem_per_block: 228 * 1024 - 1024,
    smem_per_cu: 228 * 1024,
    regfile_per_cu: 256 * 1024,
    max_regs_per_thread: 255,
    fp16_matrix_tflops: 989.0,
    fp32_tflops: 67.0,
    hbm_gbps: 3352.0,
    hbm_bytes: 80 * 1024 * 1024 * 1024,
    l2_bytes: 50 * 1024 * 1024,
    launch_overhead_us: 2.5,
    mma_tile: 16,
    has_async_copy: true,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_sheet_sanity() {
        assert_eq!(A100.cus, 108);
        assert_eq!(A100.warp_width, 32);
        assert!(A100.smem_per_block > MI250.smem_per_block * 2);
        assert!(A100.has_async_copy && !MI250.has_async_copy);
    }

    #[test]
    fn mi250_wavefront_is_double() {
        assert_eq!(MI250.warp_width, 2 * A100.warp_width);
        assert_eq!(MI250.mma_tile, 2 * A100.mma_tile);
    }

    #[test]
    fn matrix_tflops_by_dtype() {
        assert_eq!(A100.matrix_tflops(2), 312.0);
        assert!(A100.matrix_tflops(4) < A100.matrix_tflops(2));
    }

    #[test]
    fn h100_is_a_generational_leap() {
        assert!(H100.fp16_matrix_tflops > 3.0 * A100.fp16_matrix_tflops);
        assert!(H100.smem_per_block > A100.smem_per_block);
    }

    #[test]
    fn model_slugs_are_unique_and_lowercase() {
        // The slug is the platform identity (evaluator names, cache
        // keys, fleet platform rows): two specs must never share one.
        let slugs = [A100.model, MI250.model, H100.model];
        for (i, a) in slugs.iter().enumerate() {
            assert_eq!(*a, a.to_ascii_lowercase());
            assert!(!a.is_empty());
            for b in &slugs[i + 1..] {
                assert_ne!(a, b, "two GPU models share the slug {a:?}");
            }
        }
    }

    #[test]
    fn device_capacities_match_the_datasheets() {
        assert_eq!(A100.hbm_bytes, 80 * 1024 * 1024 * 1024);
        assert_eq!(H100.hbm_bytes, 80 * 1024 * 1024 * 1024);
        // Per-GCD: half of the 128 GB card.
        assert_eq!(MI250.hbm_bytes, 64 * 1024 * 1024 * 1024);
    }

    #[test]
    fn comparable_class_parts() {
        // The paper picked these parts as same-class; the models should
        // agree within ~2x on headline numbers.
        let ratio = A100.fp16_matrix_tflops / MI250.fp16_matrix_tflops;
        assert!(ratio > 1.0 && ratio < 2.5);
        let bw = A100.hbm_gbps / MI250.hbm_gbps;
        assert!(bw > 0.8 && bw < 1.6);
    }
}
