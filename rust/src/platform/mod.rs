//! GPU platform models.
//!
//! The paper's testbed is an NVIDIA A100-80GB and an AMD MI250-128GB.
//! Neither is available here, so — per the substitution rule in
//! DESIGN.md §2 — we model both devices analytically: occupancy +
//! roofline + pipeline-efficiency models parameterized by the *real*
//! architecture sheets ([`spec::A100`], [`spec::MI250`]).
//!
//! The cross-platform effects the paper measures are all driven by
//! architecture-parameter differences that these models capture:
//!
//! - **shared memory / LDS capacity** (164 KiB vs 64 KiB) — makes many
//!   A100-optimal flash-attention configs *invalid* on the MI250 (Fig 4's
//!   missing bars);
//! - **warp vs wavefront width** (32 vs 64) and **MMA vs MFMA native tile**
//!   (16 vs 32) — shifts which block shapes utilize the matrix units;
//! - **HBM bandwidth and L2 capacity** — moves the compute/memory
//!   crossover per workload;
//! - **async-copy pipelining** (cp.async on Ampere, absent on CDNA2) —
//!   changes the value of `num_stages`.
//!
//! [`CpuPjrt`](crate::runtime) is the *real* measured platform: HLO
//! artifacts executed through the XLA PJRT CPU client.

pub mod model;
pub mod spec;

pub use model::{InvalidConfig, SimGpu};
pub use spec::{GpuSpec, Vendor, A100, MI250};

/// Identifier of a tuning platform (simulated or real).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlatformId {
    /// Analytical model of the NVIDIA A100-80GB (SXM).
    SimA100,
    /// Analytical model of one GCD of the AMD Instinct MI250-128GB.
    SimMi250,
    /// Analytical model of the NVIDIA H100-80GB (the day-0 Hopper
    /// experiment; also lets fleets mix GPU generations, not just
    /// vendors).
    SimH100,
    /// Real execution through the XLA PJRT CPU client.
    CpuPjrt,
}

impl PlatformId {
    /// Stable CLI/display name (`sim-a100`, `sim-mi250`, `cpu-pjrt`).
    pub fn name(self) -> &'static str {
        match self {
            PlatformId::SimA100 => "sim-a100",
            PlatformId::SimMi250 => "sim-mi250",
            PlatformId::SimH100 => "sim-h100",
            PlatformId::CpuPjrt => "cpu-pjrt",
        }
    }

    /// Environment fingerprint component for the tuning cache: results
    /// from one platform must never be served for another.
    pub fn fingerprint(self) -> String {
        match self {
            PlatformId::SimA100 => format!("sim-a100/model-v{}", model::MODEL_VERSION),
            PlatformId::SimMi250 => format!("sim-mi250/model-v{}", model::MODEL_VERSION),
            PlatformId::SimH100 => format!("sim-h100/model-v{}", model::MODEL_VERSION),
            PlatformId::CpuPjrt => format!("cpu-pjrt/{}", std::env::consts::ARCH),
        }
    }

    /// The analytical model behind a sim platform (`None` for real
    /// execution platforms).
    pub fn sim(self) -> Option<SimGpu> {
        match self {
            PlatformId::SimA100 => Some(SimGpu::a100()),
            PlatformId::SimMi250 => Some(SimGpu::mi250()),
            PlatformId::SimH100 => Some(SimGpu::h100()),
            PlatformId::CpuPjrt => None,
        }
    }
}

impl std::fmt::Display for PlatformId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for PlatformId {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sim-a100" | "a100" => Ok(PlatformId::SimA100),
            "sim-mi250" | "mi250" => Ok(PlatformId::SimMi250),
            "sim-h100" | "h100" => Ok(PlatformId::SimH100),
            "cpu-pjrt" | "cpu" => Ok(PlatformId::CpuPjrt),
            other => Err(format!("unknown platform {other:?}")),
        }
    }
}
