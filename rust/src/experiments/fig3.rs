//! Fig. 3 — RMS-norm relative performance as cumulative distributions.
//!
//! The paper re-runs the Fig. 2 benchmark grid for the RMS layernorm and
//! summarizes, per platform, the CDF of (SOTA latency / autotuned-Triton
//! latency).  Readings:
//!
//! - **MI250**: the autotuned Triton kernel beats the hipify-cross-
//!   compiled CUDA kernel by >20 % on average (ratio > 1.2);
//! - **A100**: Triton reaches 91-98 % in most scenarios but only
//!   60-90 % on small workloads — a Triton FP16-packing gap, not a
//!   config-selection problem (§Q1).

use super::{sim_platforms, tune_triton_rms, BATCH_SWEEP, SEQLEN_SWEEP};
use crate::kernels::baselines::TemplateLibrary;
use crate::metrics::Cdf;
use crate::platform::SimGpu;
use crate::report::Report;
use crate::workload::Workload;

/// Relative performance samples (sota_us / tuned_us) per platform.
pub fn relative_perf(gpu: &SimGpu) -> Vec<(Workload, f64)> {
    let cuda = TemplateLibrary::vllm_cuda_rms();
    let mut out = Vec::new();
    for &seq in &SEQLEN_SWEEP {
        for &batch in &BATCH_SWEEP {
            let w = Workload::llama3_rms(batch, seq);
            let Ok((cuda_us, _)) = cuda.latency_us(gpu, &w) else { continue };
            let Some((tuned_us, _)) = tune_triton_rms(gpu, &w) else { continue };
            out.push((w, cuda_us / tuned_us));
        }
    }
    out
}

/// Fig. 3 report: CDF quantiles of relative performance per platform.
pub fn rms_cdf() -> Report {
    let mut rep = Report::new(
        "Fig.3 RMS norm: autotuned Triton vs SOTA CUDA (CDF of relative performance)",
        &["platform", "baseline", "points", "p10", "p25", "p50", "p75", "p90", "mean"],
    );
    rep.note("relative performance = SOTA_latency / Triton_latency (>1: Triton faster)");
    rep.note("MI250 baseline is the hipify-cross-compiled CUDA kernel, as in vLLM practice");
    for (pid, gpu) in sim_platforms() {
        let samples: Vec<f64> = relative_perf(&gpu).into_iter().map(|(_, r)| r).collect();
        let mean = samples.iter().sum::<f64>() / samples.len().max(1) as f64;
        let cdf = Cdf::new(samples.clone());
        let baseline = match gpu.spec.vendor {
            crate::platform::Vendor::Nvidia => "layernorm_kernels.cu",
            crate::platform::Vendor::Amd => "layernorm_kernels.cu (hipify)",
        };
        rep.row(vec![
            pid.name().into(),
            baseline.into(),
            cdf.len().to_string(),
            format!("{:.2}", cdf.quantile(0.10)),
            format!("{:.2}", cdf.quantile(0.25)),
            format!("{:.2}", cdf.quantile(0.50)),
            format!("{:.2}", cdf.quantile(0.75)),
            format!("{:.2}", cdf.quantile(0.90)),
            format!("{mean:.2}"),
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triton_beats_hipify_on_mi250_by_20pct() {
        // Paper: "consistently outperforms ... on MI250 by more than
        // 20% on average".
        let samples: Vec<f64> = relative_perf(&SimGpu::mi250()).into_iter().map(|(_, r)| r).collect();
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!(mean > 1.2, "MI250 mean relative perf {mean:.2}");
    }

    #[test]
    fn a100_triton_stays_behind_but_close() {
        // Paper: 91-98% typical on A100 (ratio ~ 1/0.95), small
        // workloads 60-90%.
        let samples = relative_perf(&SimGpu::a100());
        let typical: Vec<f64> = samples
            .iter()
            .filter(|(w, _)| matches!(w, Workload::RmsNorm { n_rows, .. } if *n_rows >= 4096))
            .map(|(_, r)| *r)
            .collect();
        let gm = crate::metrics::geomean(&typical);
        assert!(
            (0.85..1.05).contains(&gm),
            "A100 typical relative perf {gm:.2} (triton should be close behind)"
        );
    }

    #[test]
    fn small_workloads_hurt_triton_most_on_a100() {
        let samples = relative_perf(&SimGpu::a100());
        let small: Vec<f64> = samples
            .iter()
            .filter(|(w, _)| matches!(w, Workload::RmsNorm { n_rows, .. } if *n_rows <= 1024))
            .map(|(_, r)| *r)
            .collect();
        let large: Vec<f64> = samples
            .iter()
            .filter(|(w, _)| matches!(w, Workload::RmsNorm { n_rows, .. } if *n_rows >= 32768))
            .map(|(_, r)| *r)
            .collect();
        assert!(
            crate::metrics::geomean(&small) < crate::metrics::geomean(&large),
            "small workloads should be Triton's weak spot on A100"
        );
    }

    #[test]
    fn report_has_both_platforms() {
        let rep = rms_cdf();
        assert_eq!(rep.rows.len(), 2);
        assert!(rep.rows[1][1].contains("hipify"));
    }
}
