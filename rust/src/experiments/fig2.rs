//! Fig. 2 — causal flash-attention latency sweeps.
//!
//! For each platform and each max sequence length in {512, 1024, 2048,
//! 4096}, sweep batch size {1..64} and compare the vendor SOTA library
//! against the (unchanged) autotuned Triton kernel.  Latencies are
//! normalized to the leftmost flash_attn point of each panel, as in the
//! paper.  Headline claims checked by `summary()`:
//!
//! - best case: autotuned Triton up to **2.3x faster** than the vendor
//!   library;
//! - worst case: still >= **78 %** of SOTA;
//! - all from one kernel source, <2 % of the library's LoC.

use super::{sim_platforms, tune_triton_attention, BATCH_SWEEP, SEQLEN_SWEEP};
use crate::kernels::baselines::sota_attention_library;
use crate::platform::SimGpu;
use crate::report::Report;
use crate::workload::Workload;

/// One (platform, seqlen, batch) comparison point.
#[derive(Debug, Clone)]
pub struct Point {
    /// Sequence length of the point.
    pub seq_len: usize,
    /// Batch size of the point.
    pub batch: usize,
    /// Vendor-library (SOTA) latency, µs.
    pub sota_us: f64,
    /// Autotuned-Triton latency, µs.
    pub tuned_us: f64,
}

impl Point {
    /// sota/tuned: >1 means autotuning wins.
    pub fn speedup(&self) -> f64 {
        self.sota_us / self.tuned_us
    }
}

/// All sweep points for one platform.
pub fn sweep_points(gpu: &SimGpu) -> Vec<Point> {
    let lib = sota_attention_library(gpu.spec.vendor);
    let mut out = Vec::new();
    for &seq in &SEQLEN_SWEEP {
        for &batch in &BATCH_SWEEP {
            let w = Workload::llama3_attention(batch, seq);
            let Ok((sota_us, _)) = lib.latency_us(gpu, &w) else { continue };
            let Some((tuned_us, _, _, _)) = tune_triton_attention(gpu, &w) else { continue };
            out.push(Point { seq_len: seq, batch, sota_us, tuned_us });
        }
    }
    out
}

/// Fig. 2a/2b report for one platform.
pub fn latency_sweep(gpu: &SimGpu) -> Report {
    let mut rep = Report::new(
        format!("Fig.2 causal attention latency sweep — {}", gpu.spec.name),
        &["seqlen", "batch", "flash_attn_us", "autotuned_us", "flash_norm", "autotuned_norm", "speedup"],
    );
    rep.note("normalized to the leftmost flash_attn latency of each seqlen panel (lower is better)");
    let points = sweep_points(gpu);
    for &seq in &SEQLEN_SWEEP {
        let panel: Vec<&Point> = points.iter().filter(|p| p.seq_len == seq).collect();
        let Some(base) = panel.first().map(|p| p.sota_us) else { continue };
        for p in panel {
            rep.row(vec![
                p.seq_len.to_string(),
                p.batch.to_string(),
                format!("{:.1}", p.sota_us),
                format!("{:.1}", p.tuned_us),
                format!("{:.3}", p.sota_us / base),
                format!("{:.3}", p.tuned_us / base),
                format!("{:.2}", p.speedup()),
            ]);
        }
    }
    rep
}

/// Headline Q1 summary across both platforms.
pub fn summary() -> Report {
    let mut rep = Report::new(
        "Fig.2 summary — autotuned Triton vs vendor SOTA (paper §Q1)",
        &["platform", "points", "best_speedup", "worst_fraction_of_sota", "geomean_speedup"],
    );
    rep.note("paper: best case 2.3x faster, worst case 78% of SOTA");
    for (pid, gpu) in sim_platforms() {
        let pts = sweep_points(&gpu);
        let speedups: Vec<f64> = pts.iter().map(|p| p.speedup()).collect();
        let best = speedups.iter().cloned().fold(0.0f64, f64::max);
        let worst = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
        rep.row(vec![
            pid.name().into(),
            pts.len().to_string(),
            format!("{best:.2}x"),
            format!("{:.0}%", worst * 100.0),
            format!("{:.2}x", crate::metrics::geomean(&speedups)),
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_full_grid() {
        let pts = sweep_points(&SimGpu::a100());
        assert_eq!(pts.len(), SEQLEN_SWEEP.len() * BATCH_SWEEP.len());
    }

    #[test]
    fn best_case_beats_sota_substantially() {
        // Paper: up to 2.3x. Require >=1.5x somewhere across platforms.
        let best = sim_platforms()
            .iter()
            .flat_map(|(_, g)| sweep_points(g))
            .map(|p| p.speedup())
            .fold(0.0f64, f64::max);
        assert!(best > 1.5, "best speedup {best:.2}");
        assert!(best < 4.0, "speedup should stay paper-plausible, got {best:.2}");
    }

    #[test]
    fn worst_case_stays_competitive() {
        // Paper: worst case 78% of SOTA. Allow the band [0.6, 1.0].
        let worst = sim_platforms()
            .iter()
            .flat_map(|(_, g)| sweep_points(g))
            .map(|p| p.speedup())
            .fold(f64::INFINITY, f64::min);
        assert!(worst > 0.6, "worst fraction {worst:.2}");
        assert!(worst < 1.0, "somewhere SOTA should win, worst={worst:.2}");
    }

    #[test]
    fn autotuning_wins_most_at_small_batch() {
        // The mechanism behind the paper's best case: template dispatch
        // collapses occupancy on small workloads.
        let pts = sweep_points(&SimGpu::a100());
        let small: Vec<f64> = pts.iter().filter(|p| p.batch <= 2).map(|p| p.speedup()).collect();
        let large: Vec<f64> = pts.iter().filter(|p| p.batch >= 32).map(|p| p.speedup()).collect();
        let gm = |v: &[f64]| crate::metrics::geomean(v);
        assert!(
            gm(&small) > gm(&large),
            "small-batch speedup {:.2} should exceed large-batch {:.2}",
            gm(&small),
            gm(&large)
        );
    }

    #[test]
    fn latency_grows_with_batch() {
        let pts = sweep_points(&SimGpu::mi250());
        for &seq in &SEQLEN_SWEEP {
            let panel: Vec<&Point> = pts.iter().filter(|p| p.seq_len == seq).collect();
            assert!(panel.last().unwrap().tuned_us > panel.first().unwrap().tuned_us);
        }
    }
}
