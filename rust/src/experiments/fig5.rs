//! Fig. 5 — does autotuning enable better code generation?
//!
//! The paper analyzes the PTX of all Triton configurations evaluated for
//! one setup (Llama-3.1-8B attention, batch 64, seq 2048) against the 30
//! applicable CUDA templates:
//!
//! - Triton emits up to **475 unique instructions** vs the templates'
//!   **224** — the JIT specializes much more aggressively;
//! - Triton code sizes span **over an order of magnitude**; template
//!   sizes sit in a narrow band;
//! - the autotuner's winning configuration is *not* predictable from
//!   either static metric (the red marker).
//!
//! Here the same three counts run over (a) synthetic PTX from the
//! simulated sweep (the full 450-config corpus) and (b) the **real HLO
//! text** of every AOT-lowered Pallas configuration.

use crate::codegen::{hlo, ptx, CodeStats};
use crate::config::{spaces, Config};
use crate::kernels::baselines::{TemplateLibrary, TRITON_NVIDIA};
use crate::platform::SimGpu;
use crate::report::Report;
use crate::runtime::Manifest;
use crate::workload::Workload;

/// The Fig. 5 setup: attention for Llama-3.1-8B, batch 64, seq 2048.
pub fn fig5_workload() -> Workload {
    Workload::llama3_attention(64, 2048)
}

/// Per-config code stats for the Triton sweep on the A100 model,
/// in evaluation order, plus the index of the autotuner's winner.
pub fn triton_corpus() -> (Vec<(Config, CodeStats)>, Option<usize>) {
    let gpu = SimGpu::a100();
    let w = fig5_workload();
    let space = spaces::attention_sim_space();
    let mut corpus = Vec::new();
    let mut best: Option<(usize, f64)> = None;
    for cfg in space.enumerate(&w) {
        // Only configs valid on the platform produce code (the JIT
        // rejects the rest) — matching "450 evaluated configurations".
        let Ok(us) = gpu.attention_latency_us(&cfg, &w, &TRITON_NVIDIA) else { continue };
        let stats = ptx::analyze_ptx(&ptx::emit_triton(&cfg, &w));
        let idx = corpus.len();
        corpus.push((cfg, stats));
        if best.map(|(_, b)| us < b).unwrap_or(true) {
            best = Some((idx, us));
        }
    }
    (corpus, best.map(|(i, _)| i))
}

/// Code stats for the 30-ish CUDA templates applicable to the scenario.
pub fn cuda_corpus() -> Vec<(Config, CodeStats)> {
    let gpu = SimGpu::a100();
    let w = fig5_workload();
    TemplateLibrary::flash_attn()
        .templates
        .iter()
        .filter(|c| gpu.validate_attention(c, &w).is_ok())
        .map(|c| (c.clone(), ptx::analyze_ptx(&ptx::emit_cuda_template(c, &w))))
        .collect()
}

fn corpus_summary(rep: &mut Report, name: &str, corpus: &[(Config, CodeStats)], best: Option<usize>) {
    let unique_max = corpus.iter().map(|(_, s)| s.unique_instructions).max().unwrap_or(0);
    let unique_min = corpus.iter().map(|(_, s)| s.unique_instructions).min().unwrap_or(0);
    let total_max = corpus.iter().map(|(_, s)| s.total_instructions).max().unwrap_or(0);
    let total_min = corpus.iter().map(|(_, s)| s.total_instructions).min().unwrap_or(1);
    let size_max = corpus.iter().map(|(_, s)| s.bytes).max().unwrap_or(0);
    let size_min = corpus.iter().map(|(_, s)| s.bytes).min().unwrap_or(1);
    rep.row(vec![
        name.into(),
        corpus.len().to_string(),
        format!("{unique_min}..{unique_max}"),
        format!("{total_min}..{total_max}"),
        format!("{:.1}x", size_max as f64 / size_min as f64),
        best.map(|i| format!("#{i} ({})", corpus[i].0)).unwrap_or_else(|| "-".into()),
    ]);
}

/// Fig. 5a: the Triton sweep corpus.
pub fn triton_sweep() -> Report {
    let mut rep = Report::new(
        "Fig.5a Triton autotuning sweep — generated-code analysis",
        &["corpus", "configs", "unique_instrs", "total_instrs", "size_span", "autotuner_winner"],
    );
    rep.note(format!("workload: {}", fig5_workload().key()));
    let (corpus, best) = triton_corpus();
    corpus_summary(&mut rep, "Triton (sim sweep)", &corpus, best);
    rep
}

/// Fig. 5b: the CUDA-template corpus.
pub fn cuda_templates() -> Report {
    let mut rep = Report::new(
        "Fig.5b CUDA templates — generated-code analysis",
        &["corpus", "configs", "unique_instrs", "total_instrs", "size_span", "autotuner_winner"],
    );
    let corpus = cuda_corpus();
    corpus_summary(&mut rep, "CUDA templates", &corpus, None);
    rep
}

/// The real-HLO counterpart: identical methodology over the actual AOT
/// artifacts of the Pallas attention kernel.
pub fn real_hlo_corpus() -> Report {
    let mut rep = Report::new(
        "Fig.5 (real) Pallas AOT artifacts — HLO instruction analysis",
        &["bucket", "configs", "unique_instrs", "total_instrs", "size_span", "largest_config"],
    );
    rep.note("real compiler output: one HLO module per lowered kernel configuration");
    let Ok(manifest) = Manifest::load_default() else {
        rep.note("artifacts missing — run `make artifacts`");
        return rep;
    };
    for bucket in manifest.workload_buckets("attention") {
        let mut corpus: Vec<(Config, CodeStats)> = Vec::new();
        for a in manifest.candidates_for(&bucket) {
            if let Ok(stats) = hlo::analyze_file(manifest.root.join(&a.path)) {
                corpus.push((a.config(), stats));
            }
        }
        if corpus.is_empty() {
            continue;
        }
        let largest = corpus
            .iter()
            .max_by_key(|(_, s)| s.total_instructions)
            .map(|(c, _)| c.key())
            .unwrap_or_default();
        let unique_max = corpus.iter().map(|(_, s)| s.unique_instructions).max().unwrap();
        let unique_min = corpus.iter().map(|(_, s)| s.unique_instructions).min().unwrap();
        let total_max = corpus.iter().map(|(_, s)| s.total_instructions).max().unwrap();
        let total_min = corpus.iter().map(|(_, s)| s.total_instructions).min().unwrap();
        let size_max = corpus.iter().map(|(_, s)| s.bytes).max().unwrap();
        let size_min = corpus.iter().map(|(_, s)| s.bytes).min().unwrap();
        rep.row(vec![
            bucket.key(),
            corpus.len().to_string(),
            format!("{unique_min}..{unique_max}"),
            format!("{total_min}..{total_max}"),
            format!("{:.1}x", size_max as f64 / size_min as f64),
            largest,
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triton_corpus_is_paper_scale() {
        // Paper: 450 configurations analyzed.
        let (corpus, best) = triton_corpus();
        assert!(corpus.len() >= 400, "corpus {}", corpus.len());
        assert!(best.is_some());
    }

    #[test]
    fn triton_unique_exceeds_templates() {
        // Paper: 475 vs 224 — Triton's max unique count is at least 1.5x
        // the template corpus max.
        let (tri, _) = triton_corpus();
        let cud = cuda_corpus();
        let t_max = tri.iter().map(|(_, s)| s.unique_instructions).max().unwrap();
        let c_max = cud.iter().map(|(_, s)| s.unique_instructions).max().unwrap();
        assert!(
            t_max as f64 >= 1.5 * c_max as f64,
            "triton {t_max} vs templates {c_max}"
        );
    }

    #[test]
    fn triton_sizes_span_an_order_of_magnitude() {
        let (tri, _) = triton_corpus();
        let max = tri.iter().map(|(_, s)| s.bytes).max().unwrap();
        let min = tri.iter().map(|(_, s)| s.bytes).min().unwrap();
        assert!(max as f64 / min as f64 > 8.0, "span {:.1}", max as f64 / min as f64);
    }

    #[test]
    fn template_sizes_are_narrow() {
        let cud = cuda_corpus();
        let max = cud.iter().map(|(_, s)| s.bytes).max().unwrap();
        let min = cud.iter().map(|(_, s)| s.bytes).min().unwrap();
        assert!(
            (max as f64 / min as f64) < 6.0,
            "templates should be narrow, span {:.1}",
            max as f64 / min as f64
        );
    }

    #[test]
    fn winner_not_extremal_in_static_metrics() {
        // Paper: "it is not obvious why configuration #67 was chosen"
        // — the winner is neither the largest nor the most diverse.
        let (tri, best) = triton_corpus();
        let bi = best.unwrap();
        let max_total = tri.iter().map(|(_, s)| s.total_instructions).max().unwrap();
        let min_total = tri.iter().map(|(_, s)| s.total_instructions).min().unwrap();
        let w = tri[bi].1.total_instructions;
        assert!(w != max_total && w != min_total, "winner is extremal ({w})");
    }
}
