//! "New hardware, day 0" — the extension experiment motivated by the
//! paper's introduction:
//!
//! > *"it took over a year to adapt the flash_attn library to the new
//! > NVIDIA Hopper architecture"*
//!
//! We model that year-zero situation on the H100: the flash_attn
//! template *set* still runs (same vendor, same ISA family) but its
//! templates and codegen were tuned for Ampere — smaller smem staging
//! than Hopper affords, no TMA-depth pipelines, sm80 scheduling — so it
//! reaches only a fraction of the new part's ceiling.  The unchanged
//! autotuned kernel re-tunes overnight and claims the Hopper headroom
//! (deeper staging in 228 KiB smem) immediately.

use super::{BATCH_SWEEP, SEQLEN_SWEEP};
use crate::autotuner::{SessionOutcome, SimEvaluator, TuningSession};
use crate::config::spaces;
use crate::kernels::baselines::{Codegen, TemplateLibrary};
use crate::platform::SimGpu;
use crate::report::Report;
use crate::workload::Workload;

/// flash_attn's codegen quality on day-0 Hopper: compiled for sm80,
/// missing TMA/wgmma idioms (the gap the year of manual work closed).
pub const AMPERE_BINARY_ON_HOPPER: Codegen =
    Codegen { compute_eff: 0.58, mem_eff: 0.72, f16_packed: true };

/// Triton's JIT emits native sm90 code from day 0 (the DSL argument):
/// moderately below peak, but current-generation.
pub const TRITON_HOPPER: Codegen = Codegen { compute_eff: 0.88, mem_eff: 0.93, f16_packed: false };

/// One comparison point on the H100.
pub fn day0_point(w: &Workload) -> Option<(f64, f64)> {
    let h100 = SimGpu::h100();
    let lib = TemplateLibrary::flash_attn();
    let cfg = lib.dispatch(&h100, w)?;
    let lib_us = h100.attention_latency_us(&cfg, w, &AMPERE_BINARY_ON_HOPPER).ok()?;
    let mut eval = SimEvaluator::new(h100, *w, TRITON_HOPPER);
    let space = spaces::attention_sim_space();
    let tuned = TuningSession::new(&space, w)
        .evaluator(&mut eval)
        .run()
        .and_then(SessionOutcome::into_solo)?;
    Some((lib_us, tuned.best_latency_us))
}

/// The day-0 report across the Fig. 2 grid corners.
pub fn day0_report() -> Report {
    let mut rep = Report::new(
        "Extension — new hardware day 0 (H100): Ampere-tuned flash_attn vs re-autotuned kernel",
        &["seqlen", "batch", "flash_attn(sm80 build)_us", "autotuned_us", "speedup"],
    );
    rep.note("paper §I: adapting flash_attn to Hopper took over a year; autotuning adapts overnight");
    for &seq in &SEQLEN_SWEEP {
        for &batch in &[BATCH_SWEEP[0], BATCH_SWEEP[6]] {
            let w = Workload::llama3_attention(batch, seq);
            let Some((lib_us, tuned_us)) = day0_point(&w) else { continue };
            rep.row(vec![
                seq.to_string(),
                batch.to_string(),
                format!("{lib_us:.1}"),
                format!("{tuned_us:.1}"),
                format!("{:.2}x", lib_us / tuned_us),
            ]);
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn autotuning_claims_hopper_headroom_day0() {
        // The unchanged kernel + re-tuning must beat the year-old binary
        // decisively on the new part (that's the paper's whole argument).
        let w = Workload::llama3_attention(16, 2048);
        let (lib_us, tuned_us) = day0_point(&w).unwrap();
        let speedup = lib_us / tuned_us;
        assert!(speedup > 1.2, "day-0 speedup {speedup:.2}");
        assert!(speedup < 4.0, "stays physically plausible: {speedup:.2}");
    }

    #[test]
    fn hopper_tuned_config_uses_new_capacity() {
        // The H100's 228 KiB smem admits staging that was invalid on the
        // A100 — the autotuner should (be able to) use it.
        let w = Workload::llama3_attention(64, 2048);
        let h100 = SimGpu::h100();
        let a100 = SimGpu::a100();
        let space = spaces::attention_sim_space();
        let (valid_h, valid_a) = (
            space.enumerate(&w).filter(|c| h100.validate_attention(c, &w).is_ok()).count(),
            space.enumerate(&w).filter(|c| a100.validate_attention(c, &w).is_ok()).count(),
        );
        assert!(valid_h > valid_a, "H100 {valid_h} vs A100 {valid_a} valid configs");
    }

    #[test]
    fn report_covers_grid_corners() {
        let rep = day0_report();
        assert_eq!(rep.rows.len(), SEQLEN_SWEEP.len() * 2);
    }
}
