//! Experiment harness: regenerates every table and figure of the paper.
//!
//! | module | paper artifact |
//! |---|---|
//! | [`fig1`] | Fig. 1a/1b normalized attention throughput + Fig. 1c porting effort |
//! | [`fig2`] | Fig. 2a/2b causal-attention latency sweeps (batch x seqlen, both GPUs) |
//! | [`fig3`] | Fig. 3 RMS-norm relative-performance CDFs |
//! | [`fig4`] | Fig. 4 cross-GPU configuration-reuse degradation |
//! | [`fig5`] | Fig. 5a/5b generated-code analysis (+ real-HLO counterpart) |
//! | [`tables`] | Table I implementation inventory, Table II autotuning survey |
//!
//! Each experiment is a pure function returning [`Report`]s so the CLI,
//! the criterion benches and the integration tests all share one code
//! path.  Absolute numbers come from the analytical platform models; the
//! assertions in each module check the paper's *shape* claims (who wins,
//! by what factor, where crossovers fall) — see DESIGN.md §2.

pub mod ablation;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod hopper;
pub mod tables;

use crate::autotuner::{SessionOutcome, SimEvaluator, TuningSession};
use crate::config::{spaces, Config};
use crate::kernels::baselines::{triton_codegen, HAND_TUNED};
use crate::platform::{PlatformId, SimGpu};
use crate::report::Report;
use crate::workload::Workload;

/// The paper's batch-size sweep (x-axis of Fig. 2).
pub const BATCH_SWEEP: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// The paper's sequence-length plots (panels of Fig. 2).
pub const SEQLEN_SWEEP: [usize; 4] = [512, 1024, 2048, 4096];

/// The motivating workload of Fig. 1 / Fig. 5: Llama-3.1-8B attention,
/// batch 64, seq 1024 (Fig. 5 uses seq 2048).
pub fn fig1_workload() -> Workload {
    Workload::llama3_attention(64, 1024)
}

/// Exhaustively autotune Triton on a simulated platform; returns
/// (best latency µs, best config, #evaluated, #invalid).
pub fn tune_triton_attention(gpu: &SimGpu, w: &Workload) -> Option<(f64, Config, usize, usize)> {
    let space = spaces::attention_sim_space();
    let mut eval = SimEvaluator::new(gpu.clone(), *w, triton_codegen(gpu.spec.vendor));
    // Builder defaults are exactly this experiment: exhaustive, seed 0.
    let out = TuningSession::new(&space, w)
        .evaluator(&mut eval)
        .run()
        .and_then(SessionOutcome::into_solo)?;
    Some((out.best_latency_us, out.best, out.evaluated, out.invalid))
}

/// Exhaustively autotune the Triton RMS kernel on a platform.
pub fn tune_triton_rms(gpu: &SimGpu, w: &Workload) -> Option<(f64, Config)> {
    let space = spaces::rms_sim_space();
    let mut eval = SimEvaluator::new(gpu.clone(), *w, triton_codegen(gpu.spec.vendor));
    let out = TuningSession::new(&space, w)
        .evaluator(&mut eval)
        .run()
        .and_then(SessionOutcome::into_solo)?;
    Some((out.best_latency_us, out.best))
}

/// The best *achievable* latency on a platform (hand-tuned codegen,
/// whole space) — the denominator for "fraction of SOTA" summaries.
pub fn oracle_attention(gpu: &SimGpu, w: &Workload) -> Option<f64> {
    spaces::attention_sim_space()
        .enumerate(w)
        .filter_map(|c| gpu.attention_latency_us(&c, w, &HAND_TUNED).ok())
        .min_by(f64::total_cmp)
}

/// Both simulated platforms, in paper order (Fig. 2a = A100, 2b = MI250).
pub fn sim_platforms() -> [(PlatformId, SimGpu); 2] {
    [
        (PlatformId::SimA100, SimGpu::a100()),
        (PlatformId::SimMi250, SimGpu::mi250()),
    ]
}

/// Run every experiment, returning (slug, report) pairs.
pub fn run_all() -> Vec<(String, Report)> {
    let mut out: Vec<(String, Report)> = Vec::new();
    for (slug, rep) in [
        ("fig1a", fig1::throughput(&SimGpu::a100())),
        ("fig1b", fig1::throughput(&SimGpu::mi250())),
        ("fig1c", fig1::porting_effort()),
        ("fig2a", fig2::latency_sweep(&SimGpu::a100())),
        ("fig2b", fig2::latency_sweep(&SimGpu::mi250())),
        ("fig2_summary", fig2::summary()),
        ("fig3", fig3::rms_cdf()),
        ("fig4", fig4::cross_gpu_reuse()),
        ("fig5a", fig5::triton_sweep()),
        ("fig5b", fig5::cuda_templates()),
        ("fig5_real_hlo", fig5::real_hlo_corpus()),
        ("table1", tables::table1()),
        ("table2", tables::table2()),
        ("ablation_search", ablation::search_strategies()),
        ("ablation_guided", ablation::guided_pruning()),
        ("ablation_cache", ablation::cache_reuse()),
        ("ext_hopper_day0", hopper::day0_report()),
    ] {
        out.push((slug.to_string(), rep));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tune_explores_paper_scale_space() {
        // Paper: ~450 Triton configurations evaluated on the A100 for
        // one shape; 15x more than the 30 CUDA templates.
        let (_, _, evaluated, _invalid) =
            tune_triton_attention(&SimGpu::a100(), &Workload::llama3_attention(64, 2048)).unwrap();
        assert!(evaluated >= 450, "evaluated {evaluated}");
        assert!(evaluated as f64 / 30.0 >= 15.0);
    }

    #[test]
    fn mi250_has_fewer_valid_configs() {
        // Paper §Q2: "the number of valid Triton configurations for AMD
        // GPUs was significantly lower".
        let w = Workload::llama3_attention(64, 2048);
        let (_, _, eva, inv_a) = tune_triton_attention(&SimGpu::a100(), &w).unwrap();
        let (_, _, evm, inv_m) = tune_triton_attention(&SimGpu::mi250(), &w).unwrap();
        let valid_a = eva - inv_a;
        let valid_m = evm - inv_m;
        assert!(valid_m < valid_a, "A100 {valid_a} vs MI250 {valid_m}");
    }
}
