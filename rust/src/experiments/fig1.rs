//! Fig. 1 — the motivating comparison.
//!
//! (a)/(b): normalized throughput of four attention implementations on
//! A100 and MI250 for Llama-3.1-8B attention, batch 64, seq 1024.  The
//! paper's reading: PyTorch-native is 6-13x slower than the vendor
//! library; manually-configured Triton has huge variance (error bars);
//! autotuned Triton is competitive with the vendor library — from ONE
//! unchanged source.
//!
//! (c): the effort to port the attention layer across vendors — LoC
//! ledger of flash_attn vs rocm_flash_attn vs the zero-change
//! Triton/Pallas kernels.

use super::fig1_workload;
use crate::kernels::baselines::{
    sota_attention_library, triton_manual_attention, ImplId,
};
use crate::platform::SimGpu;
use crate::report::Report;

/// Fig. 1a/1b: normalized throughput on one platform.
pub fn throughput(gpu: &SimGpu) -> Report {
    let w = fig1_workload();
    let mut rep = Report::new(
        format!("Fig.1 normalized attention throughput — {}", gpu.spec.name),
        &["implementation", "LoC", "latency_us", "throughput_norm", "spread(min..max)"],
    );
    rep.note(format!("workload: {} (Llama-3.1-8B attention layer)", w.key()));
    rep.note("normalized to PyTorch-native = 1.0 on this platform (higher is better)");

    let native_us = gpu.native_attention_latency_us(&w).expect("native always runs");
    let norm = |us: f64| native_us / us;

    rep.row(vec![
        ImplId::PyTorchNative.label().into(),
        ImplId::PyTorchNative.loc().to_string(),
        format!("{native_us:.1}"),
        "1.00".into(),
        "-".into(),
    ]);

    let lib = sota_attention_library(gpu.spec.vendor);
    let lib_impl = match gpu.spec.vendor {
        crate::platform::Vendor::Nvidia => ImplId::FlashAttn,
        crate::platform::Vendor::Amd => ImplId::RocmFlashAttn,
    };
    let (lib_us, _) = lib.latency_us(gpu, &w).expect("vendor lib valid at home");
    rep.row(vec![
        lib_impl.label().into(),
        lib_impl.loc().to_string(),
        format!("{lib_us:.1}"),
        format!("{:.2}", norm(lib_us)),
        "-".into(),
    ]);

    let (best, mean, worst) = triton_manual_attention(gpu, &w).expect("manual triton runs");
    rep.row(vec![
        ImplId::TritonManual.label().into(),
        ImplId::TritonManual.loc().to_string(),
        format!("{mean:.1}"),
        format!("{:.2}", norm(mean)),
        format!("{:.2}..{:.2}", norm(worst), norm(best)),
    ]);

    let (tuned_us, cfg, evaluated, _) = super::tune_triton_attention(gpu, &w).expect("tuning runs");
    rep.row(vec![
        ImplId::TritonAutotuned.label().into(),
        ImplId::TritonAutotuned.loc().to_string(),
        format!("{tuned_us:.1}"),
        format!("{:.2}", norm(tuned_us)),
        format!("best={cfg} ({evaluated} cfgs)"),
    ]);
    rep
}

/// Fig. 1c: porting effort across GPU architectures.
///
/// The paper measured the low-level changes required to port flash_attn
/// to the MI250 (rocm_flash_attn): more than 40 % of the library had to
/// be manually rewritten.  The Triton/Pallas kernel is byte-identical on
/// both platforms; only the autotuning cache differs.
pub fn porting_effort() -> Report {
    let mut rep = Report::new(
        "Fig.1c porting effort: NVIDIA -> AMD attention",
        &["implementation", "LoC (origin)", "LoC (ported)", "LoC changed", "% changed"],
    );
    rep.note("flash_attn LoC changes measured by the paper; Triton/Pallas row is this work");

    // rocm_flash_attn is a fork of flash_attn: everything that is not
    // shared between the two trees was touched in the port. The paper
    // reports >40 % manual optimization; the LoC ledger gives the bound.
    let origin = ImplId::FlashAttn.loc();
    let ported = ImplId::RocmFlashAttn.loc();
    // Paper Fig 1c: >40 % of the initial library had to be changed.
    let changed = (origin as f64 * 0.43) as usize;
    rep.row(vec![
        "flash_attn -> rocm_flash_attn".into(),
        origin.to_string(),
        ported.to_string(),
        format!("~{changed}"),
        ">40%".into(),
    ]);
    rep.row(vec![
        "pytorch native".into(),
        ImplId::PyTorchNative.loc().to_string(),
        ImplId::PyTorchNative.loc().to_string(),
        "0".into(),
        "0%".into(),
    ]);
    rep.row(vec![
        "Triton w/ autotuning (paper)".into(),
        ImplId::TritonAutotuned.loc().to_string(),
        ImplId::TritonAutotuned.loc().to_string(),
        "0".into(),
        "0%".into(),
    ]);
    let pallas_loc = crate::experiments::tables::our_kernel_loc("flash_attention.py").unwrap_or(0);
    rep.row(vec![
        "Pallas w/ autotuning (this repo)".into(),
        pallas_loc.to_string(),
        pallas_loc.to_string(),
        "0".into(),
        "0%".into(),
    ]);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::SimGpu;

    #[test]
    fn native_is_paper_factor_slower_than_sota() {
        // Paper: 6-13x across the two platforms.
        for gpu in [SimGpu::a100(), SimGpu::mi250()] {
            let rep = throughput(&gpu);
            let sota_norm: f64 = rep.rows[1][3].parse().unwrap();
            assert!(
                (4.0..16.0).contains(&sota_norm),
                "{}: sota {}x native",
                gpu.spec.name,
                sota_norm
            );
        }
    }

    #[test]
    fn autotuned_is_competitive_with_vendor_lib() {
        // Paper: autotuned Triton within 78%..230% of flash_attn.
        for gpu in [SimGpu::a100(), SimGpu::mi250()] {
            let rep = throughput(&gpu);
            let sota: f64 = rep.rows[1][3].parse().unwrap();
            let tuned: f64 = rep.rows[3][3].parse().unwrap();
            let ratio = tuned / sota;
            assert!(
                (0.7..2.5).contains(&ratio),
                "{}: autotuned/sota = {ratio:.2}",
                gpu.spec.name
            );
        }
    }

    #[test]
    fn manual_triton_has_wide_error_bars() {
        let rep = throughput(&SimGpu::a100());
        let spread = &rep.rows[2][4];
        let (lo, hi) = spread.split_once("..").unwrap();
        let (lo, hi): (f64, f64) = (lo.parse().unwrap(), hi.parse().unwrap());
        assert!(hi / lo > 1.5, "manual spread should be visible: {lo}..{hi}");
    }

    #[test]
    fn porting_effort_rows_complete() {
        let rep = porting_effort();
        assert_eq!(rep.rows.len(), 4);
        assert!(rep.rows[0][4].contains("40"));
        assert_eq!(rep.rows[2][3], "0");
    }
}
