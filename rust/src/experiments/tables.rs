//! Table I (implementation inventory) and Table II (autotuning usage in
//! LLM frameworks).
//!
//! Table I pairs the paper's LoC ledger with the *measured* LoC of this
//! repository's counterparts (the Pallas kernels), substantiating the
//! "70x code-size reduction" headline on our own artifact.
//!
//! Table II reproduces the paper's survey of Triton-kernel autotuning in
//! popular frameworks, and appends the same metric computed over this
//! repository (every kernel is autotuned here, by construction).

use crate::kernels::baselines::ImplId;
use crate::report::Report;
use crate::runtime::Manifest;

/// Count non-empty, non-comment lines of one of our kernel sources.
pub fn our_kernel_loc(file: &str) -> Option<usize> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = root.join("python/compile/kernels").join(file);
    let text = std::fs::read_to_string(path).ok()?;
    Some(count_loc(&text))
}

/// LoC counting rule used for the table: non-empty lines that are not
/// pure comments (matching cloc's default closely enough for a ledger).
pub fn count_loc(text: &str) -> usize {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#') && !l.starts_with("//"))
        .count()
}

/// Table I: investigated kernel implementations.
pub fn table1() -> Report {
    let mut rep = Report::new(
        "Table I — investigated LLM kernel implementations",
        &["kernel", "implementation", "LoC", "target vendor", "source"],
    );
    let rows: Vec<(&str, ImplId, &str, &str)> = vec![
        ("attention", ImplId::FlashAttn, "NVIDIA", "github.com/Dao-AILab/flash-attention"),
        ("attention", ImplId::RocmFlashAttn, "AMD", "github.com/ROCm/flash-attention"),
        ("attention", ImplId::PyTorchNative, "NVIDIA / AMD", "pytorch functional.py"),
        ("attention", ImplId::TritonManual, "NVIDIA / AMD", "AMD Triton kernels team"),
        ("attention", ImplId::TritonAutotuned, "NVIDIA / AMD", "ibm.biz/vllm-ibm-triton-lib (paper)"),
        ("RMS", ImplId::VllmCudaRms, "NVIDIA (& AMD via hipify)", "github.com/vllm-project/vllm"),
        ("RMS", ImplId::TritonRmsAutotuned, "AMD / NVIDIA", "ibm.biz/vllm-ibm-triton-lib (paper)"),
    ];
    for (kernel, id, vendor, src) in rows {
        rep.row(vec![
            kernel.into(),
            id.label().into(),
            id.loc().to_string(),
            vendor.into(),
            src.into(),
        ]);
    }
    // Our own counterparts, counted from the working tree.
    for (kernel, file) in [
        ("attention", "flash_attention.py"),
        ("RMS", "rms_norm.py"),
        ("vector add", "vector_add.py"),
    ] {
        if let Some(loc) = our_kernel_loc(file) {
            rep.row(vec![
                kernel.into(),
                format!("Pallas w/ autotuning ({file})"),
                loc.to_string(),
                "any PJRT".into(),
                "this repository".into(),
            ]);
        }
    }
    rep.note(format!(
        "code-size reduction, paper: flash_attn/TritonAutotuned = {:.0}x",
        ImplId::FlashAttn.loc() as f64 / ImplId::TritonAutotuned.loc() as f64
    ));
    rep
}

/// Table II: usage of autotuning in popular LLM frameworks.
pub fn table2() -> Report {
    let mut rep = Report::new(
        "Table II — usage of autotuning in popular LLM frameworks",
        &["framework", "triton kernels", "kernels w/ autotuning", "source"],
    );
    // The paper's survey (static data).
    for (fw, kernels, tuned, src) in [
        ("vLLM", 57, 7, "github.com/vllm-project/vllm"),
        ("pytorch-labs/applied-ai", 61, 9, "github.com/pytorch-labs/applied-ai"),
        ("sglang", 13, 0, "github.com/sgl-project/sglang"),
    ] {
        rep.row(vec![fw.into(), kernels.to_string(), tuned.to_string(), src.into()]);
    }
    // The same metric over this repository, measured from the manifest:
    // every kernel family with >1 lowered configuration is autotuned.
    if let Ok(m) = Manifest::load_default() {
        let kernels = ["attention", "rms_norm", "vector_add"];
        let tuned = kernels
            .iter()
            .filter(|k| {
                m.workload_buckets(k)
                    .iter()
                    .any(|w| m.candidates_for(w).len() > 1)
            })
            .count();
        rep.row(vec![
            "portatune (this repo)".into(),
            kernels.len().to_string(),
            tuned.to_string(),
            "this repository".into(),
        ]);
    }
    rep.note("paper: only a fraction of Triton kernels in production frameworks use autotuning");
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loc_counter_ignores_comments_and_blanks() {
        assert_eq!(count_loc("a = 1\n\n# comment\n  // c\nb = 2\n"), 2);
    }

    #[test]
    fn our_kernels_are_paper_small() {
        // Table I: the whole point — kernels in the ~100-200 LoC class
        // vs the 50-70k LoC template libraries.
        let fa = our_kernel_loc("flash_attention.py").expect("kernel file exists");
        assert!(fa < 250, "flash_attention.py has {fa} LoC");
        let ratio = ImplId::FlashAttn.loc() as f64 / fa as f64;
        assert!(ratio > 250.0, "reduction {ratio:.0}x");
        let rms = our_kernel_loc("rms_norm.py").expect("kernel file exists");
        assert!(rms < 150, "rms_norm.py has {rms} LoC");
    }

    #[test]
    fn table1_contains_paper_ledger() {
        let rep = table1();
        assert!(rep.rows.iter().any(|r| r[1] == "flash_attn" && r[2] == "69197"));
        assert!(rep.rows.iter().any(|r| r[1].contains("Pallas")));
    }

    #[test]
    fn table2_has_survey_and_us() {
        let rep = table2();
        assert!(rep.rows.len() >= 3);
        assert!(rep.rows.iter().any(|r| r[0] == "sglang" && r[2] == "0"));
    }
}
