//! Ablations for the design choices DESIGN.md calls out (beyond the
//! paper's own figures):
//!
//! - **search strategy** (Q4.2): result quality vs evaluation budget for
//!   every implemented strategy — quantifies how much cheaper than the
//!   paper's 24 h exhaustive budget a practical tuner can be;
//! - **model-guided pruning**: how many empirical measurements a
//!   simulator prior saves at matched quality;
//! - **cache reuse** (Q4.3): evaluations saved by the déjà-vu cache
//!   across repeated deployments.

use crate::autotuner::{SessionOutcome, SimEvaluator, Strategy, TuneOutcome, TuningSession};
use crate::cache::TuningCache;
use crate::config::spaces;
use crate::kernels::baselines::{triton_codegen, HAND_TUNED};
use crate::platform::SimGpu;
use crate::report::Report;
use crate::workload::Workload;

/// One solo builder run (the ablations never use cache or budget here,
/// so the spelling is short enough to share).
fn run_tune(
    space: &crate::config::ConfigSpace,
    w: &Workload,
    eval: &mut SimEvaluator,
    strategy: Strategy,
    seed: u64,
) -> TuneOutcome {
    TuningSession::new(space, w)
        .strategy(strategy)
        .seed(seed)
        .evaluator(eval)
        .run()
        .and_then(SessionOutcome::into_solo)
        .expect("ablation spaces are non-empty")
}

/// Strategy-quality ablation over several workloads.
pub fn search_strategies() -> Report {
    let mut rep = Report::new(
        "Ablation — search strategies (Q4.2): quality vs budget",
        &["workload", "strategy", "evaluated", "best_us", "vs_exhaustive"],
    );
    rep.note("vs_exhaustive = strategy_best / exhaustive_best (1.00 = found the optimum)");
    let gpu = SimGpu::a100();
    let space = spaces::attention_sim_space();
    for w in [
        Workload::llama3_attention(1, 512),
        Workload::llama3_attention(8, 1024),
        Workload::llama3_attention(64, 2048),
    ] {
        let cg = triton_codegen(gpu.spec.vendor);
        let mut eval = SimEvaluator::new(gpu.clone(), w, cg);
        let exhaustive = run_tune(&space, &w, &mut eval, Strategy::Exhaustive, 0);
        for strat in [
            Strategy::Exhaustive,
            Strategy::Random { budget: 50 },
            Strategy::Random { budget: 150 },
            Strategy::HillClimb { restarts: 4, budget: 150 },
            Strategy::Anneal { budget: 150, t0: 2.0, alpha: 0.95 },
            Strategy::SuccessiveHalving { initial: 64, eta: 2 },
        ] {
            let out = run_tune(&space, &w, &mut eval, strat.clone(), 7);
            rep.row(vec![
                w.key(),
                strat.label(),
                out.evaluated.to_string(),
                format!("{:.1}", out.best_latency_us),
                format!("{:.3}", out.best_latency_us / exhaustive.best_latency_us),
            ]);
        }
    }
    rep
}

/// Model-guided pruning ablation: prior = hand-tuned analytical model,
/// target = Triton-codegen model (a *different* efficiency surface, so
/// the transfer is non-trivial).
pub fn guided_pruning() -> Report {
    let mut rep = Report::new(
        "Ablation — model-guided pruning: empirical measurements saved by a simulator prior",
        &["workload", "top_k", "measured", "vs_exhaustive", "pruning"],
    );
    let gpu = SimGpu::a100();
    let space = spaces::attention_sim_space();
    for w in [Workload::llama3_attention(1, 512), Workload::llama3_attention(64, 2048)] {
        let cg = triton_codegen(gpu.spec.vendor);
        let mut target = SimEvaluator::new(gpu.clone(), w, cg);
        let exhaustive = run_tune(&space, &w, &mut target, Strategy::Exhaustive, 0);
        for top_k in [5usize, 10, 20, 50] {
            let mut prior = SimEvaluator::new(gpu.clone(), w, HAND_TUNED);
            let out = TuningSession::new(&space, &w)
                .guided(&mut prior, top_k)
                .evaluator(&mut target)
                .run()
                .and_then(SessionOutcome::into_solo)
                .unwrap();
            rep.row(vec![
                w.key(),
                top_k.to_string(),
                out.evaluated.to_string(),
                format!("{:.3}", out.best_latency_us / exhaustive.best_latency_us),
                format!("{:.0}x", exhaustive.evaluated as f64 / out.evaluated.max(1) as f64),
            ]);
        }
    }
    rep
}

/// Cache-reuse ablation: evaluations across three simulated deployments.
pub fn cache_reuse() -> Report {
    let mut rep = Report::new(
        "Ablation — déjà-vu cache (Q4.3): evaluations per deployment",
        &["deployment", "cached", "evaluated", "wall_note"],
    );
    rep.note("without the cache, every process start re-pays the full tuning cost (paper §Q3)");
    let gpu = SimGpu::a100();
    let w = Workload::llama3_attention(16, 1024);
    let space = spaces::attention_sim_space();
    let mut cache = TuningCache::ephemeral();
    for deployment in 1..=3 {
        let cg = triton_codegen(gpu.spec.vendor);
        let mut eval = SimEvaluator::new(gpu.clone(), w, cg);
        let out = TuningSession::new(&space, &w)
            .cache(&mut cache)
            .evaluator(&mut eval)
            .run()
            .and_then(SessionOutcome::into_solo)
            .unwrap();
        rep.row(vec![
            format!("run{deployment}"),
            out.from_cache.to_string(),
            out.evaluated.to_string(),
            if out.from_cache { "instant".into() } else { format!("{:.3}s", out.wall_seconds) },
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategies_table_is_complete() {
        let rep = search_strategies();
        assert_eq!(rep.rows.len(), 3 * 6);
        // Exhaustive rows must show ratio 1.000.
        for row in rep.rows.iter().filter(|r| r[1] == "exhaustive") {
            assert_eq!(row[4], "1.000");
        }
    }

    #[test]
    fn guided_pruning_saves_an_order_of_magnitude() {
        let rep = guided_pruning();
        // At top_k=20 the prior should prune >=10x while staying within
        // 15% of the exhaustive optimum.
        let k20: Vec<_> = rep.rows.iter().filter(|r| r[1] == "20").collect();
        assert_eq!(k20.len(), 2);
        for row in k20 {
            let quality: f64 = row[3].parse().unwrap();
            let pruning: f64 = row[4].trim_end_matches('x').parse().unwrap();
            assert!(quality <= 1.15, "quality {quality}");
            assert!(pruning >= 10.0, "pruning {pruning}");
        }
    }

    #[test]
    fn cache_reuse_hits_after_first() {
        let rep = cache_reuse();
        assert_eq!(rep.rows[0][1], "false");
        assert_eq!(rep.rows[1][1], "true");
        assert_eq!(rep.rows[1][2], "0");
        assert_eq!(rep.rows[2][1], "true");
    }
}
