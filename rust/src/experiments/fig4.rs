//! Fig. 4 — is autotuning necessary? Cross-GPU configuration reuse.
//!
//! Protocol (paper §Q2): tune on platform P, take the optimal
//! configuration, run it unchanged on platform Q; report the fraction of
//! Q's own tuned performance retained.  Findings to reproduce:
//!
//! - reuse degrades performance by **at least 20 %** and up to an order
//!   of magnitude (as low as **7 %** retained);
//! - some configurations are **invalid** on the other platform entirely
//!   (missing bars).

use super::{tune_triton_attention, BATCH_SWEEP, SEQLEN_SWEEP};
use crate::kernels::baselines::triton_codegen;
use crate::platform::SimGpu;
use crate::report::Report;
use crate::workload::Workload;

/// Outcome of transplanting one tuned config.
#[derive(Debug, Clone)]
pub enum ReuseOutcome {
    /// Fraction of native-tuned performance retained on the target.
    Retained(f64),
    /// The config does not run on the target platform at all.
    Invalid(String),
}

/// Transplant the optimum of `src` onto `dst` for one workload.
pub fn transplant(src: &SimGpu, dst: &SimGpu, w: &Workload) -> Option<(ReuseOutcome, f64)> {
    let (_, src_best_cfg, _, _) = tune_triton_attention(src, w)?;
    let (dst_tuned_us, _, _, _) = tune_triton_attention(dst, w)?;
    let cg = triton_codegen(dst.spec.vendor);
    match dst.attention_latency_us(&src_best_cfg, w, &cg) {
        Ok(us) => Some((ReuseOutcome::Retained(dst_tuned_us / us), dst_tuned_us)),
        Err(e) => Some((ReuseOutcome::Invalid(e.reason), dst_tuned_us)),
    }
}

/// Fig. 4 report: both transplant directions across the seqlen sweep at
/// a few batch sizes.
pub fn cross_gpu_reuse() -> Report {
    let mut rep = Report::new(
        "Fig.4 cross-GPU configuration reuse (fraction of native tuned performance)",
        &["direction", "seqlen", "batch", "retained", "note"],
    );
    rep.note("paper: >=20% loss everywhere, down to 7% retained; some configs invalid");
    let a100 = SimGpu::a100();
    let mi250 = SimGpu::mi250();
    for &(src, dst, label) in
        &[(&a100, &mi250, "A100-opt on MI250"), (&mi250, &a100, "MI250-opt on A100")]
    {
        for &seq in &SEQLEN_SWEEP {
            for &batch in &[BATCH_SWEEP[0], BATCH_SWEEP[3], BATCH_SWEEP[6]] {
                let w = Workload::llama3_attention(batch, seq);
                let Some((outcome, _)) = transplant(src, dst, &w) else { continue };
                match outcome {
                    ReuseOutcome::Retained(f) => rep.row(vec![
                        label.into(),
                        seq.to_string(),
                        batch.to_string(),
                        format!("{:.0}%", f * 100.0),
                        String::new(),
                    ]),
                    ReuseOutcome::Invalid(reason) => rep.row(vec![
                        label.into(),
                        seq.to_string(),
                        batch.to_string(),
                        "INVALID".into(),
                        reason,
                    ]),
                }
            }
        }
    }
    rep
}

/// All retained fractions (for the summary assertions / benches).
pub fn retained_fractions() -> (Vec<f64>, usize) {
    let a100 = SimGpu::a100();
    let mi250 = SimGpu::mi250();
    let mut retained = Vec::new();
    let mut invalid = 0usize;
    for (src, dst) in [(&a100, &mi250), (&mi250, &a100)] {
        for &seq in &SEQLEN_SWEEP {
            for &batch in &BATCH_SWEEP {
                let w = Workload::llama3_attention(batch, seq);
                if let Some((outcome, _)) = transplant(src, dst, &w) {
                    match outcome {
                        ReuseOutcome::Retained(f) => retained.push(f),
                        ReuseOutcome::Invalid(_) => invalid += 1,
                    }
                }
            }
        }
    }
    (retained, invalid)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_always_loses_performance() {
        let (retained, _) = retained_fractions();
        assert!(!retained.is_empty());
        for f in &retained {
            assert!(*f <= 1.0 + 1e-9, "transplanted config cannot beat native tuning: {f}");
        }
        // Paper: performance drops by at least 20% somewhere (typically
        // everywhere); require the median drop to exceed 10%.
        let mut sorted = retained.clone();
        sorted.sort_by(f64::total_cmp);
        let median = sorted[sorted.len() / 2];
        assert!(median < 0.9, "median retained {median:.2}");
    }

    #[test]
    fn worst_case_is_severe() {
        // Paper: "at least 20% loss, up to an order of magnitude", with a
        // single 7% outlier. Our analytical model reproduces the register
        // -spill cliff driving the severe cases; require the worst valid
        // transplant to lose more than half its performance.
        let (retained, _) = retained_fractions();
        let worst = retained.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(worst < 0.45, "worst retained {worst:.2}");
    }

    #[test]
    fn some_configs_invalid_on_other_platform() {
        // Fig 4b's missing values: A100 optima (big smem staging) often
        // cannot run on the MI250 at all.
        let (_, invalid) = retained_fractions();
        assert!(invalid > 0, "expected at least one invalid transplant");
    }

    #[test]
    fn report_mentions_invalid() {
        let rep = cross_gpu_reuse();
        assert!(rep.rows.iter().any(|r| r[3] == "INVALID"));
    }
}
