//! Synthetic PTX emitter for the Fig. 5 sweep.
//!
//! We cannot run the NVIDIA toolchain here, so this module *generates*
//! PTX-shaped assembly text the way the two code-production pipelines of
//! the paper do, then feeds it through the same counting methodology:
//!
//! - [`emit_triton`] models Triton's JIT: the kernel loop is software-
//!   pipelined `num_stages` deep and specialized per configuration —
//!   vector widths, cp.async staging, per-stage predicates and unrolled
//!   bodies all change with the configuration.  This is why the paper
//!   sees *"over one order of magnitude larger"* code and up to 475
//!   unique instructions across configurations.
//! - [`emit_cuda_template`] models the hand-written template libraries:
//!   a generic loop compiled conservatively (bounded unrolling, fixed
//!   vector widths), hence the narrow size range and <=224 unique
//!   instructions the paper measures.
//!
//! The emitted text is deterministic in (config, workload), so Fig. 5 is
//! exactly reproducible.

use super::CodeStats;
use crate::config::Config;
use crate::workload::Workload;

const MMA: &str = "mma.sync.aligned.m16n8k16.row.col.f32.f16.f16.f32";

struct Asm {
    lines: Vec<String>,
}

impl Asm {
    fn new() -> Self {
        Asm { lines: Vec::new() }
    }

    fn push(&mut self, mnemonic: &str, operands: &str) {
        self.lines.push(format!("\t{mnemonic} {operands};"));
    }

    fn pushn(&mut self, n: usize, mnemonic: &str, operands: &str) {
        for i in 0..n {
            self.push(mnemonic, &format!("{operands}+{i}"));
        }
    }

    fn text(self) -> String {
        self.lines.join("\n")
    }
}

/// Count statistics with the paper's rule: mnemonic = opcode + prefixes
/// (everything before the first space), predicates included.
pub fn analyze_ptx(text: &str) -> CodeStats {
    let mnemonics = text.lines().filter_map(|l| {
        let t = l.trim();
        if t.is_empty() || t.ends_with(':') || t.starts_with("//") {
            return None;
        }
        // "@%p3 bra.uni TARGET;" -> "@%p3 bra.uni" per the paper's
        // opcode+prefix counting (predication is a prefix).
        let mut parts = t.split_whitespace();
        let first = parts.next()?;
        if first.starts_with('@') {
            let op = parts.next()?;
            // Leak-free: we need a &str borrowed from text; instead
            // return the slice covering both tokens.
            let start = t.find(first)?;
            let end = t.find(op)? + op.len();
            Some(&t[start..end])
        } else {
            Some(first)
        }
    });
    super::stats_from_mnemonics(mnemonics, text.len())
}

fn attention_dims(w: &Workload) -> (usize, usize) {
    match *w {
        Workload::Attention { seq_len, head_dim, .. } => (seq_len, head_dim),
        _ => (1024, 128),
    }
}

/// PTX as Triton's JIT would emit it for one attention configuration.
pub fn emit_triton(cfg: &Config, w: &Workload) -> String {
    let (seq, d) = attention_dims(w);
    let bm = cfg.req("BLOCK_M") as usize;
    let bn = cfg.req("BLOCK_N") as usize;
    let warps = cfg.req("num_warps") as usize;
    let stages = cfg.req("num_stages") as usize;
    let threads = warps * 32;
    let mut a = Asm::new();

    // --- prologue: parameter loads, index math, predicate setup --------
    for i in 0..8 {
        a.push("ld.param.u64", &format!("%rd{i}, [param_{i}]"));
    }
    a.push("mov.u32", "%tid, %tid.x");
    a.push("mov.u32", "%ctaid, %ctaid.x");
    a.pushn(6 + warps, "mad.lo.s32", "%r");
    a.pushn(4, "shl.b32", "%r");
    a.pushn(stages + 1, "setp.lt.s32", "%p");
    // Specialized address precomputation per stage (what JIT
    // specialization buys: immediate-folded addressing).
    for s in 0..stages {
        a.push(&format!("cvta.to.shared.u64.stage{s}"), "%rd");
    }

    // --- Q tile load (once): vectorized width picked per config --------
    let vec = if (bm * d / threads) % 8 == 0 { 8 } else if (bm * d / threads) % 4 == 0 { 4 } else { 2 };
    let q_loads = (bm * d / threads / vec).max(1);
    a.pushn(q_loads, &format!("ld.global.nc.v{vec}.b16"), "%q");

    // --- main K/V loop, software-pipelined `stages` deep ----------------
    let k_iters_codegen = stages.max(1); // bodies materialized in code
    let kv_loads = (bn * d / threads / vec).max(1);
    let mma_per_panel = (bm / 16).max(1) * (bn / 8).max(1) * (d / 16).max(1) / warps.max(1);
    for s in 0..k_iters_codegen {
        a.push(&format!("@%p{s} bra.uni"), &format!("SKIP_{s}"));
        // cp.async staging per pipeline stage (Ampere path).
        a.pushn(kv_loads, &format!("cp.async.cg.shared.global.stage{s}"), "[%smem], [%gk]");
        a.pushn(kv_loads, &format!("cp.async.cg.shared.global.stage{s}"), "[%smem], [%gv]");
        a.push("cp.async.commit_group", "");
        a.push(&format!("cp.async.wait_group.{s}"), "");
        a.push("bar.sync", "0");
        // QK^T on the tensor cores.
        a.pushn(mma_per_panel.max(1), MMA, "{%acc}, {%qa}, {%kb}, {%acc}");
        // online softmax: row max, exp2, normalizer update.
        let soft = (bm / warps).max(1);
        a.pushn(soft, "max.f32", "%m");
        a.pushn(soft, "sub.ftz.f32", "%s");
        a.pushn(soft, "ex2.approx.ftz.f32", "%e");
        a.pushn(soft, "fma.rn.f32", "%l");
        // P·V accumulate.
        a.pushn(mma_per_panel.max(1), MMA, "{%o}, {%pa}, {%vb}, {%o}");
        // register rescale of the accumulator (f32).
        a.pushn((bm * d / threads / 2).max(1), "mul.rn.f32", "%acc");
    }
    // loop bookkeeping
    a.push("add.s32", "%it, %it, 1");
    a.push("setp.lt.s32", &format!("%pl, %it, {}", seq / bn.max(1)));
    a.push("@%pl bra.uni", "LOOP");

    // --- epilogue: normalize + store, vectorized per config -------------
    let stores = (bm * d / threads / vec).max(1);
    a.pushn((bm / warps).max(1), "rcp.approx.f32", "%inv");
    a.pushn(stores, &format!("st.global.v{vec}.b16"), "[%out], %o");
    a.push("ret", "");
    a.text()
}

/// PTX as nvcc emits a hand-written template: generic loop, fixed
/// 128-bit vector width, at most double-buffered, no per-stage
/// specialization.
pub fn emit_cuda_template(cfg: &Config, w: &Workload) -> String {
    let (_, d) = attention_dims(w);
    let bm = cfg.req("BLOCK_M") as usize;
    let bn = cfg.req("BLOCK_N") as usize;
    let warps = cfg.req("num_warps") as usize;
    let threads = warps * 32;
    let mut a = Asm::new();

    for i in 0..6 {
        a.push("ld.param.u64", &format!("%rd{i}, [param_{i}]"));
    }
    a.push("mov.u32", "%tid, %tid.x");
    a.pushn(6, "mad.lo.s32", "%r");
    a.push("setp.lt.s32", "%p0");

    // nvcc bounds #pragma unroll: beyond 16 iterations it emits a loop,
    // so code size stays in a narrow band across templates.
    let q_loads = (bm * d / threads / 8).clamp(1, 16);
    a.pushn(q_loads, "ld.global.v4.b32", "%q");

    // Generic double-buffered loop body, emitted once.
    let kv_loads = (bn * d / threads / 8).clamp(1, 16);
    let mma = ((bm / 16).max(1) * (bn / 8).max(1) * (d / 16).max(1) / warps.max(1)).clamp(1, 24);
    for buf in 0..2 {
        a.pushn(kv_loads, "cp.async.cg.shared.global", &format!("[%smem{buf}], [%gk]"));
        a.pushn(kv_loads, "cp.async.cg.shared.global", &format!("[%smem{buf}], [%gv]"));
    }
    a.push("cp.async.commit_group", "");
    a.push("cp.async.wait_group.1", "");
    a.push("bar.sync", "0");
    a.pushn(mma, MMA, "{%acc}, {%qa}, {%kb}, {%acc}");
    let soft = (bm / warps).max(1);
    a.pushn(soft, "max.f32", "%m");
    a.pushn(soft, "ex2.approx.f32", "%e");
    a.pushn(soft, "fma.rn.f32", "%l");
    a.pushn(mma, MMA, "{%o}, {%pa}, {%vb}, {%o}");
    a.push("add.s32", "%it, %it, 1");
    a.push("setp.lt.s32", "%pl, %it, %nk");
    a.push("@%pl bra.uni", "LOOP");

    a.pushn((bm * d / threads / 8).clamp(1, 16), "st.global.v4.b32", "[%out], %o");
    a.push("ret", "");
    a.text()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(bm: i64, bn: i64, warps: i64, stages: i64) -> Config {
        Config::new(&[
            ("BLOCK_M", bm),
            ("BLOCK_N", bn),
            ("num_warps", warps),
            ("num_stages", stages),
            ("waves_per_eu", 0),
        ])
    }

    fn w() -> Workload {
        Workload::llama3_attention(64, 2048)
    }

    #[test]
    fn triton_code_varies_with_config() {
        let a = analyze_ptx(&emit_triton(&cfg(64, 64, 4, 2), &w()));
        let b = analyze_ptx(&emit_triton(&cfg(128, 128, 8, 5), &w()));
        assert_ne!(a.total_instructions, b.total_instructions);
        assert_ne!(a.unique_instructions, b.unique_instructions);
    }

    #[test]
    fn deterministic_emission() {
        let x = emit_triton(&cfg(64, 64, 4, 2), &w());
        let y = emit_triton(&cfg(64, 64, 4, 2), &w());
        assert_eq!(x, y);
    }

    #[test]
    fn triton_more_diverse_than_template() {
        // Fig 5 key contrast: across the same configs, Triton's
        // specialization produces more unique instructions.
        let c = cfg(128, 64, 4, 3);
        let t = analyze_ptx(&emit_triton(&c, &w()));
        let n = analyze_ptx(&emit_cuda_template(&c, &w()));
        assert!(t.unique_instructions > n.unique_instructions);
    }

    #[test]
    fn stage_specialization_grows_code() {
        let s1 = analyze_ptx(&emit_triton(&cfg(64, 64, 4, 1), &w()));
        let s5 = analyze_ptx(&emit_triton(&cfg(64, 64, 4, 5), &w()));
        assert!(s5.total_instructions > s1.total_instructions * 2);
    }

    #[test]
    fn predicated_branch_counts_with_predicate() {
        let s = analyze_ptx("\t@%p1 bra.uni SKIP;\n\t@%p2 bra.uni SKIP;");
        assert_eq!(s.unique_instructions, 2);
    }
}
