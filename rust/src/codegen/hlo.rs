//! HLO-text instruction analysis (the *real* code corpus).
//!
//! Every AOT configuration lowers to a distinct HLO module; this parser
//! extracts per-module instruction statistics so Fig. 5's methodology
//! runs on genuine compiler output.  HLO text instructions look like:
//!
//! ```text
//!   fusion.3 = f32[16,64]{1,0} fusion(p0, p1), kind=kLoop, ...
//!   while.1 = (s32[], f32[32,64]{1,0}) while(tuple.2), condition=...
//! ```
//!
//! The *opcode* is the token following the result type.  We count
//! opcode spellings (operands ignored), matching the paper's
//! "opcodes and prefixes without considering the operands".

use std::path::Path;

use super::CodeStats;
use crate::Result;

/// Extract the opcode from one HLO instruction line, if it is one.
fn opcode_of_line(line: &str) -> Option<&str> {
    let trimmed = line.trim_start().strip_prefix("ROOT ").unwrap_or(line.trim_start());
    // Instruction lines bind `name = type opcode(...)`; the name is a
    // single token (with or without the legacy % sigil).
    let (lhs, rhs) = trimmed.split_once(" = ")?;
    if lhs.contains(' ') || lhs.is_empty() {
        return None;
    }
    // rhs = "<type> <opcode>(..." where <type> may contain spaces only
    // inside tuple parens: "(s32[], f32[2]{0})". Skip the type by
    // tracking paren/brace depth until the top-level space.
    let mut depth = 0usize;
    let mut type_end = None;
    for (i, ch) in rhs.char_indices() {
        match ch {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth = depth.saturating_sub(1),
            ' ' if depth == 0 => {
                type_end = Some(i);
                break;
            }
            _ => {}
        }
    }
    let rest = &rhs[type_end?..].trim_start();
    let op_end = rest.find('(')?;
    let op = &rest[..op_end];
    (!op.is_empty() && op.chars().all(|c| c.is_alphanumeric() || c == '-' || c == '_')).then_some(op)
}

/// Statistics of one HLO-text module.
pub fn analyze_text(text: &str) -> CodeStats {
    super::stats_from_mnemonics(text.lines().filter_map(opcode_of_line), text.len())
}

/// Statistics of an HLO artifact file.
pub fn analyze_file(path: impl AsRef<Path>) -> Result<CodeStats> {
    let text = std::fs::read_to_string(path.as_ref())?;
    Ok(analyze_text(&text))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_fn, entry_computation_layout={(f32[2,2]{1,0})->(f32[2,2]{1,0})}

%region_0.7 (arg: f32[]) -> f32[] {
  %arg = f32[] parameter(0)
  ROOT %add.1 = f32[] add(%arg, %arg)
}

ENTRY %main.10 (Arg_0.1: f32[2,2]) -> (f32[2,2]) {
  %Arg_0.1 = f32[2,2]{1,0} parameter(0)
  %dot.3 = f32[2,2]{1,0} dot(%Arg_0.1, %Arg_0.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %constant.2 = f32[] constant(2)
  %broadcast.4 = f32[2,2]{1,0} broadcast(%constant.2), dimensions={}
  %add.5 = f32[2,2]{1,0} add(%dot.3, %broadcast.4)
  %tuple.9 = (s32[], f32[2,2]{1,0}) tuple(%constant.2, %add.5)
  ROOT %out = (f32[2,2]{1,0}) tuple(%add.5)
}
"#;

    #[test]
    fn parses_opcodes() {
        let s = analyze_text(SAMPLE);
        // parameter, add, dot, constant, broadcast, tuple
        assert_eq!(s.unique_instructions, 6);
        assert_eq!(s.total_instructions, 9);
    }

    #[test]
    fn parses_unsigiled_names() {
        // jax's as_hlo_text() emits names without the % sigil.
        assert_eq!(
            opcode_of_line("  dot.2 = f32[16,16]{1,0} dot(a, b), lhs_contracting_dims={1}"),
            Some("dot")
        );
        assert_eq!(
            opcode_of_line("  ROOT call.1 = s32[] call(and.1), to_apply=_where.1"),
            Some("call")
        );
        assert_eq!(opcode_of_line("_where.1 {"), None);
        assert_eq!(
            opcode_of_line("  get-tuple-element.24 = f32[16]{0} get-tuple-element(x), index=3"),
            Some("get-tuple-element")
        );
    }

    #[test]
    fn tuple_typed_results_are_handled() {
        assert_eq!(
            opcode_of_line("  %t = (s32[], f32[2]{0}) tuple(%a, %b)"),
            Some("tuple")
        );
    }

    #[test]
    fn non_instruction_lines_ignored() {
        assert_eq!(opcode_of_line("HloModule foo"), None);
        assert_eq!(opcode_of_line("ENTRY %main (x: f32[]) -> f32[] {"), None);
        assert_eq!(opcode_of_line("}"), None);
    }

    #[test]
    fn real_artifacts_if_present() {
        // Integration sanity when artifacts exist: every attention
        // artifact parses to a nontrivial module.
        let dir = crate::artifact_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = crate::runtime::Manifest::load(&dir).unwrap();
        let arts = m.kernel_artifacts("attention");
        assert!(!arts.is_empty());
        for a in arts.iter().take(3) {
            let s = analyze_file(dir.join(&a.path)).unwrap();
            assert!(s.total_instructions > 50, "{}: {s:?}", a.id);
            assert!(s.unique_instructions > 10);
        }
    }
}
