//! Generated-code analytics — the substrate for the paper's Fig. 5.
//!
//! The paper quantifies *code diversity* across autotuning configurations
//! by analyzing the PTX of all 450 Triton variants and of the 30 CUDA
//! templates: number of **unique instructions** (opcode + prefixes,
//! operands ignored), **total instructions**, and **binary size**.
//!
//! Our substitution (DESIGN.md §2) applies the identical methodology to
//! two corpora:
//!
//! - [`hlo`] — *real* analysis of the per-configuration HLO-text
//!   artifacts produced by the Pallas AOT path (HLO is our artifact ISA
//!   the way PTX was the paper's);
//! - [`ptx`] — a synthetic PTX emitter driven by the simulated platforms,
//!   reproducing the full 450-config sweep of Fig. 5a and the 30-template
//!   corpus of Fig. 5b.

pub mod hlo;
pub mod ptx;

use std::collections::BTreeSet;

/// Instruction-level statistics of one code artifact (Fig. 5 metrics).
#[derive(Debug, Clone, PartialEq)]
pub struct CodeStats {
    /// Unique instruction spellings (opcode + prefixes, no operands).
    pub unique_instructions: usize,
    /// Total instruction count.
    pub total_instructions: usize,
    /// Artifact size in bytes (cubin-size analog).
    pub bytes: usize,
}

/// Count instruction statistics from an iterator of instruction
/// mnemonics (already stripped of operands).
pub fn stats_from_mnemonics<'a>(mnemonics: impl Iterator<Item = &'a str>, bytes: usize) -> CodeStats {
    let mut unique: BTreeSet<&str> = BTreeSet::new();
    let mut total = 0usize;
    for m in mnemonics {
        total += 1;
        unique.insert(m);
    }
    CodeStats { unique_instructions: unique.len(), total_instructions: total, bytes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_count_unique_and_total() {
        let s = stats_from_mnemonics(["add", "add", "mul"].into_iter(), 10);
        assert_eq!(s.unique_instructions, 2);
        assert_eq!(s.total_instructions, 3);
        assert_eq!(s.bytes, 10);
    }
}
