//! Small statistics toolkit: summaries, percentiles, CDFs, histograms,
//! and per-device work counters.
//!
//! Used by the serving layer (latency percentiles), the experiment
//! harness (Fig. 3's cumulative distributions), and the multi-device
//! evaluator ([`DeviceUtil`] utilization accounting).

/// Streaming-ish summary of a sample set (stores the samples; the scales
/// here never exceed a few hundred thousand points).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    /// An empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one sample.
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    /// Add many samples.
    pub fn extend(&mut self, vs: impl IntoIterator<Item = f64>) {
        self.samples.extend(vs);
        self.sorted = false;
    }

    /// Number of recorded samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn sort(&mut self) {
        if !self.sorted {
            self.samples.sort_by(f64::total_cmp);
            self.sorted = true;
        }
    }

    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Smallest sample (+inf when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (-inf when empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.sort();
        let rank = (p / 100.0) * (self.samples.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    /// Median.
    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// 95th percentile.
    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    /// 99th percentile.
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// An empirical CDF over a sample set (Fig. 3's presentation).
#[derive(Debug, Clone)]
pub struct Cdf {
    sorted: Vec<f64>,
}

impl Cdf {
    /// Build the CDF of a sample set.
    pub fn new(mut samples: Vec<f64>) -> Self {
        samples.sort_by(f64::total_cmp);
        Cdf { sorted: samples }
    }

    /// P(X <= x).
    pub fn at(&self, x: f64) -> f64 {
        if self.sorted.is_empty() {
            return f64::NAN;
        }
        let idx = self.sorted.partition_point(|&v| v <= x);
        idx as f64 / self.sorted.len() as f64
    }

    /// Quantile q in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.sorted.is_empty() {
            return f64::NAN;
        }
        let idx = ((q * self.sorted.len() as f64).ceil() as usize).clamp(1, self.sorted.len());
        self.sorted[idx - 1]
    }

    /// Evenly spaced (x, F(x)) points for plotting/reporting.
    pub fn curve(&self, points: usize) -> Vec<(f64, f64)> {
        if self.sorted.is_empty() || points == 0 {
            return Vec::new();
        }
        let lo = self.sorted[0];
        let hi = *self.sorted.last().unwrap();
        (0..=points)
            .map(|i| {
                let x = lo + (hi - lo) * i as f64 / points as f64;
                (x, self.at(x))
            })
            .collect()
    }

    /// Number of underlying samples.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// True when the CDF was built from no samples.
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }
}

/// Work counters for one device of a sharded multi-device evaluator
/// (`autotuner::evaluators::MultiDeviceEvaluator`).
///
/// The evaluator updates these as it fans batch shards out; utilization
/// is the fraction of the fleet's wall-clock time this device spent
/// evaluating — a perfectly balanced fleet shows every device near 1.0,
/// while a skewed shard split (or a straggler device model) shows up as
/// low utilization on the idle devices.
#[derive(Debug, Clone, Default)]
pub struct DeviceUtil {
    /// Evaluator/platform name of the device.
    pub device: String,
    /// Configurations evaluated on this device.
    pub evaluated: usize,
    /// Batch shards this device has processed.
    pub shards: usize,
    /// Of [`DeviceUtil::evaluated`], how many were replicated
    /// measure-everywhere evaluations (fleet tuning measures each config
    /// once per *distinct platform*; sharded throughput mode measures it
    /// on exactly one device and leaves this at 0).
    pub replicated: usize,
    /// Cumulative time this device spent evaluating, µs.
    pub busy_us: f64,
}

impl DeviceUtil {
    /// Busy fraction of `wall_us` (total fleet wall-clock), clamped to
    /// [0, 1]; 0.0 when no wall time has elapsed.
    pub fn utilization(&self, wall_us: f64) -> f64 {
        if wall_us <= 0.0 {
            0.0
        } else {
            (self.busy_us / wall_us).clamp(0.0, 1.0)
        }
    }
}

/// Geometric mean (the right average for speedup ratios).
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    (values.iter().map(|v| v.ln()).sum::<f64>() / values.len() as f64).exp()
}

/// Median of `xs` (NaN when empty) — the outlier-robust aggregate
/// measurement paths use over per-iteration latency samples.  Unlike
/// the mean, a minority of spiked samples cannot move it at all: with
/// an odd sample count and fewer than half the samples spiked, the
/// median equals the clean value *bit-for-bit*, which is what lets a
/// single injected latency outlier never crown a wrong tuning variant.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let mid = sorted.len() / 2;
    if sorted.len() % 2 == 1 {
        sorted[mid]
    } else {
        (sorted[mid - 1] + sorted[mid]) / 2.0
    }
}

/// Fault-tolerance counters shared by the serving executor
/// ([`crate::serving::ExecutorStats`]), the router report
/// ([`crate::serving::ServeReport`]) and the chaos tests: how many
/// faults were injected, observed, retried away, quarantined, or shed.
///
/// All counts are cumulative over the owning component's lifetime.
/// `PartialEq` + `Debug` make the struct directly usable in the
/// bit-reproducibility assertions of the chaos test suite.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Faults injected by a fault-injection decorator
    /// ([`crate::serving::ChaosBackend`]); 0 on undecorated backends.
    pub injected: usize,
    /// Backend-call failures observed (every `Err`, including ones a
    /// retry later cleared).
    pub failures: usize,
    /// Retry attempts issued after a failure.
    pub retries: usize,
    /// Operations that succeeded after at least one retry.
    pub recovered: usize,
    /// Variant quarantine events (circuit breaker opened after K
    /// consecutive hard failures).
    pub quarantined: usize,
    /// Quarantined variants given their post-cooldown re-probe.
    pub reprobed: usize,
    /// Variants written off permanently (re-probe failed too).
    pub gave_up: usize,
    /// Request batches served by a fallback variant after the active
    /// variant failed to execute.
    pub fallbacks: usize,
    /// Requests shed with a typed error (no healthy variant, or queue
    /// saturation at the router).
    pub shed: usize,
}

impl FaultCounters {
    /// True when any counter is nonzero.
    pub fn any(&self) -> bool {
        *self != FaultCounters::default()
    }

    /// Add every counter of `other` into `self` — the rollup primitive
    /// the sharded serving plane uses to aggregate per-shard counters
    /// into one report-level set.
    pub fn absorb(&mut self, other: &FaultCounters) {
        self.injected += other.injected;
        self.failures += other.failures;
        self.retries += other.retries;
        self.recovered += other.recovered;
        self.quarantined += other.quarantined;
        self.reprobed += other.reprobed;
        self.gave_up += other.gave_up;
        self.fallbacks += other.fallbacks;
        self.shed += other.shed;
    }

    /// (label, value) rows for rendering counter tables.
    pub fn rows(&self) -> Vec<(&'static str, usize)> {
        vec![
            ("injected", self.injected),
            ("failures", self.failures),
            ("retries", self.retries),
            ("recovered", self.recovered),
            ("quarantined", self.quarantined),
            ("reprobed", self.reprobed),
            ("gave_up", self.gave_up),
            ("fallbacks", self.fallbacks),
            ("shed", self.shed),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_interpolate() {
        let mut s = Summary::new();
        s.extend([1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn summary_mean_min_max() {
        let mut s = Summary::new();
        s.extend([2.0, 4.0, 6.0]);
        assert_eq!(s.mean(), 4.0);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 6.0);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn cdf_basics() {
        let c = Cdf::new(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.at(0.5), 0.0);
        assert_eq!(c.at(2.0), 0.5);
        assert_eq!(c.at(10.0), 1.0);
        assert_eq!(c.quantile(0.5), 2.0);
    }

    #[test]
    fn cdf_curve_monotone() {
        let c = Cdf::new(vec![1.0, 5.0, 2.0, 8.0, 3.0]);
        let curve = c.curve(10);
        for win in curve.windows(2) {
            assert!(win[1].1 >= win[0].1);
        }
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[2.0, 0.5]) - 1.0).abs() < 1e-12);
        assert!((geomean(&[4.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn median_is_outlier_robust_and_bitwise_exact_when_odd() {
        // Odd count: the median IS one of the samples, bit for bit —
        // a minority of spiked samples cannot move it at all.
        let clean = 37.25f64;
        let spiked = [clean * 25.0, clean, clean];
        assert_eq!(median(&spiked).to_bits(), clean.to_bits());
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
        // Even count: mean of the two middle samples.
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
        assert_eq!(median(&[2.0]), 2.0);
        assert!(median(&[]).is_nan());
        // The mean, for contrast, is dragged by the same spike.
        let mean = spiked.iter().sum::<f64>() / 3.0;
        assert!(mean > clean * 5.0);
    }

    #[test]
    fn fault_counters_absorb_sums_every_field() {
        let mut a = FaultCounters {
            injected: 1,
            failures: 2,
            retries: 3,
            recovered: 4,
            quarantined: 5,
            reprobed: 6,
            gave_up: 7,
            fallbacks: 8,
            shed: 9,
        };
        let b = a.clone();
        a.absorb(&b);
        for ((_, doubled), (_, base)) in a.rows().into_iter().zip(b.rows()) {
            assert_eq!(doubled, base * 2);
        }
        let mut zero = FaultCounters::default();
        zero.absorb(&FaultCounters::default());
        assert!(!zero.any());
    }

    #[test]
    fn fault_counters_any_and_rows() {
        let mut f = FaultCounters::default();
        assert!(!f.any());
        f.retries = 2;
        assert!(f.any());
        let rows = f.rows();
        assert_eq!(rows.len(), 9);
        assert!(rows.contains(&("retries", 2)));
        assert!(rows.contains(&("injected", 0)));
    }

    #[test]
    fn device_util_fractions() {
        let u = DeviceUtil {
            device: "sim".into(),
            evaluated: 10,
            shards: 2,
            replicated: 0,
            busy_us: 50.0,
        };
        assert!((u.utilization(100.0) - 0.5).abs() < 1e-12);
        assert_eq!(u.utilization(0.0), 0.0);
        // Clock skew cannot push utilization above 1.
        assert_eq!(u.utilization(25.0), 1.0);
    }

    #[test]
    fn empty_is_nan_not_panic() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan());
        assert!(Cdf::new(vec![]).at(1.0).is_nan());
    }
}
