//! Report rendering: tables (markdown/TSV) and ASCII series plots.
//!
//! Every experiment produces a [`Report`]; the CLI prints it and
//! `portatune bench all` also writes the TSV form under `reports/` so the
//! paper's figures can be re-plotted from raw rows.

use std::fmt::Write as _;
use std::path::Path;

use crate::Result;

/// A titled table: the unit of experiment output.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Table heading.
    pub title: String,
    /// Free-form annotations rendered above the table.
    pub notes: Vec<String>,
    /// Column headers.
    pub columns: Vec<String>,
    /// Data rows; each must match the column arity.
    pub rows: Vec<Vec<String>>,
}

impl Report {
    /// A titled, empty table with the given columns.
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Report {
            title: title.into(),
            notes: Vec::new(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append an annotation line.
    pub fn note(&mut self, note: impl Into<String>) {
        self.notes.push(note.into());
    }

    /// Append one row (must match the column arity).
    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.columns.len(), "row arity mismatch in {}", self.title);
        self.rows.push(cells);
    }

    /// Markdown rendering (what the CLI prints).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "\n## {}\n", self.title);
        for n in &self.notes {
            let _ = writeln!(out, "> {n}");
        }
        if !self.notes.is_empty() {
            out.push('\n');
        }
        // column widths
        let mut w: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < w.len() {
                    w[i] = w[i].max(c.len());
                }
            }
        }
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = w.get(i).copied().unwrap_or(0)))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        let _ = writeln!(out, "{}", fmt_row(&self.columns, &w));
        let sep: Vec<String> = w.iter().map(|n| "-".repeat(*n)).collect();
        let _ = writeln!(out, "{}", fmt_row(&sep, &w));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &w));
        }
        out
    }

    /// Tab-separated values (machine-readable row dump).
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# {}", self.title);
        for n in &self.notes {
            let _ = writeln!(out, "# {n}");
        }
        let _ = writeln!(out, "{}", self.columns.join("\t"));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join("\t"));
        }
        out
    }

    /// Write the TSV form into `dir/<slug>.tsv`.
    pub fn save_tsv(&self, dir: impl AsRef<Path>, slug: &str) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{slug}.tsv")), self.to_tsv())?;
        Ok(())
    }
}

/// A quick ASCII scatter/line chart for terminal output of figure-style
/// series (log-y supported, since most paper plots are log scale).
pub fn ascii_chart(title: &str, series: &[(&str, Vec<(f64, f64)>)], log_y: bool, width: usize, height: usize) -> String {
    let mut out = format!("{title}\n");
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, pts)| pts.iter().copied()).collect();
    if all.is_empty() {
        return out + "(no data)\n";
    }
    let tx = |x: f64| x;
    let ty = |y: f64| if log_y { y.max(1e-12).log10() } else { y };
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        x0 = x0.min(tx(x));
        x1 = x1.max(tx(x));
        y0 = y0.min(ty(y));
        y1 = y1.max(ty(y));
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    for (si, (_, pts)) in series.iter().enumerate() {
        let m = marks[si % marks.len()];
        for &(x, y) in pts {
            let cx = (((tx(x) - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((ty(y) - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = m;
        }
    }
    for row in grid {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!("   x: [{x0:.3} .. {x1:.3}]  y{}: [{y0:.3} .. {y1:.3}]\n", if log_y { "(log10)" } else { "" }));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("   {} {}\n", marks[si % marks.len()], name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_has_all_rows() {
        let mut r = Report::new("T", &["a", "b"]);
        r.row(vec!["1".into(), "2".into()]);
        r.row(vec!["3".into(), "4".into()]);
        let md = r.to_markdown();
        assert!(md.contains("## T"));
        assert!(md.contains("| 1 | 2 |"));
        assert_eq!(md.matches('|').count() / 3, 4); // header, sep, 2 rows
    }

    #[test]
    fn tsv_roundtrip_columns() {
        let mut r = Report::new("T", &["x", "y"]);
        r.note("a note");
        r.row(vec!["1".into(), "2".into()]);
        let tsv = r.to_tsv();
        assert!(tsv.contains("# a note"));
        assert!(tsv.contains("x\ty"));
        assert!(tsv.contains("1\t2"));
    }

    #[test]
    fn chart_renders_without_panic() {
        let s = ascii_chart(
            "demo",
            &[("a", vec![(1.0, 10.0), (2.0, 100.0)]), ("b", vec![(1.5, 50.0)])],
            true,
            40,
            10,
        );
        assert!(s.contains('*') && s.contains('o'));
    }

    #[test]
    fn chart_empty_series_ok() {
        assert!(ascii_chart("e", &[("a", vec![])], false, 10, 5).contains("no data"));
    }
}
