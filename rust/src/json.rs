//! Minimal, dependency-free JSON: a full RFC-8259 parser and emitter.
//!
//! The offline build environment ships no `serde_json`, so this module is
//! the substrate behind the artifact manifest, the tuning cache and the
//! report dumps.  It parses into a dynamic [`Value`] tree; typed views
//! (e.g. [`crate::runtime::Manifest`]) are built on top with explicit
//! accessors, which keeps schema errors descriptive.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as `f64`, like JavaScript).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object; `BTreeMap` so emission order is deterministic.
    Obj(BTreeMap<String, Value>),
}

impl Value {
    // ---- typed accessors -------------------------------------------------

    /// The boolean value, if this is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The numeric value, if this is a [`Value::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value as a `usize`, if it is a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    /// The numeric value as an `i64`, if it is an integer.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    /// The string slice, if this is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The element slice, if this is a [`Value::Arr`].
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The field map, if this is a [`Value::Obj`].
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects/missing/null).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self.as_obj()?.get(key) {
            Some(Value::Null) | None => None,
            Some(v) => Some(v),
        }
    }

    /// Required field, with a descriptive error when absent.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
    }

    /// Required string field ([`Value::req`] + [`Value::as_str`]).
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("field {key:?} is not a string"))
    }

    /// Required non-negative integer field.
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow!("field {key:?} is not a usize"))
    }

    /// Required numeric field.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow!("field {key:?} is not a number"))
    }

    /// Required array field.
    pub fn req_arr(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow!("field {key:?} is not an array"))
    }

    // ---- builders --------------------------------------------------------

    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// Build a numeric value.
    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }

    // ---- emit ------------------------------------------------------------

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with `indent` spaces.
    pub fn pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(indent), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(n) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(n * d));
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Obj(map)),
                c => bail!("expected ',' or '}}' in object, got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Arr(arr)),
                c => bail!("expected ',' or ']' in array, got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00) & 0x3FF);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| anyhow!("invalid \\u escape"))?);
                    }
                    c => bail!("invalid escape \\{}", c as char),
                },
                c if c < 0x20 => bail!("raw control character in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump()?;
                        }
                        s.push_str(
                            std::str::from_utf8(&self.bytes[start..self.pos])
                                .map_err(|_| anyhow!("invalid utf-8 in string"))?,
                        );
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump()?;
            let d = (c as char).to_digit(16).ok_or_else(|| anyhow!("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for (txt, v) in [
            ("null", Value::Null),
            ("true", Value::Bool(true)),
            ("false", Value::Bool(false)),
            ("42", Value::Num(42.0)),
            ("-3.5", Value::Num(-3.5)),
            ("1e3", Value::Num(1000.0)),
            ("\"hi\"", Value::Str("hi".into())),
        ] {
            assert_eq!(parse(txt).unwrap(), v, "{txt}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": {"e": true}}"#;
        let v = parse(text).unwrap();
        let re = parse(&v.dump()).unwrap();
        assert_eq!(v, re);
        let rp = parse(&v.pretty(2)).unwrap();
        assert_eq!(v, rp);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        // surrogate pair: 😀
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
        // raw multibyte passthrough
        assert_eq!(parse("\"héllo😀\"").unwrap(), Value::Str("héllo😀".into()));
    }

    #[test]
    fn escapes_roundtrip_through_dump() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated", "{} extra"] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "x", "a": [1], "b": true, "nul": null}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_arr("a").unwrap().len(), 1);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.get("nul").is_none(), "null reads as absent");
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn integer_emission_is_exact() {
        assert_eq!(Value::Num(1048576.0).dump(), "1048576");
        assert_eq!(Value::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn deep_nesting_parses() {
        let mut s = String::new();
        for _ in 0..200 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..200 {
            s.push(']');
        }
        assert!(parse(&s).is_ok());
    }
}
