//! Workload descriptors: the *scenario* a kernel configuration is tuned for.
//!
//! The paper's central observation is that the optimal kernel configuration
//! depends on **both** the platform and the workload (tensor shapes, dtype,
//! batch size) — so workloads are first-class values, used as cache keys,
//! sweep axes, and inputs to the analytical cost models.
//!
//! [`SeqLenMix`] extends this to *distributions* of workloads: the
//! serving-plane scenario generator ([`crate::serving::loadgen`]) draws
//! per-request sequence lengths from a named mix, so traffic classes
//! ("interactive decode", "batch prefill") are first-class too.

use crate::util::rng::Rng;

/// Element type of kernel operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit IEEE float.
    F32,
    /// 16-bit IEEE half.
    F16,
    /// bfloat16.
    BF16,
}

impl DType {
    /// Bytes per element.
    pub fn bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 | DType::BF16 => 2,
        }
    }

    /// Lowercase type name (`f32`, `f16`, `bf16`).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A concrete kernel invocation scenario.
///
/// `Attention` follows the paper's Llama-3 geometry: `q_heads` query heads
/// sharing `kv_heads` KV heads (GQA), `seq_len` is the *maximum* sequence
/// length in the batch; actual per-sequence lengths are drawn by
/// [`crate::experiments::workload_gen`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    /// One (grouped-query) attention launch.
    Attention {
        /// Sequences in the batch.
        batch: usize,
        /// Query heads.
        q_heads: usize,
        /// KV heads (GQA: `q_heads / kv_heads` queries share a KV head).
        kv_heads: usize,
        /// Maximum sequence length in the batch.
        seq_len: usize,
        /// Per-head embedding dimension.
        head_dim: usize,
        /// Operand element type.
        dtype: DType,
        /// Causal (decoder) masking.
        causal: bool,
    },
    /// One RMS-norm launch over `n_rows` rows of width `hidden`.
    RmsNorm {
        /// Number of rows (tokens).
        n_rows: usize,
        /// Hidden dimension (row width).
        hidden: usize,
        /// Operand element type.
        dtype: DType,
    },
    /// One element-wise vector addition of length `n`.
    VectorAdd {
        /// Element count.
        n: usize,
        /// Operand element type.
        dtype: DType,
    },
}

impl Workload {
    /// The paper's primary workload: Llama-3.1-8B attention (128 head dim,
    /// 32 query heads, 8 KV heads) at a given batch size and seq length.
    pub fn llama3_attention(batch: usize, seq_len: usize) -> Self {
        Workload::Attention {
            batch,
            q_heads: 32,
            kv_heads: 8,
            seq_len,
            head_dim: 128,
            dtype: DType::F16,
            causal: true,
        }
    }

    /// RMS norm over the hidden states of Llama-3-8B for `batch` sequences
    /// of length `seq_len` (rows = tokens).
    pub fn llama3_rms(batch: usize, seq_len: usize) -> Self {
        Workload::RmsNorm {
            n_rows: batch * seq_len,
            hidden: 4096,
            dtype: DType::F16,
        }
    }

    /// Model FLOPs (useful work, not hardware-inflated).
    pub fn flops(&self) -> f64 {
        match *self {
            Workload::Attention {
                batch,
                q_heads,
                seq_len,
                head_dim,
                causal,
                ..
            } => {
                let full = 4.0 * batch as f64 * q_heads as f64 * (seq_len as f64).powi(2) * head_dim as f64;
                if causal {
                    full / 2.0
                } else {
                    full
                }
            }
            Workload::RmsNorm { n_rows, hidden, .. } => 3.0 * n_rows as f64 * hidden as f64,
            Workload::VectorAdd { n, .. } => n as f64,
        }
    }

    /// Minimum HBM traffic in bytes (the memory-roofline denominator):
    /// each operand read once, output written once.
    pub fn min_bytes(&self) -> f64 {
        match *self {
            Workload::Attention {
                batch,
                q_heads,
                kv_heads,
                seq_len,
                head_dim,
                dtype,
                ..
            } => {
                let q = (batch * q_heads * seq_len * head_dim) as f64;
                let kv = 2.0 * (batch * kv_heads * seq_len * head_dim) as f64;
                (2.0 * q + kv) * dtype.bytes() as f64
            }
            Workload::RmsNorm { n_rows, hidden, dtype } => {
                (2.0 * (n_rows * hidden) as f64 + hidden as f64) * dtype.bytes() as f64
            }
            Workload::VectorAdd { n, dtype } => 3.0 * n as f64 * dtype.bytes() as f64,
        }
    }

    /// Arithmetic intensity (FLOPs per byte of compulsory traffic).
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops() / self.min_bytes()
    }

    /// Resident KV-cache footprint (bytes) this workload pins in device
    /// memory while it is being served: K and V panels for every
    /// sequence in the batch (`batch × seq_len × kv_heads × head_dim × 2`
    /// elements).  Non-attention kernels hold no KV state.  The serving
    /// plane budgets its bucket grid against this (SNIPPETS.md §3's
    /// vLLM KV-cache-vs-graph memory tradeoff).
    pub fn kv_cache_bytes(&self) -> usize {
        match *self {
            Workload::Attention { batch, kv_heads, seq_len, head_dim, dtype, .. } => {
                batch * seq_len * kv_heads * head_dim * 2 * dtype.bytes()
            }
            Workload::RmsNorm { .. } | Workload::VectorAdd { .. } => 0,
        }
    }

    /// The operand element type.
    pub fn dtype(&self) -> DType {
        match *self {
            Workload::Attention { dtype, .. }
            | Workload::RmsNorm { dtype, .. }
            | Workload::VectorAdd { dtype, .. } => dtype,
        }
    }

    /// Stable string key for caches and file names, e.g.
    /// `attn_b64_h32kv8_s1024_d128_f16_causal`.
    pub fn key(&self) -> String {
        match *self {
            Workload::Attention {
                batch,
                q_heads,
                kv_heads,
                seq_len,
                head_dim,
                dtype,
                causal,
            } => format!(
                "attn_b{batch}_h{q_heads}kv{kv_heads}_s{seq_len}_d{head_dim}_{dtype}{}",
                if causal { "_causal" } else { "" }
            ),
            Workload::RmsNorm { n_rows, hidden, dtype } => {
                format!("rms_n{n_rows}_h{hidden}_{dtype}")
            }
            Workload::VectorAdd { n, dtype } => format!("vecadd_n{n}_{dtype}"),
        }
    }

    /// The kernel this workload exercises (manifest naming).
    pub fn kernel_name(&self) -> &'static str {
        match self {
            Workload::Attention { .. } => "attention",
            Workload::RmsNorm { .. } => "rms_norm",
            Workload::VectorAdd { .. } => "vector_add",
        }
    }
}

/// A named distribution of request sequence lengths — the workload-mix
/// axis of a serving scenario.
///
/// Mixes are sampled with the caller's seeded [`Rng`], so a scenario
/// trace is a pure function of its seed.  Samples are clamped to
/// `[MIN_TOKENS, max_tokens]`; the clamp floor keeps every request
/// inside the smallest serving bucket.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SeqLenMix {
    /// Long-prompt traffic: lengths cluster near `max_tokens`
    /// (summarization, RAG context stuffing) — the compute-bound end.
    PrefillHeavy,
    /// Short-prompt traffic: lengths cluster near a few dozen tokens
    /// (chat turns, tool calls) — the memory/launch-bound end.
    DecodeHeavy,
    /// Two populations: a `short_frac` fraction of decode-like requests
    /// plus a long-prompt remainder — the shape that stresses bucket
    /// policies hardest, because no single bucket fits the traffic.
    Bimodal {
        /// Fraction of requests drawn from the short mode, in [0, 1].
        short_frac: f64,
    },
    /// A generic log-normal: `median` tokens scaled by `exp(sigma · z)`
    /// for a standard normal `z` — the long-tailed shape real request
    /// logs show.  The legacy `synth_trace` distribution is
    /// `LogNormal { median: 48.0, sigma: 0.6 }`.
    LogNormal {
        /// Median of the distribution, tokens.
        median: f64,
        /// Log-space standard deviation.
        sigma: f64,
    },
}

impl SeqLenMix {
    /// Smallest sequence length any mix emits.
    pub const MIN_TOKENS: usize = 8;

    /// Draw one sequence length in `[MIN_TOKENS, max_tokens]`.
    pub fn sample(&self, rng: &mut Rng, max_tokens: usize) -> usize {
        let lognormal = |rng: &mut Rng, median: f64, sigma: f64| median * (sigma * rng.normal()).exp();
        let raw = match *self {
            SeqLenMix::PrefillHeavy => lognormal(rng, 0.7 * max_tokens as f64, 0.25),
            SeqLenMix::DecodeHeavy => lognormal(rng, 24.0, 0.5),
            SeqLenMix::Bimodal { short_frac } => {
                // One draw decides the mode, then one draw inside it —
                // a fixed number of RNG pulls per sample either way.
                if rng.f64() < short_frac {
                    lognormal(rng, 16.0, 0.3)
                } else {
                    lognormal(rng, 0.9 * max_tokens as f64, 0.1)
                }
            }
            SeqLenMix::LogNormal { median, sigma } => lognormal(rng, median, sigma),
        };
        raw.round().clamp(Self::MIN_TOKENS as f64, max_tokens as f64) as usize
    }

    /// Short human name for reports and the scenario catalog.
    pub fn name(&self) -> &'static str {
        match self {
            SeqLenMix::PrefillHeavy => "prefill-heavy",
            SeqLenMix::DecodeHeavy => "decode-heavy",
            SeqLenMix::Bimodal { .. } => "bimodal",
            SeqLenMix::LogNormal { .. } => "log-normal",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::F16.bytes(), 2);
        assert_eq!(DType::BF16.bytes(), 2);
    }

    #[test]
    fn llama3_attention_geometry() {
        let w = Workload::llama3_attention(64, 1024);
        match w {
            Workload::Attention { q_heads, kv_heads, head_dim, .. } => {
                assert_eq!((q_heads, kv_heads, head_dim), (32, 8, 128));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn causal_halves_flops() {
        let mk = |causal| Workload::Attention {
            batch: 2,
            q_heads: 4,
            kv_heads: 4,
            seq_len: 128,
            head_dim: 64,
            dtype: DType::F16,
            causal,
        };
        assert!((mk(true).flops() * 2.0 - mk(false).flops()).abs() < 1.0);
    }

    #[test]
    fn attention_is_compute_bound_at_scale() {
        // Flash attention at seq 1024 should have high arithmetic intensity
        // (that's why the naive baseline loses: it destroys this ratio).
        let w = Workload::llama3_attention(64, 1024);
        assert!(w.arithmetic_intensity() > 100.0);
    }

    #[test]
    fn rms_is_memory_bound() {
        let w = Workload::llama3_rms(64, 1024);
        assert!(w.arithmetic_intensity() < 2.0);
    }

    #[test]
    fn kv_cache_bytes_counts_k_and_v() {
        // 64 seqs x 1024 tokens x 8 KV heads x 128 dim x 2 (K+V) x 2 B.
        let w = Workload::llama3_attention(64, 1024);
        assert_eq!(w.kv_cache_bytes(), 64 * 1024 * 8 * 128 * 2 * 2);
        assert_eq!(Workload::llama3_rms(4, 128).kv_cache_bytes(), 0);
        assert_eq!(Workload::VectorAdd { n: 1 << 20, dtype: DType::F32 }.kv_cache_bytes(), 0);
    }

    #[test]
    fn keys_are_unique_per_shape() {
        let a = Workload::llama3_attention(1, 512).key();
        let b = Workload::llama3_attention(2, 512).key();
        assert_ne!(a, b);
        assert!(a.starts_with("attn_b1_"));
    }

    #[test]
    fn seq_len_mixes_are_clamped_and_shaped() {
        let max = 512;
        let mixes = [
            SeqLenMix::PrefillHeavy,
            SeqLenMix::DecodeHeavy,
            SeqLenMix::Bimodal { short_frac: 0.5 },
            SeqLenMix::LogNormal { median: 48.0, sigma: 0.6 },
        ];
        for mix in mixes {
            let mut rng = Rng::seed_from(9);
            let samples: Vec<usize> = (0..400).map(|_| mix.sample(&mut rng, max)).collect();
            assert!(samples.iter().all(|&t| (SeqLenMix::MIN_TOKENS..=max).contains(&t)), "{mix:?}");
        }
        // Prefill-heavy means long: its mean must dominate decode-heavy's.
        let mean = |mix: SeqLenMix| {
            let mut rng = Rng::seed_from(9);
            (0..400).map(|_| mix.sample(&mut rng, max)).sum::<usize>() as f64 / 400.0
        };
        assert!(mean(SeqLenMix::PrefillHeavy) > 4.0 * mean(SeqLenMix::DecodeHeavy));
    }

    #[test]
    fn seq_len_mix_is_deterministic_per_seed() {
        let mix = SeqLenMix::Bimodal { short_frac: 0.3 };
        let draw = |seed| {
            let mut rng = Rng::seed_from(seed);
            (0..64).map(|_| mix.sample(&mut rng, 512)).collect::<Vec<_>>()
        };
        assert_eq!(draw(7), draw(7));
        assert_ne!(draw(7), draw(8));
    }
}
