//! The executor thread: sole owner of the execution backend.
//!
//! The executor is generic over [`ExecBackend`]: the same thread loop
//! serves the analytical [`SimBackend`](super::backend::SimBackend)
//! (default builds — deterministic model latencies, no toolchain) and
//! the PJRT backend (feature `pjrt` — real artifact execution).  The
//! backend is **constructed inside the executor thread** via the
//! factory passed to [`ExecutorHandle::spawn`], which is what lets the
//! non-`Send` PJRT client live here without infecting the rest of the
//! serving plane.
//!
//! The thread serves [`ExecutorCommand`]s; **when idle it advances the
//! background tuning queue** — draining up to [`IDLE_TUNE_BATCH`]
//! pending variant measurements per idle slice, yielding immediately
//! when a request arrives — and hot-swaps a bucket's active kernel
//! variant when a faster one has been proven.  This is the paper's Q4.4
//! ("move autotuning off the critical path ... using idle GPU times")
//! made concrete, and since the backend split it runs (and is tested)
//! in every default build.
//!
//! Measurement inputs are the backend's business: before each idle
//! measurement the executor hints the next few queued shapes through
//! [`ExecBackend::prefetch`] (the PJRT backend pre-generates activation
//! tensors on the shared worker pool; the sim backend needs nothing)
//! and releases a shape's inputs once its queue entries are exhausted.
//!
//! Measurement bookkeeping goes through the autotuner's own
//! [`Recorder`] (one per bucket, fidelity 1.0), driven by the backend's
//! [`ExecBackend::measure`] call: winner selection is `Recorder::best`,
//! failed measurements are counted as invalid like any other
//! platform-rejected config, and the stats snapshot reads the recorder
//! instead of duplicating per-variant latency fields.
//!
//! **Fault tolerance.**  Every backend verb the executor drives goes
//! through [`retrying`] (exponential backoff on [`ExecBackend::backoff`],
//! so virtual-clock backends pay modeled time instead of sleeping).  A
//! per-(bucket, variant) circuit [`Breaker`] quarantines a variant after
//! [`QUARANTINE_AFTER`] consecutive hard tuning failures, re-probes it
//! once after [`QUARANTINE_COOLDOWN_TICKS`] tuning ticks, and writes it
//! off as dead when the re-probe also fails — a flaky variant cannot
//! poison idle tuning.  Write-offs persist through the [`TuningCache`]
//! (a `serving_dead_variants#<fingerprint>` entry per variant), so a
//! restarted server remembers dead variants instead of replaying the
//! whole quarantine ladder against them.  On the request path, an execute failure falls
//! back to the last-known-good variant (then the conservative default)
//! before the batch is shed with a typed [`ExecOutcome::Shed`] reply,
//! so an injected fault can degrade service but never panic the thread
//! or silently drop requests.  All of it is counted in
//! [`ExecutorStats::faults`].
//!
//! **Surrogate pre-ranking.**  When a persistent cache is attached, the
//! executor loads the platform's learned [`CostModel`]
//! ([`crate::surrogate`]) at boot and re-orders each bucket's queued
//! variant measurements best-predicted-first, so the earliest idle
//! slices measure the likely winners.  Every completed bucket folds its
//! full-fidelity measurements back into the model (online refit), the
//! refreshed coefficients are persisted through the cache under the
//! `surrogate_model#...` namespace, and the remaining queue is
//! re-ranked — each finished bucket improves the next bucket's ranking.
//! Winner selection is unchanged: a bucket still activates only after
//! *all* its variants are measured, so pre-ranking shifts measurement
//! *order*, never the final argmin.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use super::backend::{ExecBackend, ExecHandle, VariantDesc};
use super::batcher::Batch;
use super::{Completion, Request};
use crate::autotuner::search::Recorder;
use crate::cache::{entry_now, TuningCache};
use crate::config::Config;
use crate::metrics::FaultCounters;
use crate::platform::model::InvalidConfig;
use crate::surrogate::CostModel;
use crate::workload::Workload;
use crate::Result;

pub use super::backend::ShapeKey;

/// How many pending tuning measurements one idle slice may drain.
/// Batching amortizes the idle-detection timeout across several
/// measurements (the queue empties ~4x faster under bursty traffic);
/// the drain polls the command queue between measurements so request
/// latency never waits on more than one in-flight measurement.
pub const IDLE_TUNE_BATCH: usize = 4;

/// Retries after a failed backend call (so up to `MAX_RETRIES + 1`
/// attempts total).  At a 12.5% per-attempt transient-fault rate the
/// residual hard-failure probability is 0.125⁴ ≈ 2.4e-4 — low enough
/// that chaos smoke runs at `--fault-rate 0.1` ride out their faults.
pub const MAX_RETRIES: usize = 3;

/// First retry backoff (µs); doubles per retry.  Paid through
/// [`ExecBackend::backoff`], so sim runs charge the virtual clock.
pub const BACKOFF_BASE_US: f64 = 200.0;

/// Consecutive hard tuning failures before a variant is quarantined.
pub const QUARANTINE_AFTER: u32 = 3;

/// Tuning ticks a quarantined variant sits out before its one re-probe.
pub const QUARANTINE_COOLDOWN_TICKS: u64 = 16;

/// Reply to an [`ExecutorCommand::Execute`].
pub enum ExecOutcome {
    /// The batch executed; per-request completions.
    Done(Vec<Completion>),
    /// The batch could not be served even after retries and fallback:
    /// the requests come back with a typed reason so the router sheds
    /// them gracefully instead of blocking or silently dropping them.
    Shed {
        /// The unserved requests, handed back to the caller.
        requests: Vec<Request>,
        /// Why the batch could not be served.
        reason: String,
    },
}

/// Commands accepted by the executor thread.
pub enum ExecutorCommand {
    /// Run one batch; reply with per-request completions (or a typed
    /// shed when the bucket has no healthy variant).
    Execute { batch: Batch, enqueued_at: Instant, reply: Sender<ExecOutcome> },
    /// Snapshot statistics.
    Stats { reply: Sender<ExecutorStats> },
    /// Flush: measure every pending tuning item *now* (used by examples
    /// to show the "after tuning" steady state without idling).
    FinishTuning { reply: Sender<()> },
    /// Stop the executor thread.
    Shutdown,
}

/// One kernel-config variant of a compiled model shape.  Measurement
/// results are NOT stored here: each bucket's measurements live in its
/// [`Recorder`] — the same fidelity-correct log every autotuner
/// strategy records through — so winner selection, gain computation and
/// the stats snapshot all read one source of truth instead of ad-hoc
/// per-variant fields.
struct Variant {
    desc: VariantDesc,
    /// Backend-issued executable handle, compiled lazily.
    handle: Option<ExecHandle>,
}

/// A record of the executor swapping a bucket's active variant.
#[derive(Debug, Clone)]
pub struct SwapEvent {
    /// The (batch, seq) bucket whose variant changed.
    pub shape: ShapeKey,
    /// Previous active artifact id.
    pub from: String,
    /// New active artifact id.
    pub to: String,
    /// measured latency ratio old/new (>1 = improvement).
    pub gain: f64,
}

/// Executor statistics snapshot.
#[derive(Debug, Clone, Default)]
pub struct ExecutorStats {
    /// Buckets whose active variant came from the persistent cache at
    /// startup (warm start; no cold tuning needed).
    pub warm_started: usize,
    /// Batches executed on the request path.
    pub batches_executed: usize,
    /// Requests served across all batches.
    pub requests_served: usize,
    /// Background tuning measurements performed.
    pub variants_measured: usize,
    /// Variant compiles (request path + tuning).
    pub compiles: usize,
    /// Every variant hot-swap, in order.
    pub swaps: Vec<SwapEvent>,
    /// shape -> active artifact id.
    pub active: HashMap<String, String>,
    /// shape -> measured latency of active variant (µs).
    pub active_us: HashMap<String, f64>,
    /// Fault-tolerance counters: injected faults (when the backend is a
    /// chaos decorator), failures, retries, quarantines, sheds.
    pub faults: FaultCounters,
    /// Backend virtual-clock reading at snapshot time (µs): total
    /// modeled compile/execute/measure/backoff time.  0.0 on wall-clock
    /// backends.  Sharded reports difference two snapshots of this to
    /// get a shard's deterministic busy time for a replay.
    pub clock_us: f64,
}

impl ExecutorStats {
    /// Fold another executor's snapshot into this one — the per-shard →
    /// aggregate rollup.  Numeric counters and the virtual clock sum,
    /// swap logs concatenate (callers absorb shards in index order, so
    /// the merged log is deterministic), and the per-bucket active maps
    /// merge (shards of one backend converge to the same winners, so
    /// later shards overwriting earlier ones is the intended "one
    /// answer per bucket" view).
    pub fn absorb(&mut self, other: &ExecutorStats) {
        self.warm_started += other.warm_started;
        self.batches_executed += other.batches_executed;
        self.requests_served += other.requests_served;
        self.variants_measured += other.variants_measured;
        self.compiles += other.compiles;
        self.swaps.extend(other.swaps.iter().cloned());
        for (k, v) in &other.active {
            self.active.insert(k.clone(), v.clone());
        }
        for (k, v) in &other.active_us {
            self.active_us.insert(k.clone(), *v);
        }
        self.faults.absorb(&other.faults);
        self.clock_us += other.clock_us;
    }
}

/// Run `op` with retry-and-exponential-backoff, folding the attempt
/// outcomes into `faults`.  Backoff goes through
/// [`ExecBackend::backoff`], so virtual-clock backends (sim) charge
/// modeled µs and fault-injection tests stay instant.
fn retrying<B: ExecBackend, T>(
    backend: &mut B,
    faults: &mut FaultCounters,
    mut op: impl FnMut(&mut B) -> Result<T>,
) -> Result<T> {
    let mut attempt = 0usize;
    loop {
        match op(backend) {
            Ok(v) => {
                if attempt > 0 {
                    faults.recovered += 1;
                }
                return Ok(v);
            }
            Err(e) => {
                faults.failures += 1;
                if attempt >= MAX_RETRIES {
                    return Err(e);
                }
                backend.backoff(BACKOFF_BASE_US * (1u64 << attempt) as f64);
                faults.retries += 1;
                attempt += 1;
            }
        }
    }
}

/// Circuit-breaker state of one (bucket, variant) tuning candidate.
///
/// Lifecycle: hard failures (a whole [`retrying`] loop exhausted) bump
/// `streak`; at [`QUARANTINE_AFTER`] the variant is quarantined for
/// [`QUARANTINE_COOLDOWN_TICKS`] tuning ticks, then re-probed exactly
/// once; a failed re-probe marks it `dead` and records it invalid so
/// the bucket can still activate its best healthy variant.  Any
/// successful measurement clears the breaker entirely.
#[derive(Debug, Clone, Copy, Default)]
struct Breaker {
    /// Consecutive hard tuning failures.
    streak: u32,
    /// Quarantined until this tuning tick (cooldown), if open.
    quarantined_until: Option<u64>,
    /// Has the post-cooldown re-probe been spent?
    reprobed: bool,
    /// Written off permanently (re-probe failed too).
    dead: bool,
}

struct ExecutorState<B: ExecBackend> {
    backend: B,
    variants: HashMap<ShapeKey, Vec<Variant>>,
    active: HashMap<ShapeKey, usize>,
    tune_queue: Vec<(ShapeKey, usize)>,
    /// Per-bucket measurement log (the autotuner's [`Recorder`], at
    /// fidelity 1.0): `best()` picks the winner, failed measurements
    /// count as invalid instead of silently blocking the bucket.
    bucket_recs: HashMap<ShapeKey, Recorder<'static>>,
    stats: ExecutorStats,
    /// Measurement effort for background tuning.
    tune_warmup: usize,
    tune_iters: usize,
    /// Persistent tuning cache (Q4.3): bucket winners survive restarts,
    /// so a re-deployed server starts warm.
    cache: Option<TuningCache>,
    /// Circuit breakers, one per (bucket, variant) that has hard-failed.
    breaker: HashMap<(ShapeKey, usize), Breaker>,
    /// Last variant that successfully executed per bucket — the
    /// fallback target when the active variant fails on the request
    /// path.
    last_good: HashMap<ShapeKey, usize>,
    /// Tuning tick counter (one per [`ExecutorState::tune_step`] call)
    /// — the clock quarantine cooldowns are measured on.
    tick: u64,
    /// Learned cost model for this platform's serving kernel — loaded
    /// from the cache at boot and refit after every completed bucket —
    /// used to pre-rank the tuning queue so idle measurements go to the
    /// best-predicted variants first.  `None` without a cache or until
    /// enough training data accumulates.
    surrogate: Option<CostModel>,
    /// Accumulated full-fidelity (config, bucket workload, µs) triples
    /// behind the online refit.  [`CostModel::fit`] canonicalizes and
    /// deduplicates, so accumulation order never changes coefficients.
    surrogate_train: Vec<(Config, Workload, f64)>,
}

impl<B: ExecBackend> ExecutorState<B> {
    const CACHE_SPACE: &'static str = "serving_model_variants";

    /// Cache-space prefix for written-off variants (see
    /// [`ExecutorState::dead_space`]).
    const DEAD_SPACE_PREFIX: &'static str = "serving_dead_variants";

    /// Cache-space string of one written-off variant.  The config
    /// fingerprint is baked into the space so each (bucket workload,
    /// variant) pair gets its own exact-match cache key — the winner
    /// namespace ([`ExecutorState::CACHE_SPACE`]) holds one entry per
    /// bucket, but every variant of a bucket can independently be dead.
    fn dead_space(cfg: &Config) -> String {
        format!("{}#{:016x}", Self::DEAD_SPACE_PREFIX, cfg.fingerprint())
    }

    fn new(mut backend: B, cache: Option<TuningCache>) -> Result<Self> {
        // Discovery is retried like every other backend verb: a
        // transient fault at boot must not kill the server.
        let mut faults = FaultCounters::default();
        let universe = retrying(&mut backend, &mut faults, |b| b.discover())?;
        let mut variants: HashMap<ShapeKey, Vec<Variant>> = HashMap::new();
        for (shape, descs) in universe {
            variants
                .entry(shape)
                .or_default()
                .extend(descs.into_iter().map(|desc| Variant { desc, handle: None }));
        }
        let tune_queue: Vec<(ShapeKey, usize)> = variants
            .iter()
            .flat_map(|(k, vs)| (0..vs.len()).map(move |i| (*k, i)))
            .collect();
        let active = variants.keys().map(|k| (*k, 0)).collect();
        let mut state = ExecutorState {
            backend,
            variants,
            active,
            tune_queue,
            bucket_recs: HashMap::new(),
            stats: ExecutorStats { faults, ..ExecutorStats::default() },
            tune_warmup: 1,
            tune_iters: 3,
            cache,
            breaker: HashMap::new(),
            last_good: HashMap::new(),
            tick: 0,
            surrogate: None,
            surrogate_train: Vec::new(),
        };
        state.warm_start_from_cache();
        state.restore_dead_variants();
        state.load_surrogate();
        state.rank_tune_queue();
        Ok(state)
    }

    /// Adopt a persisted cost model for this (platform, kernel), if the
    /// cache holds one with a matching version — the serving twin of
    /// the winner warm start, but for measurement *order* instead of
    /// the active variant.
    fn load_surrogate(&mut self) {
        let Some(cache) = &self.cache else { return };
        let platform = self.backend.platform();
        let Some(shape) = self.variants.keys().min().copied() else { return };
        let kernel = self.backend.bucket_workload(shape).kernel_name();
        self.surrogate = CostModel::load(cache, &platform, kernel);
    }

    /// Re-order the pending tuning queue with the surrogate: buckets
    /// keep their first-appearance order (and their entries stay
    /// contiguous), but within a bucket the best-predicted variant is
    /// measured first.  `tune_queue.pop()` takes from the *back*, so a
    /// bucket's run is sorted worst-predicted-first — the surrogate's
    /// favorite sits last and is popped next.  Deterministic: ties
    /// break toward the lower variant index measuring first.  A no-op
    /// without a model, and winner selection is unaffected either way
    /// (activation waits for the full bucket).
    fn rank_tune_queue(&mut self) {
        let Some(model) = self.surrogate.clone() else { return };
        if self.tune_queue.is_empty() {
            return;
        }
        let mut order: Vec<ShapeKey> = Vec::new();
        let mut groups: HashMap<ShapeKey, Vec<usize>> = HashMap::new();
        for &(key, idx) in &self.tune_queue {
            if !groups.contains_key(&key) {
                order.push(key);
            }
            groups.entry(key).or_default().push(idx);
        }
        let mut ranked: Vec<(ShapeKey, usize)> = Vec::with_capacity(self.tune_queue.len());
        for key in order {
            let w = self.backend.bucket_workload(key);
            let Some(idxs) = groups.remove(&key) else { continue };
            let mut scored: Vec<(f64, usize)> = idxs
                .into_iter()
                .map(|i| {
                    let p = self
                        .variants
                        .get(&key)
                        .and_then(|vs| vs.get(i))
                        .map(|v| model.predict_us(&v.desc.config, &w))
                        .unwrap_or(f64::INFINITY);
                    (p, i)
                })
                .collect();
            scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(b.1.cmp(&a.1)));
            ranked.extend(scored.into_iter().map(|(_, i)| (key, i)));
        }
        self.tune_queue = ranked;
    }

    /// Online refit (each completed bucket improves the next bucket's
    /// ranking): fold `key`'s full-fidelity measurements into the
    /// training set, refit the cost model, persist the coefficients
    /// through the cache, and re-rank what's left of the tuning queue.
    /// Gated on the cache — without persistence there is nothing to
    /// warm-start from, and ephemeral runs stay byte-for-byte as before.
    fn refit_surrogate(&mut self, key: ShapeKey) {
        if self.cache.is_none() {
            return;
        }
        let w = self.backend.bucket_workload(key);
        let pairs: Vec<(Config, Workload, f64)> = {
            let Some(vs) = self.variants.get(&key) else { return };
            let Some(rec) = self.bucket_recs.get(&key) else { return };
            let latencies = rec.full_fidelity_latencies();
            vs.iter()
                .filter_map(|v| {
                    latencies
                        .get(&v.desc.config.fingerprint())
                        .map(|us| (v.desc.config.clone(), w, *us))
                })
                .collect()
        };
        for p in pairs {
            let dup = self
                .surrogate_train
                .iter()
                .any(|(c, tw, _)| tw.key() == p.1.key() && c.fingerprint() == p.0.fingerprint());
            if !dup {
                self.surrogate_train.push(p);
            }
        }
        let platform = self.backend.platform();
        let Some(model) =
            CostModel::fit(&platform, &self.surrogate_train, crate::surrogate::RIDGE_LAMBDA)
        else {
            return;
        };
        if let Some(cache) = &mut self.cache {
            model.save(cache);
            let _ = cache.save();
        }
        self.surrogate = Some(model);
        self.rank_tune_queue();
    }

    /// Warm start: adopt cached per-bucket winners before any tuning.
    fn warm_start_from_cache(&mut self) {
        let Some(cache) = &self.cache else { return };
        let platform = self.backend.platform();
        let keys: Vec<ShapeKey> = self.variants.keys().copied().collect();
        // Only buckets whose cached winner was actually *adopted* skip
        // tuning: a cache entry whose config is absent from this
        // session's variant universe (regenerated manifest, different
        // sim seed) must still be tuned — and its stale entry
        // overwritten — or the bucket would serve the default forever.
        let mut warmed: std::collections::HashSet<ShapeKey> = std::collections::HashSet::new();
        for key in keys {
            let w = self.backend.bucket_workload(key);
            let Some(hit) = cache.get(&w, &platform, Self::CACHE_SPACE) else { continue };
            let Some(cfg) = hit.config() else { continue };
            if let Some(idx) = self.variants[&key].iter().position(|v| v.desc.config == cfg) {
                self.active.insert(key, idx);
                warmed.insert(key);
            }
        }
        if !warmed.is_empty() {
            self.stats.warm_started = warmed.len();
            // Nothing left to prove for adopted buckets this session.
            self.tune_queue.retain(|(k, _)| !warmed.contains(k));
        }
    }

    /// Warm start for *failures*: re-adopt variants a previous session
    /// wrote off as dead, so a restarted server never spends its whole
    /// quarantine ladder (re-probes included) re-discovering a variant
    /// that is known broken.  Restored variants are marked dead in the
    /// breaker, dropped from the tuning queue, and recorded invalid in
    /// the bucket recorder — so [`ExecutorState::try_activate`] still
    /// sees the bucket as fully measured and activates its best healthy
    /// variant.
    fn restore_dead_variants(&mut self) {
        let Some(cache) = &self.cache else { return };
        let platform = self.backend.platform();
        let mut dead: Vec<(ShapeKey, usize)> = Vec::new();
        for (key, vs) in &self.variants {
            let w = self.backend.bucket_workload(*key);
            for (idx, v) in vs.iter().enumerate() {
                let space = Self::dead_space(&v.desc.config);
                if cache.get(&w, &platform, &space).is_some() {
                    dead.push((*key, idx));
                }
            }
        }
        for &(key, idx) in &dead {
            self.breaker.insert(
                (key, idx),
                Breaker {
                    streak: QUARANTINE_AFTER,
                    quarantined_until: None,
                    reprobed: true,
                    dead: true,
                },
            );
            self.tune_queue.retain(|&(k, i)| (k, i) != (key, idx));
            self.record_measurement(
                key,
                idx,
                Err(anyhow::anyhow!("written off as dead in a previous session")),
            );
        }
    }

    /// Persist a written-off variant so the *next* session skips it
    /// (the fault-tolerance twin of [`ExecutorState::persist_winner`]).
    fn persist_dead_variant(&mut self, key: ShapeKey, idx: usize) {
        let Some(cfg) = self.variants.get(&key).and_then(|vs| vs.get(idx)).map(|v| v.desc.config.clone())
        else {
            return;
        };
        let w = self.backend.bucket_workload(key);
        let platform = self.backend.platform();
        if let Some(cache) = &mut self.cache {
            cache.put(&w, entry_now(&cfg, 0.0, 0, 1, &platform, &Self::dead_space(&cfg), 0.0));
            let _ = cache.save();
        }
    }

    /// Persist a freshly proven bucket winner (Q4.3).
    fn persist_winner(&mut self, key: ShapeKey, idx: usize, measured_us: f64, evaluated: usize) {
        let w = self.backend.bucket_workload(key);
        let platform = self.backend.platform();
        let Some(cfg) = self.variants.get(&key).and_then(|vs| vs.get(idx)).map(|v| v.desc.config.clone())
        else {
            return;
        };
        if let Some(cache) = &mut self.cache {
            cache.put(
                &w,
                entry_now(&cfg, measured_us, evaluated, 0, &platform, Self::CACHE_SPACE, 0.0),
            );
            let _ = cache.save();
        }
    }

    fn shapes(&self) -> Vec<ShapeKey> {
        let mut v: Vec<ShapeKey> = self.variants.keys().copied().collect();
        v.sort();
        v
    }

    /// Lazily compile one variant through the backend (with retry for
    /// transient compile faults), memoizing the handle (the backend is
    /// guaranteed at most one *successful* compile per (shape, variant)).
    fn ensure_compiled(&mut self, key: ShapeKey, idx: usize) -> Result<ExecHandle> {
        let v = self
            .variants
            .get(&key)
            .and_then(|vs| vs.get(idx))
            .ok_or_else(|| anyhow::anyhow!("no variant {idx} for shape {key:?}"))?;
        if let Some(h) = v.handle {
            return Ok(h);
        }
        let desc = v.desc.clone();
        let h = retrying(&mut self.backend, &mut self.stats.faults, |b| b.compile(key, &desc))?;
        if let Some(slot) = self.variants.get_mut(&key).and_then(|vs| vs.get_mut(idx)) {
            slot.handle = Some(h);
        }
        self.stats.compiles += 1;
        Ok(h)
    }

    /// Compile-if-needed and execute one variant with retries; a
    /// success marks the variant last-known-good for its bucket.
    fn try_execute_variant(&mut self, key: ShapeKey, idx: usize) -> Result<f64> {
        let handle = self.ensure_compiled(key, idx)?;
        let us = retrying(&mut self.backend, &mut self.stats.faults, |b| b.execute(handle, key))?;
        self.last_good.insert(key, idx);
        Ok(us)
    }

    /// The variant to fall back to when `failed` cannot execute:
    /// last-known-good, else the conservative default (index 0), else
    /// the first variant not written off by its circuit breaker.
    fn fallback_variant(&self, key: ShapeKey, failed: usize) -> Option<usize> {
        let n = self.variants.get(&key)?.len();
        let healthy = |i: usize| {
            i != failed && i < n && !self.breaker.get(&(key, i)).map_or(false, |b| b.dead)
        };
        if let Some(&lg) = self.last_good.get(&key) {
            if healthy(lg) {
                return Some(lg);
            }
        }
        if healthy(0) {
            return Some(0);
        }
        (0..n).find(|&i| healthy(i))
    }

    fn execute(&mut self, batch: &Batch, enqueued_at: Instant) -> Result<Vec<Completion>> {
        let key = (batch.batch_shape, batch.seq_len);
        let idx = *self.active.get(&key).ok_or_else(|| anyhow::anyhow!("no variant for shape {key:?}"))?;
        let (exec_us, served) = match self.try_execute_variant(key, idx) {
            Ok(us) => (us, idx),
            Err(e) => {
                // Graceful degradation: try the last-known-good variant
                // before giving the batch up to the router as shed.
                let Some(fb) = self.fallback_variant(key, idx) else {
                    return Err(anyhow::anyhow!(
                        "bucket b{}s{}: active variant failed ({e}); no healthy fallback variant",
                        key.0,
                        key.1
                    ));
                };
                self.stats.faults.fallbacks += 1;
                match self.try_execute_variant(key, fb) {
                    Ok(us) => {
                        // Demote: keep serving what works.
                        self.active.insert(key, fb);
                        (us, fb)
                    }
                    Err(e2) => {
                        return Err(anyhow::anyhow!(
                            "bucket b{}s{}: active variant failed ({e}); fallback failed too ({e2})",
                            key.0,
                            key.1
                        ));
                    }
                }
            }
        };
        let latency_us = enqueued_at.elapsed().as_secs_f64() * 1e6;
        self.stats.batches_executed += 1;
        self.stats.requests_served += batch.requests.len();
        let artifact_id = self
            .variants
            .get(&key)
            .and_then(|vs| vs.get(served))
            .map(|v| v.desc.artifact_id.clone())
            .unwrap_or_default();
        Ok(batch
            .requests
            .iter()
            .map(|r| Completion {
                id: r.id,
                tokens: r.tokens,
                bucket_seq: batch.seq_len,
                batch_size: batch.batch_shape,
                latency_us,
                exec_us,
                variant: artifact_id.clone(),
            })
            .collect())
    }

    /// Fold one measurement result (success or failure) into the
    /// bucket's recorder and activate the winner if the bucket is now
    /// fully measured.  Recording failures as invalid — the same way
    /// every autotuner strategy counts invalid configs — is what lets a
    /// bucket with one broken variant still activate its best working
    /// one (previously a single failed measurement blocked the bucket's
    /// swap forever).
    fn record_measurement(&mut self, key: ShapeKey, idx: usize, res: Result<f64>) {
        let Some(cfg) = self.variants.get(&key).and_then(|vs| vs.get(idx)).map(|v| v.desc.config.clone())
        else {
            return;
        };
        let res = res.map_err(|e| InvalidConfig { reason: e.to_string() });
        if res.is_ok() {
            self.stats.variants_measured += 1;
        }
        self.bucket_recs.entry(key).or_default().record(&cfg, res, 1.0);
        self.try_activate(key);
    }

    /// If every variant of `key`'s bucket has been measured (or failed),
    /// activate the fastest valid variant, record the swap, and persist
    /// the winner to the tuning cache (Q4.3).
    fn try_activate(&mut self, key: ShapeKey) {
        let Some(vs) = self.variants.get(&key) else { return };
        let Some(rec) = self.bucket_recs.get(&key) else { return };
        if rec.len() < vs.len() {
            return; // bucket not fully measured yet
        }
        let Some((best_cfg, best_us)) = rec.best() else {
            return; // every variant failed to measure: nothing to swap
        };
        let latencies = rec.full_fidelity_latencies();
        let Some(best) = vs.iter().position(|v| v.desc.config == best_cfg) else { return };
        let cur = self.active.get(&key).copied().unwrap_or(0);
        if best != cur {
            // Gain versus the incumbent; infinite headroom when the
            // incumbent itself failed to measure.
            let gain = vs
                .get(cur)
                .and_then(|v| latencies.get(&v.desc.config.fingerprint()))
                .map(|c| c / best_us)
                .unwrap_or(f64::INFINITY);
            self.stats.swaps.push(SwapEvent {
                shape: key,
                from: vs.get(cur).map(|v| v.desc.artifact_id.clone()).unwrap_or_default(),
                to: vs[best].desc.artifact_id.clone(),
                gain,
            });
            self.active.insert(key, best);
        }
        let shape_name = format!("b{}s{}", key.0, key.1);
        let (best_id, n) = (vs[best].desc.artifact_id.clone(), vs.len());
        self.stats.active.insert(shape_name.clone(), best_id);
        self.stats.active_us.insert(shape_name, best_us);
        self.persist_winner(key, best, best_us, n);
        self.refit_surrogate(key);
    }

    /// Run ONE background tuning measurement. Returns false when the
    /// queue is exhausted.
    fn tune_step(&mut self) -> bool {
        // Quarantine cooldowns are measured on this tick clock, so they
        // elapse the same way under idle tuning and `finish_tuning`.
        self.tick += 1;
        // Hint the backend about the next few queued shapes so it can
        // prepare measurement inputs off the critical path
        // (`tune_queue.pop()` takes from the back, so the *next* items
        // are the tail).
        let mut upcoming: Vec<ShapeKey> = Vec::new();
        for (key, _) in self.tune_queue.iter().rev().take(IDLE_TUNE_BATCH) {
            if !upcoming.contains(key) {
                upcoming.push(*key);
            }
        }
        if !upcoming.is_empty() {
            self.backend.prefetch(&upcoming);
        }
        let Some((key, idx)) = self.tune_queue.pop() else {
            // Queue drained: memoized measurement inputs have nothing
            // left to serve.
            self.backend.release_all();
            return false;
        };
        // Circuit breaker: a quarantined variant waits out its cooldown
        // (deferred to the queue front), then gets exactly one re-probe.
        if let Some(b) = self.breaker.get_mut(&(key, idx)) {
            if let Some(until) = b.quarantined_until {
                if self.tick < until {
                    self.tune_queue.insert(0, (key, idx));
                    return true;
                }
                b.quarantined_until = None;
                b.reprobed = true;
                self.stats.faults.reprobed += 1;
            }
        }
        let attempt = match self.ensure_compiled(key, idx) {
            Ok(handle) => {
                let (warmup, iters) = (self.tune_warmup, self.tune_iters);
                retrying(&mut self.backend, &mut self.stats.faults, |b| {
                    b.measure(handle, key, warmup, iters)
                })
            }
            Err(e) if self.breaker.get(&(key, idx)).map_or(true, |b| !b.reprobed) => {
                // Uncompilable variant (platform rejection, or an
                // injected persistent compile failure — transients were
                // already retried): record it invalid right away so the
                // bucket can still complete, keep tuning.
                self.breaker.remove(&(key, idx));
                self.record_measurement(key, idx, Err(e));
                if !self.tune_queue.iter().any(|(k, _)| *k == key) {
                    self.backend.release(key);
                }
                return true;
            }
            Err(e) => Err(e),
        };
        match attempt {
            Ok(us) => {
                // Any success resets the breaker completely.
                self.breaker.remove(&(key, idx));
                self.record_measurement(key, idx, Ok(us));
            }
            Err(e) => self.note_tune_failure(key, idx, e),
        }
        // Drop the shape's memoized inputs once it has no queued
        // measurements left (the backend clears everything on
        // exhaustion).
        if !self.tune_queue.iter().any(|(k, _)| *k == key) {
            self.backend.release(key);
        }
        true
    }

    /// A tuning measurement hard-failed (retries exhausted): advance
    /// the variant's circuit breaker.  Below [`QUARANTINE_AFTER`] the
    /// variant is simply re-queued; at the threshold it is quarantined
    /// for a cooldown; a failed re-probe writes it off for good
    /// (recorded invalid, so the bucket still activates its best
    /// healthy variant).
    fn note_tune_failure(&mut self, key: ShapeKey, idx: usize, err: anyhow::Error) {
        let tick = self.tick;
        let (dead, quarantined) = {
            let b = self.breaker.entry((key, idx)).or_default();
            b.streak += 1;
            if b.reprobed {
                b.dead = true;
                (true, false)
            } else if b.streak >= QUARANTINE_AFTER {
                b.quarantined_until = Some(tick + QUARANTINE_COOLDOWN_TICKS);
                (false, true)
            } else {
                (false, false)
            }
        };
        if dead {
            self.stats.faults.gave_up += 1;
            self.persist_dead_variant(key, idx);
            self.record_measurement(key, idx, Err(err));
        } else {
            if quarantined {
                self.stats.faults.quarantined += 1;
            }
            self.tune_queue.insert(0, (key, idx));
        }
    }

    fn snapshot(&self) -> ExecutorStats {
        let mut s = self.stats.clone();
        s.faults.injected = self.backend.injected_faults();
        s.clock_us = self.backend.virtual_clock_us();
        for (key, vs) in &self.variants {
            let Some(&idx) = self.active.get(key) else { continue };
            let Some(v) = vs.get(idx) else { continue };
            let name = format!("b{}s{}", key.0, key.1);
            s.active.insert(name.clone(), v.desc.artifact_id.clone());
            // Latest full-fidelity measurement of the active variant: a
            // reverse scan of the bucket's (small) log, instead of
            // materializing a whole fingerprint→latency map per bucket
            // on every Stats command.
            let fp = v.desc.config.fingerprint();
            let measured = self.bucket_recs.get(key).and_then(|r| {
                r.evals
                    .iter()
                    .rev()
                    .find(|e| e.fingerprint == fp && e.is_full_fidelity())
                    .and_then(|e| e.latency_us)
            });
            if let Some(us) = measured {
                s.active_us.insert(name, us);
            }
        }
        s
    }
}

/// Handle to the executor thread.
pub struct ExecutorHandle {
    /// Command channel into the executor thread.
    pub tx: Sender<ExecutorCommand>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Compiled model (batch, seq) shapes discovered at startup.
    pub shapes: Vec<ShapeKey>,
}

impl ExecutorHandle {
    /// Spawn the executor thread over a backend built by `make`.
    ///
    /// The factory runs *inside* the new thread, so backends never need
    /// to be `Send` (PJRT handles are not); only the factory itself
    /// crosses the thread boundary.  `idle_tuning` enables Q4.4
    /// background measurements; `cache` makes bucket winners persistent
    /// across server restarts (Q4.3).
    pub fn spawn<B, F>(make: F, idle_tuning: bool, cache: Option<TuningCache>) -> Result<Self>
    where
        B: ExecBackend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = std::sync::mpsc::channel::<ExecutorCommand>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<Vec<ShapeKey>>>();
        let join = std::thread::Builder::new()
            .name("portatune-executor".into())
            .spawn(move || executor_loop(make, idle_tuning, cache, rx, ready_tx))?;
        let shapes = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("executor thread died during init"))??;
        Ok(ExecutorHandle { tx, join: Some(join), shapes })
    }

    /// Snapshot the executor's counters.
    pub fn stats(&self) -> Result<ExecutorStats> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.tx
            .send(ExecutorCommand::Stats { reply: tx })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        Ok(rx.recv()?)
    }

    /// Block until the background tuning queue is drained.
    pub fn finish_tuning(&self) -> Result<()> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.tx
            .send(ExecutorCommand::FinishTuning { reply: tx })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        rx.recv()?;
        Ok(())
    }
}

impl Drop for ExecutorHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(ExecutorCommand::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn executor_loop<B, F>(
    make: F,
    idle_tuning: bool,
    cache: Option<TuningCache>,
    rx: Receiver<ExecutorCommand>,
    ready: Sender<Result<Vec<ShapeKey>>>,
) where
    B: ExecBackend,
    F: FnOnce() -> Result<B>,
{
    let backend = match make() {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let mut state = match ExecutorState::new(backend, cache) {
        Ok(s) => {
            let _ = ready.send(Ok(s.shapes()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    loop {
        // Serve requests promptly; tune only in idle slices.
        let cmd = if idle_tuning {
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(c) => Some(c),
                Err(RecvTimeoutError::Timeout) => {
                    // Idle: drain a batch of pending tuning measurements,
                    // handing control back the moment a command arrives.
                    let mut interrupt = None;
                    for _ in 0..IDLE_TUNE_BATCH {
                        if !state.tune_step() {
                            break; // queue exhausted
                        }
                        if let Ok(c) = rx.try_recv() {
                            interrupt = Some(c);
                            break;
                        }
                    }
                    match interrupt {
                        Some(c) => Some(c),
                        None => continue,
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return,
            }
        } else {
            match rx.recv() {
                Ok(c) => Some(c),
                Err(_) => return,
            }
        };
        match cmd {
            Some(ExecutorCommand::Execute { batch, enqueued_at, reply }) => {
                match state.execute(&batch, enqueued_at) {
                    Ok(completions) => {
                        let _ = reply.send(ExecOutcome::Done(completions));
                    }
                    Err(e) => {
                        // Typed shed: the requests go back to the
                        // router with the reason — never a silent drop.
                        state.stats.faults.shed += batch.requests.len();
                        let _ = reply.send(ExecOutcome::Shed {
                            requests: batch.requests,
                            reason: e.to_string(),
                        });
                    }
                }
            }
            Some(ExecutorCommand::Stats { reply }) => {
                let _ = reply.send(state.snapshot());
            }
            Some(ExecutorCommand::FinishTuning { reply }) => {
                while state.tune_step() {}
                let _ = reply.send(());
            }
            Some(ExecutorCommand::Shutdown) | None => {
                // Drain, don't drop: Execute commands still queued
                // behind the shutdown get a typed Shed reply so the
                // router counts their requests instead of losing them
                // silently to a closed reply channel.
                while let Ok(late) = rx.try_recv() {
                    match late {
                        ExecutorCommand::Execute { batch, reply, .. } => {
                            state.stats.faults.shed += batch.requests.len();
                            let _ = reply.send(ExecOutcome::Shed {
                                requests: batch.requests,
                                reason: "executor shutting down".into(),
                            });
                        }
                        ExecutorCommand::Stats { reply } => {
                            let _ = reply.send(state.snapshot());
                        }
                        ExecutorCommand::FinishTuning { reply } => {
                            let _ = reply.send(());
                        }
                        ExecutorCommand::Shutdown => {}
                    }
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::SimGpu;
    use crate::serving::backend::SimBackend;

    #[test]
    fn executor_tunes_and_activates_on_the_sim_backend() {
        let handle =
            ExecutorHandle::spawn(move || Ok(SimBackend::new(SimGpu::a100(), 7)), true, None)
                .unwrap();
        assert!(!handle.shapes.is_empty(), "sim backend must discover a shape grid");
        handle.finish_tuning().unwrap();
        let stats = handle.stats().unwrap();
        assert!(stats.variants_measured > 0, "idle tuning must measure variants");
        assert_eq!(
            stats.active.len(),
            handle.shapes.len(),
            "every bucket activates a winner (variant 0 is always valid)"
        );
        assert!(!stats.active_us.is_empty());
        for s in &stats.swaps {
            assert!(s.gain > 1.0, "swap {:?} without improvement", s.shape);
        }
    }

    #[test]
    fn shutdown_drains_queued_executes_with_a_typed_shed() {
        use crate::serving::batcher::Batch;
        use crate::serving::Request;
        let (tx, rx) = std::sync::mpsc::channel();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel();
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let batch = Batch {
            bucket: 0,
            seq_len: 128,
            batch_shape: 1,
            requests: vec![Request { id: 1, tokens: 8 }, Request { id: 2, tokens: 8 }],
            formed_at: std::time::Instant::now(),
        };
        // Queue the shutdown FIRST, then a straggler batch behind it:
        // the loop must drain the straggler with a typed shed, not
        // return and drop its reply channel.
        tx.send(ExecutorCommand::Shutdown).unwrap();
        tx.send(ExecutorCommand::Execute {
            batch,
            enqueued_at: std::time::Instant::now(),
            reply: reply_tx,
        })
        .unwrap();
        drop(tx);
        executor_loop(move || Ok(SimBackend::new(SimGpu::a100(), 7)), false, None, rx, ready_tx);
        ready_rx.recv().unwrap().unwrap();
        match reply_rx.recv().expect("straggler must get a reply, not a closed channel") {
            ExecOutcome::Shed { requests, reason } => {
                assert_eq!(requests.len(), 2);
                assert!(reason.contains("shutting down"), "unexpected reason: {reason}");
            }
            _ => panic!("straggler behind a shutdown must be shed, not executed"),
        }
    }

    #[test]
    fn executor_init_failure_surfaces_through_spawn() {
        let err = ExecutorHandle::spawn::<SimBackend, _>(
            move || Err(anyhow::anyhow!("no such device")),
            false,
            None,
        )
        .unwrap_err();
        assert!(err.to_string().contains("no such device"), "{err}");
    }

    #[test]
    fn retrying_recovers_with_exponential_backoff_on_the_virtual_clock() {
        let mut b = SimBackend::new(SimGpu::a100(), 1);
        let mut faults = FaultCounters::default();
        let before = b.clock_us();
        let mut fail_left = 2;
        let v = retrying(&mut b, &mut faults, |_| {
            if fail_left > 0 {
                fail_left -= 1;
                Err(anyhow::anyhow!("flaky"))
            } else {
                Ok(42.0)
            }
        })
        .unwrap();
        assert_eq!(v, 42.0);
        assert_eq!(faults.failures, 2);
        assert_eq!(faults.retries, 2);
        assert_eq!(faults.recovered, 1);
        // 200µs + 400µs of modeled backoff — charged to the virtual
        // clock, zero wall-clock sleep.
        assert_eq!(b.clock_us() - before, BACKOFF_BASE_US * 3.0);
    }

    #[test]
    fn retrying_gives_up_after_max_retries() {
        let mut b = SimBackend::new(SimGpu::a100(), 1);
        let mut faults = FaultCounters::default();
        let err = retrying(&mut b, &mut faults, |_| -> Result<f64> {
            Err(anyhow::anyhow!("always down"))
        })
        .unwrap_err();
        assert!(err.to_string().contains("always down"));
        assert_eq!(faults.failures, MAX_RETRIES + 1);
        assert_eq!(faults.retries, MAX_RETRIES);
        assert_eq!(faults.recovered, 0);
    }

    #[test]
    fn dead_variants_persist_across_restart() {
        let dir = crate::util::tmp::TempDir::new("dead-variants").unwrap();
        let cache_path = dir.join("cache.json");
        // Session 1: drive one variant to dead (its re-probe is spent,
        // so the next hard failure writes it off) and let the cache
        // persist the write-off.
        let (key, dead_cfg, measured_before);
        {
            let backend = SimBackend::new(SimGpu::a100(), 7);
            let cache = TuningCache::open(&cache_path).unwrap();
            let mut state = ExecutorState::new(backend, Some(cache)).unwrap();
            key = *state.variants.keys().min().unwrap();
            let idx = 1; // a non-default variant
            dead_cfg = state.variants[&key][idx].desc.config.clone();
            state
                .breaker
                .insert((key, idx), Breaker { reprobed: true, ..Breaker::default() });
            state.note_tune_failure(key, idx, anyhow::anyhow!("persistent fault"));
            assert!(state.breaker[&(key, idx)].dead);
            measured_before = state.stats.faults.gave_up;
            assert_eq!(measured_before, 1);
        } // state dropped; cache saved on drop
        // The write-off is on disk under the variant's own space key.
        let reread = TuningCache::open(&cache_path).unwrap();
        let space = ExecutorState::<SimBackend>::dead_space(&dead_cfg);
        assert!(
            reread
                .entries()
                .any(|(_, e)| e.space == space && e.invalid == 1),
            "dead variant must be persisted"
        );
        // Session 2 (restart): the variant comes back pre-dead — out of
        // the tuning queue, breaker open, recorded invalid so the
        // bucket can still activate.
        let backend = SimBackend::new(SimGpu::a100(), 7);
        let cache = TuningCache::open(&cache_path).unwrap();
        let state = ExecutorState::new(backend, Some(cache)).unwrap();
        let idx = state.variants[&key]
            .iter()
            .position(|v| v.desc.config == dead_cfg)
            .expect("same seed, same variant universe");
        assert!(state.breaker.get(&(key, idx)).map_or(false, |b| b.dead));
        assert!(
            !state.tune_queue.contains(&(key, idx)),
            "dead variant must not be re-tuned"
        );
        assert!(
            state.bucket_recs.get(&key).map_or(false, |r| r.len() >= 1),
            "restored write-off must be recorded invalid"
        );
    }

    #[test]
    fn restored_dead_variant_never_blocks_activation() {
        // A bucket whose non-default variant was written off last
        // session must still fully tune and activate a winner.
        let dir = crate::util::tmp::TempDir::new("dead-activate").unwrap();
        let cache_path = dir.join("cache.json");
        let (key, idx) = {
            let backend = SimBackend::new(SimGpu::a100(), 7);
            let cache = TuningCache::open(&cache_path).unwrap();
            let mut state = ExecutorState::new(backend, Some(cache)).unwrap();
            let key = *state.variants.keys().min().unwrap();
            state
                .breaker
                .insert((key, 2), Breaker { reprobed: true, ..Breaker::default() });
            state.note_tune_failure(key, 2, anyhow::anyhow!("persistent fault"));
            (key, 2)
        };
        let handle = {
            let cache_path = cache_path.clone();
            ExecutorHandle::spawn(
                move || Ok(SimBackend::new(SimGpu::a100(), 7)),
                false,
                Some(TuningCache::open(&cache_path).unwrap()),
            )
            .unwrap()
        };
        handle.finish_tuning().unwrap();
        let stats = handle.stats().unwrap();
        assert_eq!(
            stats.active.len(),
            handle.shapes.len(),
            "every bucket (including the one with a dead variant) activates"
        );
        let name = format!("b{}s{}", key.0, key.1);
        assert!(stats.active.contains_key(&name), "bucket {name} must serve; dead idx {idx}");
    }

    #[test]
    fn completed_buckets_persist_a_surrogate_and_restarts_pre_rank_with_it() {
        let dir = crate::util::tmp::TempDir::new("surrogate-serving").unwrap();
        let cache_path = dir.join("cache.json");
        // Session 1: tune every bucket; each completed bucket refits
        // the cost model and persists the coefficients.
        {
            let backend = SimBackend::new(SimGpu::a100(), 7);
            let cache = TuningCache::open(&cache_path).unwrap();
            let mut state = ExecutorState::new(backend, Some(cache)).unwrap();
            while state.tune_step() {}
            assert!(state.surrogate.is_some(), "completed buckets must refit a model");
        }
        let reread = TuningCache::open(&cache_path).unwrap();
        assert!(
            reread
                .entries()
                .any(|(_, e)| e.space.starts_with(crate::surrogate::SURROGATE_SPACE_PREFIX)),
            "coefficients must persist under the surrogate namespace"
        );
        // Session 2: a different sim seed serves different candidate
        // sets, so the winners can't warm-start — but the model does,
        // and the queue is pre-ranked: within each bucket's contiguous
        // run the entries are worst-predicted-first, so `pop()` (which
        // takes from the back) measures the model's favorite first.
        let backend = SimBackend::new(SimGpu::a100(), 11);
        let cache = TuningCache::open(&cache_path).unwrap();
        let state = ExecutorState::new(backend, Some(cache)).unwrap();
        let model = state.surrogate.clone().expect("restart must adopt the persisted model");
        assert!(!state.tune_queue.is_empty());
        let mut i = 0;
        while i < state.tune_queue.len() {
            let key = state.tune_queue[i].0;
            let mut j = i;
            while j < state.tune_queue.len() && state.tune_queue[j].0 == key {
                j += 1;
            }
            let w = state.backend.bucket_workload(key);
            let preds: Vec<f64> = state.tune_queue[i..j]
                .iter()
                .map(|&(_, idx)| model.predict_us(&state.variants[&key][idx].desc.config, &w))
                .collect();
            for win in preds.windows(2) {
                assert!(
                    win[0] >= win[1],
                    "bucket {key:?} queue not worst-predicted-first: {preds:?}"
                );
            }
            i = j;
        }
    }

    #[test]
    fn finish_tuning_is_idempotent() {
        let handle =
            ExecutorHandle::spawn(move || Ok(SimBackend::new(SimGpu::mi250(), 3)), false, None)
                .unwrap();
        handle.finish_tuning().unwrap();
        let first = handle.stats().unwrap().variants_measured;
        assert!(first > 0);
        handle.finish_tuning().unwrap();
        assert_eq!(handle.stats().unwrap().variants_measured, first, "queue drains exactly once");
    }
}
