//! The executor thread: sole owner of the PJRT client.
//!
//! PJRT objects are not `Send`, so every compile/execute happens here.
//! The thread serves [`ExecutorCommand`]s; **when idle it advances the
//! background tuning queue** — draining up to [`IDLE_TUNE_BATCH`]
//! pending variant measurements per idle slice, yielding immediately
//! when a request arrives — and hot-swaps a bucket's active kernel
//! variant when a faster one has been proven.  This is the paper's Q4.4
//! ("move autotuning off the critical path ... using idle GPU times")
//! made concrete.
//!
//! The drain is fed by the shared worker pool
//! ([`crate::util::pool`]): measurement *inputs* (synthetic activation
//! tensors, one per bucket shape — potentially tens of MB each) are
//! generated on pool workers ahead of the measurements that need them
//! and memoized per shape, so the executor thread spends its idle
//! slices measuring instead of filling buffers.  The PJRT work itself
//! stays on this thread (PJRT handles are not `Send`).
//!
//! Measurement bookkeeping goes through the autotuner's own
//! [`Recorder`] (one per bucket, fidelity 1.0): winner selection is
//! `Recorder::best`, failed measurements are counted as invalid like
//! any other platform-rejected config, and the stats snapshot reads the
//! recorder instead of duplicating per-variant latency fields.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use super::batcher::Batch;
use super::Completion;
use crate::autotuner::search::Recorder;
use crate::cache::{entry_now, TuningCache};
use crate::config::Config;
use crate::platform::model::InvalidConfig;
use crate::runtime::{Engine, Executable, Manifest, TensorF32};
use crate::workload::{DType, Workload};
use crate::Result;

/// Key of a compiled model shape: (batch, seq).
pub type ShapeKey = (usize, usize);

/// How many pending tuning measurements one idle slice may drain.
/// Batching amortizes the idle-detection timeout across several
/// measurements (the queue empties ~4x faster under bursty traffic);
/// the drain polls the command queue between measurements so request
/// latency never waits on more than one in-flight measurement.
pub const IDLE_TUNE_BATCH: usize = 4;

/// Commands accepted by the executor thread.
pub enum ExecutorCommand {
    /// Run one batch; reply with per-request completions.
    Execute { batch: Batch, enqueued_at: Instant, reply: Sender<Vec<Completion>> },
    /// Snapshot statistics.
    Stats { reply: Sender<ExecutorStats> },
    /// Flush: measure every pending tuning item *now* (used by examples
    /// to show the "after tuning" steady state without idling).
    FinishTuning { reply: Sender<()> },
    /// Stop the executor thread.
    Shutdown,
}

/// One kernel-config variant of a compiled model shape.  Measurement
/// results are NOT stored here: each bucket's measurements live in its
/// [`Recorder`] — the same fidelity-correct log every autotuner
/// strategy records through — so winner selection, gain computation and
/// the stats snapshot all read one source of truth instead of ad-hoc
/// per-variant fields.
struct Variant {
    artifact_id: String,
    /// Kernel config parsed from the artifact id (the recorder key).
    config: Config,
    path: std::path::PathBuf,
    exe: Option<Executable>,
}

/// A record of the executor swapping a bucket's active variant.
#[derive(Debug, Clone)]
pub struct SwapEvent {
    /// The (batch, seq) bucket whose variant changed.
    pub shape: ShapeKey,
    /// Previous active artifact id.
    pub from: String,
    /// New active artifact id.
    pub to: String,
    /// measured latency ratio old/new (>1 = improvement).
    pub gain: f64,
}

/// Executor statistics snapshot.
#[derive(Debug, Clone, Default)]
pub struct ExecutorStats {
    /// Buckets whose active variant came from the persistent cache at
    /// startup (warm start; no cold tuning needed).
    pub warm_started: usize,
    /// Batches executed on the request path.
    pub batches_executed: usize,
    /// Requests served across all batches.
    pub requests_served: usize,
    /// Background tuning measurements performed.
    pub variants_measured: usize,
    /// Artifact compiles (request path + tuning).
    pub compiles: usize,
    /// Every variant hot-swap, in order.
    pub swaps: Vec<SwapEvent>,
    /// shape -> active artifact id.
    pub active: HashMap<String, String>,
    /// shape -> measured latency of active variant (µs).
    pub active_us: HashMap<String, f64>,
}

struct ExecutorState {
    engine: Engine,
    hidden: usize,
    variants: HashMap<ShapeKey, Vec<Variant>>,
    active: HashMap<ShapeKey, usize>,
    tune_queue: Vec<(ShapeKey, usize)>,
    /// Per-bucket measurement log (the autotuner's [`Recorder`], at
    /// fidelity 1.0): `best()` picks the winner, failed measurements
    /// count as invalid instead of silently blocking the bucket.
    bucket_recs: HashMap<ShapeKey, Recorder<'static>>,
    /// Weights uploaded ONCE as device buffers: the request path only
    /// moves activations (§Perf L3 — this was the dominant cost before).
    weights: Vec<xla::PjRtBuffer>,
    stats: ExecutorStats,
    /// Measurement effort for background tuning.
    tune_warmup: usize,
    tune_iters: usize,
    /// Persistent tuning cache (Q4.3): bucket winners survive restarts,
    /// so a re-deployed server starts warm instead of re-tuning.
    cache: Option<TuningCache>,
    /// Synthetic measurement inputs, memoized per bucket shape and
    /// generated ahead of need on the shared worker pool (the tensors
    /// are deterministic per shape, so caching changes nothing but
    /// wall-clock).
    tune_inputs: HashMap<ShapeKey, TensorF32>,
    model_geom: (usize, usize, usize), // (q_heads, kv_heads, head_dim)
}

impl ExecutorState {
    /// Synthetic workload key for a serving bucket: the attention
    /// geometry of the served model at this (batch, seq) shape.
    fn bucket_workload(&self, key: ShapeKey) -> Workload {
        let (q, kv, d) = self.model_geom;
        Workload::Attention {
            batch: key.0,
            q_heads: q,
            kv_heads: kv,
            seq_len: key.1,
            head_dim: d,
            dtype: DType::F32,
            causal: true,
        }
    }

    const CACHE_SPACE: &'static str = "serving_model_variants";

    fn cache_platform() -> String {
        crate::platform::PlatformId::CpuPjrt.fingerprint()
    }

    /// Warm start: adopt cached per-bucket winners before any tuning.
    fn warm_start_from_cache(&mut self) {
        let Some(cache) = &self.cache else { return };
        let platform = Self::cache_platform();
        let keys: Vec<ShapeKey> = self.variants.keys().copied().collect();
        let mut warmed = 0;
        for key in keys {
            let w = self.bucket_workload(key);
            let Some(hit) = cache.get(&w, &platform, Self::CACHE_SPACE) else { continue };
            let Some(cfg) = hit.config() else { continue };
            if let Some(idx) = self.variants[&key].iter().position(|v| v.config == cfg) {
                self.active.insert(key, idx);
                warmed += 1;
            }
        }
        if warmed > 0 {
            self.stats.warm_started = warmed;
            // Nothing left to prove for warmed buckets this session.
            let platform = Self::cache_platform();
            let cached_keys: std::collections::HashSet<ShapeKey> = self
                .variants
                .keys()
                .copied()
                .filter(|k| {
                    let w = self.bucket_workload(*k);
                    self.cache
                        .as_ref()
                        .map(|c| c.get(&w, &platform, Self::CACHE_SPACE).is_some())
                        .unwrap_or(false)
                })
                .collect();
            self.tune_queue.retain(|(k, _)| !cached_keys.contains(k));
        }
    }

    /// Persist a freshly proven bucket winner (Q4.3).
    fn persist_winner(&mut self, key: ShapeKey, idx: usize, measured_us: f64, evaluated: usize) {
        let w = self.bucket_workload(key);
        let cfg = self.variants[&key][idx].config.clone();
        if let Some(cache) = &mut self.cache {
            cache.put(
                &w,
                entry_now(&cfg, measured_us, evaluated, 0, &Self::cache_platform(), Self::CACHE_SPACE, 0.0),
            );
            let _ = cache.save();
        }
    }

    fn new(manifest: &Manifest, cache: Option<TuningCache>) -> Result<Self> {
        let engine = Engine::cpu()?;
        let model = &manifest.model;
        // Deterministic synthetic weights, uploaded once to the device.
        let weights = model
            .param_order
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let shape = &model.param_shapes[name];
                // Small magnitudes keep block outputs numerically tame.
                let mut t = TensorF32::random(shape, 0x5EED + i as u64);
                let scale = 1.0 / (model.hidden as f32).sqrt();
                for v in &mut t.data {
                    *v *= scale;
                }
                engine.upload(&t)
            })
            .collect::<Result<Vec<_>>>()?;

        let mut variants: HashMap<ShapeKey, Vec<Variant>> = HashMap::new();
        for a in manifest.model_artifacts() {
            let (Some(batch), Some(seq)) = (a.workload.batch, a.workload.seq_len) else { continue };
            variants.entry((batch, seq)).or_default().push(Variant {
                artifact_id: a.id.clone(),
                config: variant_config(&a.id),
                path: manifest.root.join(&a.path),
                exe: None,
            });
        }
        let tune_queue: Vec<(ShapeKey, usize)> = variants
            .iter()
            .flat_map(|(k, vs)| (0..vs.len()).map(move |i| (*k, i)))
            .collect();
        let active = variants.keys().map(|k| (*k, 0)).collect();
        let mut state = ExecutorState {
            engine,
            hidden: model.hidden,
            variants,
            active,
            tune_queue,
            bucket_recs: HashMap::new(),
            weights,
            stats: ExecutorStats::default(),
            tune_warmup: 1,
            tune_iters: 3,
            cache,
            tune_inputs: HashMap::new(),
            model_geom: (model.n_q_heads, model.n_kv_heads, model.head_dim),
        };
        state.warm_start_from_cache();
        Ok(state)
    }

    fn shapes(&self) -> Vec<ShapeKey> {
        let mut v: Vec<ShapeKey> = self.variants.keys().copied().collect();
        v.sort();
        v
    }

    fn ensure_compiled(&mut self, key: ShapeKey, idx: usize) -> Result<()> {
        let v = &mut self.variants.get_mut(&key).unwrap()[idx];
        if v.exe.is_none() {
            v.exe = Some(self.engine.load_hlo_text(&v.path)?);
            self.stats.compiles += 1;
        }
        Ok(())
    }

    fn execute(&mut self, batch: &Batch, enqueued_at: Instant) -> Result<Vec<Completion>> {
        let key = (batch.batch_shape, batch.seq_len);
        let idx = *self.active.get(&key).ok_or_else(|| anyhow::anyhow!("no artifact shape {key:?}"))?;
        self.ensure_compiled(key, idx)?;
        let hidden = self.hidden;
        // Synthetic embedded prompt activations for the batch; weights
        // are already device-resident.
        let x = TensorF32::random(&[batch.batch_shape, batch.seq_len, hidden], 0xAB + batch.bucket as u64);
        let x_buf = self.engine.upload(&x)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
        args.push(&x_buf);
        args.extend(self.weights.iter());
        let v = &self.variants[&key][idx];
        let exe = v.exe.as_ref().unwrap();
        let t0 = Instant::now();
        let out = exe.run_buffers(&args)?;
        let exec_us = t0.elapsed().as_secs_f64() * 1e6;
        debug_assert_eq!(out.len(), batch.batch_shape * batch.seq_len * hidden);
        let latency_us = enqueued_at.elapsed().as_secs_f64() * 1e6;
        self.stats.batches_executed += 1;
        self.stats.requests_served += batch.requests.len();
        Ok(batch
            .requests
            .iter()
            .map(|r| Completion {
                id: r.id,
                tokens: r.tokens,
                bucket_seq: batch.seq_len,
                batch_size: batch.batch_shape,
                latency_us,
                exec_us,
                variant: v.artifact_id.clone(),
            })
            .collect())
    }

    /// Generate (on the shared worker pool, in parallel) the synthetic
    /// input tensors for the next up-to-[`IDLE_TUNE_BATCH`] queued
    /// measurements that don't have one memoized yet.  The tensors are
    /// deterministic per shape, so this is purely a wall-clock
    /// optimization: the executor thread measures while the pool fills
    /// buffers for upcoming shapes.
    fn prefetch_tune_inputs(&mut self) {
        let hidden = self.hidden;
        let mut todo: Vec<ShapeKey> = Vec::new();
        // `tune_queue.pop()` takes from the back, so the *next* items
        // are the tail.
        for (key, _) in self.tune_queue.iter().rev().take(IDLE_TUNE_BATCH) {
            if !self.tune_inputs.contains_key(key) && !todo.contains(key) {
                todo.push(*key);
            }
        }
        if todo.is_empty() {
            return;
        }
        let mut made: Vec<Option<TensorF32>> = vec![None; todo.len()];
        crate::util::pool::global().scope(|s| {
            for (key, slot) in todo.iter().zip(made.iter_mut()) {
                let key = *key;
                s.spawn(move || {
                    *slot = Some(TensorF32::random(&[key.0, key.1, hidden], 0xEE));
                });
            }
        });
        for (key, tensor) in todo.into_iter().zip(made) {
            if let Some(t) = tensor {
                self.tune_inputs.insert(key, t);
            }
        }
    }

    /// Fold one measurement result (success or failure) into the
    /// bucket's recorder and activate the winner if the bucket is now
    /// fully measured.  Recording failures as invalid — the same way
    /// every autotuner strategy counts invalid configs — is what lets a
    /// bucket with one broken variant still activate its best working
    /// one (previously a single failed measurement blocked the bucket's
    /// swap forever).
    fn record_measurement(&mut self, key: ShapeKey, idx: usize, res: Result<f64>) {
        let cfg = self.variants[&key][idx].config.clone();
        let res = res.map_err(|e| InvalidConfig { reason: e.to_string() });
        if res.is_ok() {
            self.stats.variants_measured += 1;
        }
        self.bucket_recs.entry(key).or_default().record(&cfg, res, 1.0);
        self.try_activate(key);
    }

    /// If every variant of `key`'s bucket has been measured (or failed),
    /// activate the fastest valid variant, record the swap, and persist
    /// the winner to the tuning cache (Q4.3).
    fn try_activate(&mut self, key: ShapeKey) {
        let vs = &self.variants[&key];
        let Some(rec) = self.bucket_recs.get(&key) else { return };
        if rec.len() < vs.len() {
            return; // bucket not fully measured yet
        }
        let Some((best_cfg, best_us)) = rec.best() else {
            return; // every variant failed to measure: nothing to swap
        };
        let latencies = rec.full_fidelity_latencies();
        let Some(best) = vs.iter().position(|v| v.config == best_cfg) else { return };
        let cur = self.active[&key];
        if best != cur {
            // Gain versus the incumbent; infinite headroom when the
            // incumbent itself failed to measure.
            let gain = latencies
                .get(&vs[cur].config.fingerprint())
                .map(|c| c / best_us)
                .unwrap_or(f64::INFINITY);
            self.stats.swaps.push(SwapEvent {
                shape: key,
                from: vs[cur].artifact_id.clone(),
                to: vs[best].artifact_id.clone(),
                gain,
            });
            self.active.insert(key, best);
        }
        let shape_name = format!("b{}s{}", key.0, key.1);
        let (best_id, n) = (vs[best].artifact_id.clone(), vs.len());
        self.stats.active.insert(shape_name.clone(), best_id);
        self.stats.active_us.insert(shape_name, best_us);
        self.persist_winner(key, best, best_us, n);
    }

    /// Run ONE background tuning measurement. Returns false when the
    /// queue is exhausted.
    fn tune_step(&mut self) -> bool {
        self.prefetch_tune_inputs();
        let Some((key, idx)) = self.tune_queue.pop() else {
            // Queue drained: the memoized inputs (tens of MB per shape)
            // have nothing left to serve.
            self.tune_inputs.clear();
            return false;
        };
        if let Err(e) = self.ensure_compiled(key, idx) {
            // Uncompilable variant: count it as invalid so the bucket
            // can still complete, keep tuning.
            self.record_measurement(key, idx, Err(e));
            return true;
        }
        let hidden = self.hidden;
        if !self.tune_inputs.contains_key(&key) {
            // Prefetch miss (e.g. shape beyond the lookahead window).
            self.tune_inputs.insert(key, TensorF32::random(&[key.0, key.1, hidden], 0xEE));
        }
        let x = &self.tune_inputs[&key];
        let x_buf = match self.engine.upload(x) {
            Ok(buf) => buf,
            Err(e) => {
                self.record_measurement(key, idx, Err(e));
                return true;
            }
        };
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
        args.push(&x_buf);
        args.extend(self.weights.iter());
        let (warmup, iters) = (self.tune_warmup, self.tune_iters);
        let v = &self.variants[&key][idx];
        let exe = v.exe.as_ref().unwrap();
        let measured = exe.time_us_buffers(&args, warmup, iters);
        self.record_measurement(key, idx, measured);
        // Drop the memoized input once its shape has no queued
        // measurements left (the whole map is cleared on exhaustion).
        if !self.tune_queue.iter().any(|(k, _)| *k == key) {
            self.tune_inputs.remove(&key);
        }
        true
    }

    fn snapshot(&self) -> ExecutorStats {
        let mut s = self.stats.clone();
        for (key, vs) in &self.variants {
            let idx = self.active[key];
            let name = format!("b{}s{}", key.0, key.1);
            s.active.insert(name.clone(), vs[idx].artifact_id.clone());
            // Latest full-fidelity measurement of the active variant: a
            // reverse scan of the bucket's (small) log, instead of
            // materializing a whole fingerprint→latency map per bucket
            // on every Stats command.
            let fp = vs[idx].config.fingerprint();
            let measured = self.bucket_recs.get(key).and_then(|r| {
                r.evals
                    .iter()
                    .rev()
                    .find(|e| e.fingerprint == fp && e.is_full_fidelity())
                    .and_then(|e| e.latency_us)
            });
            if let Some(us) = measured {
                s.active_us.insert(name, us);
            }
        }
        s
    }
}

/// Parse the kernel config out of a model artifact id
/// (`model/b1_s128/bq32_bk64_u2` -> block_q=32,block_k=64,unroll=2).
fn variant_config(artifact_id: &str) -> Config {
    let mut cfg = Config::default();
    if let Some(last) = artifact_id.rsplit('/').next() {
        for part in last.split('_') {
            if let Some(v) = part.strip_prefix("bq").and_then(|s| s.parse().ok()) {
                cfg.set("block_q", v);
            } else if let Some(v) = part.strip_prefix("bk").and_then(|s| s.parse().ok()) {
                cfg.set("block_k", v);
            } else if let Some(v) = part.strip_prefix('u').and_then(|s| s.parse().ok()) {
                cfg.set("unroll", v);
            }
        }
    }
    cfg
}

/// Handle to the executor thread.
pub struct ExecutorHandle {
    /// Command channel into the executor thread.
    pub tx: Sender<ExecutorCommand>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Compiled model (batch, seq) shapes discovered at startup.
    pub shapes: Vec<ShapeKey>,
}

impl ExecutorHandle {
    /// Spawn the executor thread over the manifest's model artifacts.
    /// `idle_tuning` enables Q4.4 background measurements; `cache` makes
    /// bucket winners persistent across server restarts (Q4.3).
    pub fn spawn(manifest: Manifest, idle_tuning: bool, cache: Option<TuningCache>) -> Result<Self> {
        let (tx, rx) = std::sync::mpsc::channel::<ExecutorCommand>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<Vec<ShapeKey>>>();
        let join = std::thread::Builder::new()
            .name("portatune-executor".into())
            .spawn(move || executor_loop(manifest, idle_tuning, cache, rx, ready_tx))?;
        let shapes = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("executor thread died during init"))??;
        Ok(ExecutorHandle { tx, join: Some(join), shapes })
    }

    /// Snapshot the executor's counters.
    pub fn stats(&self) -> Result<ExecutorStats> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.tx
            .send(ExecutorCommand::Stats { reply: tx })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        Ok(rx.recv()?)
    }

    /// Block until the background tuning queue is drained.
    pub fn finish_tuning(&self) -> Result<()> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.tx
            .send(ExecutorCommand::FinishTuning { reply: tx })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        rx.recv()?;
        Ok(())
    }
}

impl Drop for ExecutorHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(ExecutorCommand::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn executor_loop(
    manifest: Manifest,
    idle_tuning: bool,
    cache: Option<TuningCache>,
    rx: Receiver<ExecutorCommand>,
    ready: Sender<Result<Vec<ShapeKey>>>,
) {
    let mut state = match ExecutorState::new(&manifest, cache) {
        Ok(s) => {
            let _ = ready.send(Ok(s.shapes()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    loop {
        // Serve requests promptly; tune only in idle slices.
        let cmd = if idle_tuning {
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(c) => Some(c),
                Err(RecvTimeoutError::Timeout) => {
                    // Idle: drain a batch of pending tuning measurements,
                    // handing control back the moment a command arrives.
                    let mut interrupt = None;
                    for _ in 0..IDLE_TUNE_BATCH {
                        if !state.tune_step() {
                            break; // queue exhausted
                        }
                        if let Ok(c) = rx.try_recv() {
                            interrupt = Some(c);
                            break;
                        }
                    }
                    match interrupt {
                        Some(c) => Some(c),
                        None => continue,
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return,
            }
        } else {
            match rx.recv() {
                Ok(c) => Some(c),
                Err(_) => return,
            }
        };
        match cmd {
            Some(ExecutorCommand::Execute { batch, enqueued_at, reply }) => {
                match state.execute(&batch, enqueued_at) {
                    Ok(completions) => {
                        let _ = reply.send(completions);
                    }
                    Err(e) => {
                        eprintln!("portatune-executor: execute failed: {e}");
                        let _ = reply.send(Vec::new());
                    }
                }
            }
            Some(ExecutorCommand::Stats { reply }) => {
                let _ = reply.send(state.snapshot());
            }
            Some(ExecutorCommand::FinishTuning { reply }) => {
                while state.tune_step() {}
                let _ = reply.send(());
            }
            Some(ExecutorCommand::Shutdown) | None => return,
        }
    }
}
