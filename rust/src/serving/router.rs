//! The request router: trace replay, dynamic batching, sharded
//! dispatch, reporting.
//!
//! `Router::serve_trace_timed` replays a (deterministic, seeded)
//! arrival trace through ONE [`DynamicBatcher`](super::batcher::DynamicBatcher)
//! and fans the formed batches out over N executor shards
//! ([`ShardSet`]) per the placement policy, aggregating a
//! [`ServeReport`] with per-shard and rolled-up stats — the end-to-end
//! driver behind `portatune serve` and `examples/serve_attention.rs`.
//! The router is backend-agnostic: it serves the always-available
//! [`SimBackend`] ([`Router::sim`]) in default builds and real PJRT
//! artifacts (`Router::pjrt`, feature `pjrt` — the link target only
//! exists in pjrt builds) when the toolchain exists.
//!
//! Admission control is shared across shards: one `max_pending` bound
//! covers the batcher queue plus every dispatched-but-unreaped batch,
//! so adding shards raises throughput without silently raising the
//! memory bound.  Dispatch is pipelined (up to 2 batches in flight per
//! shard) but reaped strictly in dispatch order, which keeps the whole
//! replay a pure function of the trace — the bit-reproducibility the
//! sharding tests pin.

use std::collections::VecDeque;
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use super::backend::{ExecBackend, SimBackend};
use super::batcher::{BucketPolicy, DynamicBatcher};
use super::executor::{ExecOutcome, ExecutorCommand, ExecutorHandle, ExecutorStats};
use super::loadgen::TimedRequest;
use super::shard::{PlacementPolicy, ShardSet, ShardUtil};
use super::{Completion, Request};
use crate::metrics::{FaultCounters, Summary};
use crate::util::rng::Rng;
use crate::Result;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Flush deadline for partial batches (µs).
    pub max_wait_us: u64,
    /// Enable Q4.4 idle-time background tuning.
    pub idle_tuning: bool,
    /// Persistent tuning-cache file (Q4.3): bucket winners survive
    /// restarts, so re-deployed servers start warm.
    pub cache_path: Option<std::path::PathBuf>,
    /// Admission-control bound, shared across all shards: when this
    /// many requests are queued in the batcher plus dispatched and not
    /// yet reaped, new arrivals are shed (graceful degradation) instead
    /// of growing the queues without bound.
    pub max_pending: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_wait_us: 2_000, idle_tuning: true, cache_path: None, max_pending: 1024 }
    }
}

/// Aggregated serving statistics.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Requests completed.
    pub requests: usize,
    /// Requests rejected (no bucket fits them).
    pub rejected: usize,
    /// Batches executed (every batch sent to an executor; identical
    /// batch shapes are NOT collapsed).
    pub batches: usize,
    /// Wall-clock duration of the replay, seconds.
    pub wall_seconds: f64,
    /// Completed requests per second of wall-clock (host timing — use
    /// [`ServeReport::sim_throughput_rps`] for the deterministic
    /// model-time figure).
    pub throughput_rps: f64,
    /// Tokens served per second.
    pub tokens_per_second: f64,
    /// End-to-end latency median, µs.
    pub latency_p50_us: f64,
    /// End-to-end latency 95th percentile, µs.
    pub latency_p95_us: f64,
    /// End-to-end latency 99th percentile, µs.
    pub latency_p99_us: f64,
    /// Pure execution latency median, µs.
    pub exec_p50_us: f64,
    /// Pure execution latency mean, µs — the cold-vs-tuned acceptance
    /// metric (on the deterministic sim backend, tuned ≤ cold holds
    /// exactly: the tuned variant is the per-bucket argmin of the same
    /// model).
    pub exec_mean_us: f64,
    /// Mean fraction of each compiled batch doing useful work.
    pub mean_batch_occupancy: f64,
    /// Requests shed during THIS replay: executor-side typed sheds (no
    /// healthy variant, or drained at shutdown) plus router-side
    /// admission-control sheds (saturation past `max_pending`).
    pub shed: usize,
    /// Requests LOST during this replay: their shard died mid-batch
    /// (reply channel dropped) or every shard was dead when the batch
    /// was placed.  Always 0 on healthy runs; nonzero loss is counted,
    /// never silent.
    pub lost: usize,
    /// Number of executor shards that served the replay.
    pub shards: usize,
    /// Fault-tolerance counters: the shards' cumulative counters
    /// (injected faults, failures, retries, quarantines, executor-side
    /// sheds) plus this replay's router-side admission sheds.
    pub faults: FaultCounters,
    /// Executor-side counters (tuning, swaps, compiles), rolled up over
    /// all shards ([`ExecutorStats::absorb`] in shard order).
    pub executor: ExecutorStats,
    /// Per-shard executor snapshots, in shard order (cumulative over
    /// the executor's lifetime, not just this replay).
    pub shard_stats: Vec<ExecutorStats>,
    /// Per-shard work done during THIS replay: batches, requests, and
    /// virtual-clock busy time.
    pub shard_util: Vec<ShardUtil>,
    /// Modeled makespan of the replay, µs: the largest per-shard
    /// virtual-clock delta.  0.0 on wall-clock backends.
    pub sim_makespan_us: f64,
    /// Completed requests per second of *modeled* time
    /// (`requests / sim_makespan`), the deterministic throughput figure
    /// the scaling tests compare across shard counts.  0.0 on
    /// wall-clock backends.
    pub sim_throughput_rps: f64,
}

impl ServeReport {
    /// A digest of every *deterministic* field of the report — what the
    /// chaos and sharding bit-reproducibility tests pin.
    ///
    /// Determinism argument: on the sim backend all served latencies
    /// are model-derived, every injected fault is a pure function of
    /// the `FaultPlan` seed (see [`crate::serving::chaos`]), and batch
    /// placement is a pure function of the batch key and integer load
    /// counters (see [`PlacementPolicy`]) — so request counts, batch
    /// counts, exec-latency aggregates, swap history, active variants,
    /// fault counters, and per-shard busy time are bit-identical across
    /// replays.  Wall-clock-derived fields (`wall_seconds`, throughput,
    /// end-to-end latency percentiles) are host timing no seed
    /// controls, and are deliberately excluded.  Per-shard busy time is
    /// only deterministic when idle tuning is off or already finished
    /// (an idle-tuning slice lands on the clock on a wall-time
    /// schedule); the digest tests run with tuning quiesced.
    pub fn replay_digest(&self) -> String {
        use std::fmt::Write as _;
        let mut d = String::new();
        let _ = write!(
            d,
            "req={} rej={} shed={} batches={} exec_p50={:016x} exec_mean={:016x} occ={:016x}",
            self.requests,
            self.rejected,
            self.shed,
            self.batches,
            self.exec_p50_us.to_bits(),
            self.exec_mean_us.to_bits(),
            self.mean_batch_occupancy.to_bits(),
        );
        let e = &self.executor;
        let _ = write!(
            d,
            " warm={} bex={} served={} meas={} compiles={}",
            e.warm_started, e.batches_executed, e.requests_served, e.variants_measured, e.compiles
        );
        for s in &e.swaps {
            let _ = write!(d, " swap={:?}:{}->{}:{:016x}", s.shape, s.from, s.to, s.gain.to_bits());
        }
        let mut active: Vec<(&String, &String)> = e.active.iter().collect();
        active.sort();
        for (k, v) in active {
            let _ = write!(d, " active[{k}]={v}");
        }
        let mut active_us: Vec<(&String, &f64)> = e.active_us.iter().collect();
        active_us.sort_by(|a, b| a.0.cmp(b.0));
        for (k, v) in active_us {
            let _ = write!(d, " us[{k}]={:016x}", v.to_bits());
        }
        let _ = write!(d, " faults={:?}", self.faults);
        let _ = write!(d, " shards={} lost={}", self.shards, self.lost);
        for u in &self.shard_util {
            let _ = write!(
                d,
                " shard[{}]={}b/{}r/{:016x}",
                u.shard,
                u.batches,
                u.requests,
                u.busy_us.to_bits()
            );
        }
        let _ = write!(d, " makespan={:016x}", self.sim_makespan_us.to_bits());
        d
    }
}

/// One dispatched-but-unreaped batch: which shard took it, how many
/// requests ride in it, and the reply channel to harvest.
struct InFlight {
    shard: usize,
    n_requests: usize,
    rx: Receiver<ExecOutcome>,
}

/// Harvest the OLDEST in-flight batch (FIFO — reap order is dispatch
/// order, independent of which shard finishes first, which is what
/// keeps sharded replays deterministic).  A dead reply channel means
/// the shard's executor thread died mid-batch: mark the shard dead and
/// count the requests as lost, never silently dropped.
#[allow(clippy::too_many_arguments)]
fn reap_oldest(
    in_flight: &mut VecDeque<InFlight>,
    outstanding: &mut [usize],
    dead: &mut [bool],
    completions: &mut Vec<Completion>,
    exec_shed: &mut usize,
    lost: &mut usize,
    in_flight_reqs: &mut usize,
) {
    let Some(f) = in_flight.pop_front() else { return };
    outstanding[f.shard] = outstanding[f.shard].saturating_sub(1);
    *in_flight_reqs = in_flight_reqs.saturating_sub(f.n_requests);
    match f.rx.recv() {
        Ok(ExecOutcome::Done(c)) => completions.extend(c),
        // The shard handed the batch back: degrade gracefully (count
        // the shed), never panic or drop.
        Ok(ExecOutcome::Shed { requests, .. }) => *exec_shed += requests.len(),
        Err(_) => {
            dead[f.shard] = true;
            *lost += f.n_requests;
        }
    }
}

/// The serving front end.
pub struct Router {
    shards: ShardSet,
    policy: BucketPolicy,
    max_pending: usize,
}

impl Router {
    /// Build a single-shard router over any execution backend.  The
    /// factory runs inside the executor thread (backends need not be
    /// `Send` — the constraint the non-`Send` PJRT client imposes), and
    /// the bucket grid comes from whatever shapes the backend
    /// discovers.
    pub fn with_backend<B, F>(make: F, cfg: &ServerConfig) -> Result<Self>
    where
        B: ExecBackend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let cache = match &cfg.cache_path {
            Some(p) => Some(crate::cache::TuningCache::open(p)?),
            None => None,
        };
        let executor = ExecutorHandle::spawn(make, cfg.idle_tuning, cache)?;
        let shards = ShardSet::from_handles(vec![executor], PlacementPolicy::default())?;
        Self::from_shard_set(shards, cfg)
    }

    /// Build a router over N executor shards, each running its own
    /// backend instance built by `make(shard_index)`.  One batcher
    /// feeds all shards; `placement` decides which shard runs each
    /// formed batch.  The persistent cache (when configured) is wired
    /// to shard 0 only — one writer, no cache-file races; siblings
    /// cold-tune to the same deterministic winners.
    pub fn with_shards<B, F>(
        make: F,
        shards: usize,
        placement: PlacementPolicy,
        cfg: &ServerConfig,
    ) -> Result<Self>
    where
        B: ExecBackend + 'static,
        F: Fn(usize) -> Result<B> + Send + Clone + 'static,
    {
        let cache = match &cfg.cache_path {
            Some(p) => Some(crate::cache::TuningCache::open(p)?),
            None => None,
        };
        let set = ShardSet::spawn(make, shards, placement, cfg.idle_tuning, cache)?;
        Self::from_shard_set(set, cfg)
    }

    fn from_shard_set(shards: ShardSet, cfg: &ServerConfig) -> Result<Self> {
        let pairs: Vec<(usize, usize)> = shards.shapes().iter().map(|&(b, s)| (s, b)).collect();
        if pairs.is_empty() {
            anyhow::bail!("backend discovered no compiled model shapes to serve");
        }
        let policy = BucketPolicy::new(pairs, cfg.max_wait_us);
        Ok(Router { shards, policy, max_pending: cfg.max_pending.max(1) })
    }

    /// Serve on the analytical sim backend — the default-build path
    /// (`portatune serve --platform a100|mi250|h100`): deterministic
    /// model latencies, no GPU/XLA toolchain.
    pub fn sim(backend: SimBackend, cfg: &ServerConfig) -> Result<Self> {
        Self::with_backend(move || Ok(backend), cfg)
    }

    /// Serve the manifest's real AOT artifacts through the PJRT CPU
    /// client (`--platform cpu-pjrt`, feature `pjrt`).
    #[cfg(feature = "pjrt")]
    pub fn pjrt(manifest: crate::runtime::Manifest, cfg: &ServerConfig) -> Result<Self> {
        Self::with_backend(move || super::backend::PjrtBackend::new(manifest), cfg)
    }

    /// The bucket policy the router batches under.
    pub fn policy(&self) -> &BucketPolicy {
        &self.policy
    }

    /// Handle to shard 0's executor thread (stats, tuning control) —
    /// the whole fleet on single-shard routers.
    pub fn executor(&self) -> &ExecutorHandle {
        &self.shards.handles()[0]
    }

    /// The executor shard set (per-shard handles, placement policy).
    pub fn shard_set(&self) -> &ShardSet {
        &self.shards
    }

    /// Force-drain every shard's background tuning queue (for
    /// before/after demos).
    pub fn finish_tuning(&self) -> Result<()> {
        self.shards.finish_tuning()
    }

    /// Replay `requests` as fast as the executors allow (all arrivals
    /// at trace time zero), batching per policy, and aggregate a
    /// report.
    pub fn serve_trace(&self, requests: Vec<Request>) -> Result<ServeReport> {
        let trace: Vec<TimedRequest> = requests.into_iter().map(TimedRequest::immediate).collect();
        self.serve_trace_timed(&trace)
    }

    /// Replay a timed trace (arrival order, timestamps nondecreasing —
    /// what [`super::loadgen::Scenario::generate`] produces).
    ///
    /// Timestamps drive the batcher's flush deadlines on a synthetic
    /// clock (`trace start + at_us`), so partial-batch flushes are a
    /// pure function of the trace, not of host scheduling.  Dispatch
    /// pipelines up to two batches per shard, reaps strictly in
    /// dispatch order, and never fails the replay on a dying shard:
    /// its requests are counted in [`ServeReport::lost`], its shard is
    /// marked dead, and the remaining shards keep serving.
    pub fn serve_trace_timed(&self, trace: &[TimedRequest]) -> Result<ServeReport> {
        let t0 = Instant::now();
        let base = t0;
        let n_shards = self.shards.len();
        let mut batcher = DynamicBatcher::new(self.policy.clone());
        let mut completions: Vec<Completion> = Vec::with_capacity(trace.len());
        let mut batches = 0usize;
        let mut sat_shed = 0usize; // admission-control sheds (router side)
        let mut exec_shed = 0usize; // typed executor sheds, this replay
        let mut lost = 0usize; // dead-shard losses, this replay
        let mut in_flight_reqs = 0usize;
        let mut outstanding = vec![0usize; n_shards];
        let mut shard_batches = vec![0usize; n_shards];
        let mut shard_requests = vec![0usize; n_shards];
        let mut dead = vec![false; n_shards];
        let mut in_flight: VecDeque<InFlight> = VecDeque::new();
        let max_in_flight = (2 * n_shards).max(2);
        let clock_before: Vec<f64> =
            self.shards.stats().iter().map(|s| s.clock_us).collect();

        // Form and dispatch every batch the batcher will release at
        // `now`, bounding the in-flight window and reaping FIFO.
        macro_rules! pump {
            ($now:expr, $drain:expr) => {
                while let Some(batch) = batcher.next_batch($now, $drain) {
                    let nreq = batch.requests.len();
                    let mut carry = Some(batch);
                    loop {
                        let Some(s) = self
                            .shards
                            .placement()
                            .place(carry.as_ref().unwrap(), &outstanding, &dead)
                        else {
                            // Every shard is dead: the batch has nowhere
                            // to go — count it, keep replaying.
                            lost += nreq;
                            break;
                        };
                        let (tx, rx) = std::sync::mpsc::channel();
                        let cmd = ExecutorCommand::Execute {
                            batch: carry.take().unwrap(),
                            enqueued_at: $now,
                            reply: tx,
                        };
                        match self.shards.handles()[s].tx.send(cmd) {
                            Ok(()) => {
                                batches += 1;
                                shard_batches[s] += 1;
                                shard_requests[s] += nreq;
                                outstanding[s] += 1;
                                in_flight_reqs += nreq;
                                in_flight.push_back(InFlight { shard: s, n_requests: nreq, rx });
                                while in_flight.len() >= max_in_flight {
                                    reap_oldest(
                                        &mut in_flight,
                                        &mut outstanding,
                                        &mut dead,
                                        &mut completions,
                                        &mut exec_shed,
                                        &mut lost,
                                        &mut in_flight_reqs,
                                    );
                                }
                                break;
                            }
                            Err(e) => {
                                // The shard's command channel is gone:
                                // mark it dead and re-place the batch on
                                // the remaining shards.
                                dead[s] = true;
                                match e.0 {
                                    ExecutorCommand::Execute { batch, .. } => carry = Some(batch),
                                    _ => {
                                        lost += nreq;
                                        break;
                                    }
                                }
                            }
                        }
                    }
                }
            };
        }

        for tr in trace {
            let now = base + Duration::from_micros(tr.at_us);
            // Advance the trace clock first: batches whose flush
            // deadline passed before this arrival leave *without* it,
            // exactly as they would have in real time.
            pump!(now, false);
            // Shared admission control: the bound covers queued AND
            // dispatched-but-unreaped requests across every shard.
            if batcher.pending() + in_flight_reqs >= self.max_pending {
                sat_shed += 1;
            } else {
                batcher.push(tr.req.clone(), now);
            }
            pump!(now, false);
        }
        let end = base + Duration::from_micros(trace.last().map(|t| t.at_us).unwrap_or(0));
        pump!(end, true);
        while !in_flight.is_empty() {
            reap_oldest(
                &mut in_flight,
                &mut outstanding,
                &mut dead,
                &mut completions,
                &mut exec_shed,
                &mut lost,
                &mut in_flight_reqs,
            );
        }
        let wall = t0.elapsed().as_secs_f64();

        let mut lat = Summary::new();
        let mut exec = Summary::new();
        let mut occupancy = Summary::new();
        let mut tokens = 0usize;
        for c in &completions {
            lat.record(c.latency_us);
            exec.record(c.exec_us);
            tokens += c.tokens;
            occupancy.record(1.0 / c.batch_size as f64);
        }
        let shard_stats = self.shards.stats();
        let shard_util: Vec<ShardUtil> = shard_stats
            .iter()
            .enumerate()
            .map(|(i, s)| ShardUtil {
                shard: i,
                batches: shard_batches[i],
                requests: shard_requests[i],
                busy_us: (s.clock_us - clock_before[i]).max(0.0),
            })
            .collect();
        let sim_makespan_us = shard_util.iter().map(|u| u.busy_us).fold(0.0, f64::max);
        let sim_throughput_rps = if sim_makespan_us > 0.0 {
            completions.len() as f64 / (sim_makespan_us / 1e6)
        } else {
            0.0
        };
        let mut executor = ExecutorStats::default();
        for s in &shard_stats {
            executor.absorb(s);
        }
        let mut faults = executor.faults.clone();
        faults.shed += sat_shed;
        Ok(ServeReport {
            requests: completions.len(),
            rejected: batcher.rejected.len(),
            batches,
            shed: exec_shed + sat_shed,
            lost,
            shards: n_shards,
            faults,
            wall_seconds: wall,
            throughput_rps: completions.len() as f64 / wall.max(1e-9),
            tokens_per_second: tokens as f64 / wall.max(1e-9),
            latency_p50_us: lat.p50(),
            latency_p95_us: lat.p95(),
            latency_p99_us: lat.p99(),
            exec_p50_us: exec.p50(),
            exec_mean_us: exec.mean(),
            mean_batch_occupancy: occupancy.mean(),
            executor,
            shard_stats,
            shard_util,
            sim_makespan_us,
            sim_throughput_rps,
        })
    }
}

/// Deterministic variable-length request trace (the paper's "sequences
/// within a batch have variable lengths, as in real-world online
/// inference"): log-normal token counts clamped to the largest bucket.
pub fn synth_trace(n: usize, max_tokens: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::seed_from(seed);
    (0..n as u64)
        .map(|id| {
            // ln N(mu, sigma) via Box-Muller on uniform draws.
            let z = rng.normal();
            let tokens = (48.0 * (0.6 * z).exp()).round().clamp(8.0, max_tokens as f64) as usize;
            Request { id, tokens }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::SimGpu;

    #[test]
    fn trace_is_deterministic_and_clamped() {
        let a = synth_trace(100, 256, 7);
        let b = synth_trace(100, 256, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|r| r.tokens >= 8 && r.tokens <= 256));
        // Variable lengths: not all equal.
        assert!(a.iter().any(|r| r.tokens != a[0].tokens));
    }

    #[test]
    fn trace_lengths_are_long_tailed() {
        let t = synth_trace(2000, 100_000, 3);
        let mean = t.iter().map(|r| r.tokens as f64).sum::<f64>() / t.len() as f64;
        let median = {
            let mut v: Vec<usize> = t.iter().map(|r| r.tokens).collect();
            v.sort();
            v[v.len() / 2] as f64
        };
        assert!(mean > median, "log-normal: mean {mean} > median {median}");
    }

    #[test]
    fn sim_router_serves_a_trace_end_to_end() {
        let cfg = ServerConfig { max_wait_us: 500, idle_tuning: false, ..Default::default() };
        let router = Router::sim(SimBackend::new(SimGpu::a100(), 5), &cfg).unwrap();
        let max_tokens = router.policy().seq_buckets.last().copied().unwrap();
        let report = router.serve_trace(synth_trace(12, max_tokens, 9)).unwrap();
        assert_eq!(report.requests, 12);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.lost, 0);
        assert_eq!(report.shards, 1);
        assert!(report.batches >= 1);
        assert!(report.throughput_rps > 0.0);
        assert!(report.exec_p50_us > 0.0);
        assert!(report.exec_mean_us > 0.0);
        assert!(report.latency_p99_us >= report.latency_p50_us);
        assert_eq!(report.executor.requests_served, 12);
        // Single shard: its replay busy time is the whole makespan, and
        // the modeled throughput figure exists (> 0) and is derived
        // from it.
        assert_eq!(report.shard_util.len(), 1);
        assert_eq!(report.shard_util[0].requests, 12);
        assert!(report.sim_makespan_us > 0.0);
        assert!(report.sim_throughput_rps > 0.0);
        assert!((report.shard_util[0].utilization(report.sim_makespan_us) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sim_router_bucket_grid_matches_backend_shapes() {
        let cfg = ServerConfig { max_wait_us: 500, idle_tuning: false, ..Default::default() };
        let backend = SimBackend::new(SimGpu::h100(), 0).with_shapes(&[(1, 128), (2, 128), (1, 256)]);
        let router = Router::sim(backend, &cfg).unwrap();
        assert_eq!(router.policy().seq_buckets, vec![128, 256]);
        assert_eq!(router.policy().max_batch(0), 2);
        assert_eq!(router.policy().max_batch(1), 1);
    }

    #[test]
    fn timed_replay_flushes_partial_batches_on_trace_time() {
        // Two requests in the same bucket, arriving further apart than
        // the flush deadline: the batcher must release the first as a
        // partial batch at the second's arrival time — on the synthetic
        // trace clock, not host time.
        let cfg = ServerConfig { max_wait_us: 1_000, idle_tuning: false, ..Default::default() };
        let backend = SimBackend::new(SimGpu::a100(), 5).with_shapes(&[(1, 128), (8, 128)]);
        let router = Router::sim(backend, &cfg).unwrap();
        let trace = vec![
            TimedRequest { at_us: 0, class: 0, req: Request { id: 0, tokens: 16 } },
            TimedRequest { at_us: 50_000, class: 0, req: Request { id: 1, tokens: 16 } },
        ];
        let report = router.serve_trace_timed(&trace).unwrap();
        assert_eq!(report.requests, 2);
        // Deadline expiry split them; a wall-clock replay of the same
        // two requests would pack both into one batch.
        assert_eq!(report.batches, 2);
    }
}
