//! The request router: trace replay, dynamic batching, reporting.
//!
//! `Router::serve_trace` replays a (deterministic, seeded) arrival
//! trace through the [`DynamicBatcher`](super::batcher::DynamicBatcher)
//! into the executor thread and aggregates a [`ServeReport`] — the
//! end-to-end driver behind `portatune serve` and
//! `examples/serve_attention.rs`.  The router is backend-agnostic: it
//! serves the always-available [`SimBackend`] ([`Router::sim`]) in
//! default builds and real PJRT artifacts (`Router::pjrt`, feature
//! `pjrt` — the link target only exists in pjrt builds) when the
//! toolchain exists.

use std::time::Instant;

use super::backend::{ExecBackend, SimBackend};
use super::batcher::{BucketPolicy, DynamicBatcher};
use super::executor::{ExecOutcome, ExecutorCommand, ExecutorHandle, ExecutorStats};
use super::{Completion, Request};
use crate::metrics::{FaultCounters, Summary};
use crate::util::rng::Rng;
use crate::Result;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Flush deadline for partial batches (µs).
    pub max_wait_us: u64,
    /// Enable Q4.4 idle-time background tuning.
    pub idle_tuning: bool,
    /// Persistent tuning-cache file (Q4.3): bucket winners survive
    /// restarts, so re-deployed servers start warm.
    pub cache_path: Option<std::path::PathBuf>,
    /// Admission-control bound: when this many requests are already
    /// queued in the batcher, new arrivals are shed (graceful
    /// degradation) instead of growing the queues without bound.
    pub max_pending: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_wait_us: 2_000, idle_tuning: true, cache_path: None, max_pending: 1024 }
    }
}

/// Aggregated serving statistics.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Requests completed.
    pub requests: usize,
    /// Requests rejected (no bucket fits them).
    pub rejected: usize,
    /// Batches executed (every batch sent to the executor; identical
    /// batch shapes are NOT collapsed).
    pub batches: usize,
    /// Wall-clock duration of the replay, seconds.
    pub wall_seconds: f64,
    /// Completed requests per second.
    pub throughput_rps: f64,
    /// Tokens served per second.
    pub tokens_per_second: f64,
    /// End-to-end latency median, µs.
    pub latency_p50_us: f64,
    /// End-to-end latency 95th percentile, µs.
    pub latency_p95_us: f64,
    /// End-to-end latency 99th percentile, µs.
    pub latency_p99_us: f64,
    /// Pure execution latency median, µs.
    pub exec_p50_us: f64,
    /// Pure execution latency mean, µs — the cold-vs-tuned acceptance
    /// metric (on the deterministic sim backend, tuned ≤ cold holds
    /// exactly: the tuned variant is the per-bucket argmin of the same
    /// model).
    pub exec_mean_us: f64,
    /// Mean fraction of each compiled batch doing useful work.
    pub mean_batch_occupancy: f64,
    /// Requests shed during THIS replay: executor-side typed sheds (no
    /// healthy variant) plus router-side admission-control sheds
    /// (batcher queues saturated past `max_pending`).
    pub shed: usize,
    /// Fault-tolerance counters: the executor's cumulative counters
    /// (injected faults, failures, retries, quarantines, executor-side
    /// sheds) plus this replay's router-side admission sheds.
    pub faults: FaultCounters,
    /// Executor-side counters (tuning, swaps, compiles).
    pub executor: ExecutorStats,
}

impl ServeReport {
    /// A digest of every *deterministic* field of the report — what the
    /// chaos bit-reproducibility tests pin.
    ///
    /// Determinism argument: on the sim backend all served latencies
    /// are model-derived and every injected fault is a pure function of
    /// the `FaultPlan` seed (see [`crate::serving::chaos`]), so request
    /// counts, batch counts, exec-latency aggregates, swap history,
    /// active variants and fault counters are bit-identical across
    /// replays.  Wall-clock-derived fields (`wall_seconds`, throughput,
    /// end-to-end latency percentiles) are host timing no seed
    /// controls, and are deliberately excluded.
    pub fn replay_digest(&self) -> String {
        use std::fmt::Write as _;
        let mut d = String::new();
        let _ = write!(
            d,
            "req={} rej={} shed={} batches={} exec_p50={:016x} exec_mean={:016x} occ={:016x}",
            self.requests,
            self.rejected,
            self.shed,
            self.batches,
            self.exec_p50_us.to_bits(),
            self.exec_mean_us.to_bits(),
            self.mean_batch_occupancy.to_bits(),
        );
        let e = &self.executor;
        let _ = write!(
            d,
            " warm={} bex={} served={} meas={} compiles={}",
            e.warm_started, e.batches_executed, e.requests_served, e.variants_measured, e.compiles
        );
        for s in &e.swaps {
            let _ = write!(d, " swap={:?}:{}->{}:{:016x}", s.shape, s.from, s.to, s.gain.to_bits());
        }
        let mut active: Vec<(&String, &String)> = e.active.iter().collect();
        active.sort();
        for (k, v) in active {
            let _ = write!(d, " active[{k}]={v}");
        }
        let mut active_us: Vec<(&String, &f64)> = e.active_us.iter().collect();
        active_us.sort_by(|a, b| a.0.cmp(b.0));
        for (k, v) in active_us {
            let _ = write!(d, " us[{k}]={:016x}", v.to_bits());
        }
        let _ = write!(d, " faults={:?}", self.faults);
        d
    }
}

/// The serving front end.
pub struct Router {
    executor: ExecutorHandle,
    policy: BucketPolicy,
    max_pending: usize,
}

impl Router {
    /// Build a router over any execution backend.  The factory runs
    /// inside the executor thread (backends need not be `Send` — the
    /// constraint the non-`Send` PJRT client imposes), and the bucket
    /// grid comes from whatever shapes the backend discovers.
    pub fn with_backend<B, F>(make: F, cfg: &ServerConfig) -> Result<Self>
    where
        B: ExecBackend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let cache = match &cfg.cache_path {
            Some(p) => Some(crate::cache::TuningCache::open(p)?),
            None => None,
        };
        let executor = ExecutorHandle::spawn(make, cfg.idle_tuning, cache)?;
        let pairs: Vec<(usize, usize)> = executor.shapes.iter().map(|&(b, s)| (s, b)).collect();
        if pairs.is_empty() {
            anyhow::bail!("backend discovered no compiled model shapes to serve");
        }
        let policy = BucketPolicy::new(pairs, cfg.max_wait_us);
        Ok(Router { executor, policy, max_pending: cfg.max_pending.max(1) })
    }

    /// Serve on the analytical sim backend — the default-build path
    /// (`portatune serve --platform a100|mi250|h100`): deterministic
    /// model latencies, no GPU/XLA toolchain.
    pub fn sim(backend: SimBackend, cfg: &ServerConfig) -> Result<Self> {
        Self::with_backend(move || Ok(backend), cfg)
    }

    /// Serve the manifest's real AOT artifacts through the PJRT CPU
    /// client (`--platform cpu-pjrt`, feature `pjrt`).
    #[cfg(feature = "pjrt")]
    pub fn pjrt(manifest: crate::runtime::Manifest, cfg: &ServerConfig) -> Result<Self> {
        Self::with_backend(move || super::backend::PjrtBackend::new(manifest), cfg)
    }

    /// The bucket policy the router batches under.
    pub fn policy(&self) -> &BucketPolicy {
        &self.policy
    }

    /// Handle to the executor thread (stats, tuning control).
    pub fn executor(&self) -> &ExecutorHandle {
        &self.executor
    }

    /// Force-drain the background tuning queue (for before/after demos).
    pub fn finish_tuning(&self) -> Result<()> {
        self.executor.finish_tuning()
    }

    /// Replay `requests` as fast as the executor allows, batching per
    /// policy, and aggregate a report.
    pub fn serve_trace(&self, requests: Vec<Request>) -> Result<ServeReport> {
        let t0 = Instant::now();
        let mut batcher = DynamicBatcher::new(self.policy.clone());
        let total = requests.len();
        let mut completions: Vec<Completion> = Vec::with_capacity(total);
        let mut batches = 0usize;

        let mut pending = std::collections::VecDeque::from(requests);
        let mut sat_shed = 0usize; // admission-control sheds (router side)
        let mut exec_shed = 0usize; // typed executor sheds, this replay
        let enqueued_at = Instant::now();
        while !pending.is_empty() || batcher.pending() > 0 {
            // Admit a burst of arrivals.
            for _ in 0..8 {
                if let Some(r) = pending.pop_front() {
                    if batcher.pending() >= self.max_pending {
                        // Saturated: shed the arrival instead of
                        // queueing without bound.
                        sat_shed += 1;
                        continue;
                    }
                    batcher.push(r, Instant::now());
                } else {
                    break;
                }
            }
            let drain = pending.is_empty();
            while let Some(batch) = batcher.next_batch(Instant::now(), drain) {
                let (tx, rx) = std::sync::mpsc::channel();
                self.executor
                    .tx
                    .send(ExecutorCommand::Execute { batch, enqueued_at, reply: tx })
                    .map_err(|_| anyhow::anyhow!("executor gone"))?;
                batches += 1;
                match rx.recv()? {
                    ExecOutcome::Done(c) => completions.extend(c),
                    // The executor handed the batch back: degrade
                    // gracefully (count the shed), never panic or drop.
                    ExecOutcome::Shed { requests, .. } => exec_shed += requests.len(),
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        let mut lat = Summary::new();
        let mut exec = Summary::new();
        let mut occupancy = Summary::new();
        let mut tokens = 0usize;
        for c in &completions {
            lat.record(c.latency_us);
            exec.record(c.exec_us);
            tokens += c.tokens;
            occupancy.record(1.0 / c.batch_size as f64);
        }
        let executor = self.executor.stats()?;
        let mut faults = executor.faults.clone();
        faults.shed += sat_shed;
        Ok(ServeReport {
            requests: completions.len(),
            rejected: batcher.rejected.len(),
            batches,
            shed: exec_shed + sat_shed,
            faults,
            wall_seconds: wall,
            throughput_rps: completions.len() as f64 / wall.max(1e-9),
            tokens_per_second: tokens as f64 / wall.max(1e-9),
            latency_p50_us: lat.p50(),
            latency_p95_us: lat.p95(),
            latency_p99_us: lat.p99(),
            exec_p50_us: exec.p50(),
            exec_mean_us: exec.mean(),
            mean_batch_occupancy: occupancy.mean(),
            executor,
        })
    }
}

/// Deterministic variable-length request trace (the paper's "sequences
/// within a batch have variable lengths, as in real-world online
/// inference"): log-normal token counts clamped to the largest bucket.
pub fn synth_trace(n: usize, max_tokens: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::seed_from(seed);
    (0..n as u64)
        .map(|id| {
            // ln N(mu, sigma) via Box-Muller on uniform draws.
            let z = rng.normal();
            let tokens = (48.0 * (0.6 * z).exp()).round().clamp(8.0, max_tokens as f64) as usize;
            Request { id, tokens }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::SimGpu;

    #[test]
    fn trace_is_deterministic_and_clamped() {
        let a = synth_trace(100, 256, 7);
        let b = synth_trace(100, 256, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|r| r.tokens >= 8 && r.tokens <= 256));
        // Variable lengths: not all equal.
        assert!(a.iter().any(|r| r.tokens != a[0].tokens));
    }

    #[test]
    fn trace_lengths_are_long_tailed() {
        let t = synth_trace(2000, 100_000, 3);
        let mean = t.iter().map(|r| r.tokens as f64).sum::<f64>() / t.len() as f64;
        let median = {
            let mut v: Vec<usize> = t.iter().map(|r| r.tokens).collect();
            v.sort();
            v[v.len() / 2] as f64
        };
        assert!(mean > median, "log-normal: mean {mean} > median {median}");
    }

    #[test]
    fn sim_router_serves_a_trace_end_to_end() {
        let cfg = ServerConfig { max_wait_us: 500, idle_tuning: false, ..Default::default() };
        let router = Router::sim(SimBackend::new(SimGpu::a100(), 5), &cfg).unwrap();
        let max_tokens = router.policy().seq_buckets.last().copied().unwrap();
        let report = router.serve_trace(synth_trace(12, max_tokens, 9)).unwrap();
        assert_eq!(report.requests, 12);
        assert_eq!(report.rejected, 0);
        assert!(report.batches >= 1);
        assert!(report.throughput_rps > 0.0);
        assert!(report.exec_p50_us > 0.0);
        assert!(report.exec_mean_us > 0.0);
        assert!(report.latency_p99_us >= report.latency_p50_us);
        assert_eq!(report.executor.requests_served, 12);
    }

    #[test]
    fn sim_router_bucket_grid_matches_backend_shapes() {
        let cfg = ServerConfig { max_wait_us: 500, idle_tuning: false, ..Default::default() };
        let backend = SimBackend::new(SimGpu::h100(), 0).with_shapes(&[(1, 128), (2, 128), (1, 256)]);
        let router = Router::sim(backend, &cfg).unwrap();
        assert_eq!(router.policy().seq_buckets, vec![128, 256]);
        assert_eq!(router.policy().max_batch(0), 2);
        assert_eq!(router.policy().max_batch(1), 1);
    }
}
