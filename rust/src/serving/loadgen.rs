//! Scenario workload generator: seeded, replayable traffic traces.
//!
//! The serving plane's scaling claims only mean something under
//! production-shaped load, so scenarios are first-class: a [`Scenario`]
//! is an [`ArrivalProcess`] (when requests arrive) crossed with a set of
//! [`TrafficClass`]es (who sends them and how long their sequences are,
//! via [`SeqLenMix`]).  `generate` expands a scenario into a
//! [`TimedRequest`] trace — a pure function of `(scenario, n, seed)`, so
//! the same trace can be replayed through any shard count or placement
//! policy and compared bit-for-bit (`ServeReport::replay_digest`).
//!
//! This is the LLMServingTuner workflow's "simulate the benchmark" leg
//! (SNIPPETS.md §1): the generator supplies the benchmark, the
//! `SimBackend` virtual clock supplies the simulation, and the tuner
//! closes the loop.

use crate::serving::Request;
use crate::util::rng::Rng;
use crate::workload::SeqLenMix;

/// One request with an arrival timestamp on the scenario's trace clock.
///
/// `at_us` is microseconds since trace start; traces are generated in
/// nondecreasing timestamp order.  `class` indexes the scenario's
/// traffic classes (0 for single-class and legacy traces).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimedRequest {
    /// Arrival time, µs since trace start (nondecreasing within a trace).
    pub at_us: u64,
    /// Index into the generating scenario's [`TrafficClass`] list.
    pub class: usize,
    /// The request itself.
    pub req: Request,
}

impl TimedRequest {
    /// Wrap a plain request as arriving at trace start (class 0) — how
    /// legacy untimed traces enter the timed serving path.
    pub fn immediate(req: Request) -> Self {
        TimedRequest { at_us: 0, class: 0, req }
    }
}

/// When requests arrive: the time axis of a scenario.
///
/// All processes are sampled with the scenario's seeded [`Rng`] —
/// inter-arrival gaps for the stochastic processes are exponential
/// draws against the instantaneous rate, i.e. an (inhomogeneous)
/// Poisson process — so arrival times are deterministic per seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Clockwork arrivals at a fixed rate: one request every `1/rps`
    /// seconds, no randomness on the time axis.
    Steady {
        /// Arrival rate, requests per second.
        rps: f64,
    },
    /// Poisson arrivals whose rate square-waves between a quiet base
    /// and a burst: the first `burst_frac` of every `period_s` window
    /// runs at `burst_rps`, the rest at `base_rps`.  This is the
    /// scenario saturation and scaling tests lean on.
    PoissonBurst {
        /// Quiet-phase arrival rate, requests per second.
        base_rps: f64,
        /// Burst-phase arrival rate, requests per second.
        burst_rps: f64,
        /// Burst cycle length, seconds.
        period_s: f64,
        /// Fraction of each period spent bursting, in (0, 1).
        burst_frac: f64,
    },
    /// Poisson arrivals whose rate follows a raised cosine between
    /// trough and peak over `period_s` — a compressed day/night cycle.
    DiurnalRamp {
        /// Minimum (night-time) arrival rate, requests per second.
        trough_rps: f64,
        /// Maximum (peak-hour) arrival rate, requests per second.
        peak_rps: f64,
        /// Full cycle length, seconds.
        period_s: f64,
    },
}

impl ArrivalProcess {
    /// Instantaneous arrival rate at trace time `t_s` (seconds).
    pub fn rate_at(&self, t_s: f64) -> f64 {
        match *self {
            ArrivalProcess::Steady { rps } => rps,
            ArrivalProcess::PoissonBurst { base_rps, burst_rps, period_s, burst_frac } => {
                let phase = (t_s / period_s).fract();
                if phase < burst_frac {
                    burst_rps
                } else {
                    base_rps
                }
            }
            ArrivalProcess::DiurnalRamp { trough_rps, peak_rps, period_s } => {
                let phase = (t_s / period_s).fract();
                let wave = 0.5 * (1.0 - (2.0 * std::f64::consts::PI * phase).cos());
                trough_rps + (peak_rps - trough_rps) * wave
            }
        }
    }

    /// Draw the gap (µs) to the next arrival after trace time `t_us`.
    fn next_gap_us(&self, t_us: f64, rng: &mut Rng) -> f64 {
        let rate = self.rate_at(t_us / 1e6).max(1e-9);
        match self {
            // Clockwork: exactly 1/rate apart, no draw consumed.
            ArrivalProcess::Steady { .. } => 1e6 / rate,
            // Exponential inter-arrival at the current rate.  u < 1 so
            // -ln(1-u) is finite and >= 0, keeping timestamps monotone.
            _ => {
                let u = rng.f64();
                -(1.0 - u).ln() / rate * 1e6
            }
        }
    }

    /// Short human name for the catalog.
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Steady { .. } => "steady",
            ArrivalProcess::PoissonBurst { .. } => "poisson-burst",
            ArrivalProcess::DiurnalRamp { .. } => "diurnal-ramp",
        }
    }
}

/// One tenant / traffic class inside a scenario: a share of the traffic
/// with its own sequence-length mix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrafficClass {
    /// Class name (reports, per-class accounting).
    pub name: &'static str,
    /// Relative traffic share (weights are normalized over the
    /// scenario's classes; they need not sum to 1).
    pub weight: f64,
    /// Sequence-length distribution of this class's requests.
    pub mix: SeqLenMix,
}

/// A named, fully seeded traffic scenario: arrival process × traffic
/// classes.  See [`Scenario::catalog`] for the built-ins.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Catalog name (`portatune serve --scenario NAME`).
    pub name: &'static str,
    /// One-line description for the catalog listing.
    pub description: &'static str,
    /// When requests arrive.
    pub arrivals: ArrivalProcess,
    /// Who sends them, and with what sequence lengths.
    pub classes: Vec<TrafficClass>,
}

impl Scenario {
    /// The built-in scenario catalog: `steady`, `burst`, `diurnal`.
    pub fn catalog() -> Vec<Scenario> {
        vec![
            Scenario {
                name: "steady",
                description: "clockwork arrivals, single class, legacy long-tailed lengths",
                arrivals: ArrivalProcess::Steady { rps: 400.0 },
                classes: vec![TrafficClass {
                    name: "standard",
                    weight: 1.0,
                    mix: SeqLenMix::LogNormal { median: 48.0, sigma: 0.6 },
                }],
            },
            Scenario {
                name: "burst",
                description: "Poisson bursts (50→2000 rps), interactive decode + batch prefill tenants",
                arrivals: ArrivalProcess::PoissonBurst {
                    base_rps: 50.0,
                    burst_rps: 2000.0,
                    period_s: 2.0,
                    burst_frac: 0.25,
                },
                classes: vec![
                    TrafficClass { name: "interactive", weight: 0.7, mix: SeqLenMix::DecodeHeavy },
                    TrafficClass { name: "batch", weight: 0.3, mix: SeqLenMix::PrefillHeavy },
                ],
            },
            Scenario {
                name: "diurnal",
                description: "raised-cosine day/night ramp (20→800 rps), three tenants incl. bimodal background",
                arrivals: ArrivalProcess::DiurnalRamp {
                    trough_rps: 20.0,
                    peak_rps: 800.0,
                    period_s: 60.0,
                },
                classes: vec![
                    TrafficClass { name: "interactive", weight: 0.5, mix: SeqLenMix::DecodeHeavy },
                    TrafficClass { name: "batch", weight: 0.2, mix: SeqLenMix::PrefillHeavy },
                    TrafficClass {
                        name: "background",
                        weight: 0.3,
                        mix: SeqLenMix::Bimodal { short_frac: 0.6 },
                    },
                ],
            },
        ]
    }

    /// Look up a catalog scenario by name.
    pub fn by_name(name: &str) -> Option<Scenario> {
        Self::catalog().into_iter().find(|s| s.name == name)
    }

    /// Comma-separated catalog names (CLI error messages).
    pub fn names() -> String {
        Self::catalog().iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
    }

    /// Expand the scenario into `n` timed requests with sequence
    /// lengths clamped to `[SeqLenMix::MIN_TOKENS, max_tokens]`.
    ///
    /// Pure in `(self, n, max_tokens, seed)`: ids are sequential,
    /// timestamps nondecreasing, and every random draw comes from one
    /// seeded [`Rng`], so two calls with equal inputs return equal
    /// traces — the property the replay-digest tests pin.
    pub fn generate(&self, n: usize, max_tokens: usize, seed: u64) -> Vec<TimedRequest> {
        assert!(!self.classes.is_empty(), "scenario {} has no traffic classes", self.name);
        let mut rng = Rng::seed_from(seed);
        let total_weight: f64 = self.classes.iter().map(|c| c.weight).sum();
        let mut t_us = 0.0f64;
        let mut out = Vec::with_capacity(n);
        for id in 0..n as u64 {
            t_us += self.arrivals.next_gap_us(t_us, &mut rng);
            // Weighted class draw against the cumulative weights.
            let mut u = rng.f64() * total_weight;
            let mut class = self.classes.len() - 1;
            for (i, c) in self.classes.iter().enumerate() {
                if u < c.weight {
                    class = i;
                    break;
                }
                u -= c.weight;
            }
            let tokens = self.classes[class].mix.sample(&mut rng, max_tokens);
            out.push(TimedRequest { at_us: t_us as u64, class, req: Request { id, tokens } });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_names_resolve() {
        for sc in Scenario::catalog() {
            let found = Scenario::by_name(sc.name).expect("catalog name must resolve");
            assert_eq!(found, sc);
        }
        assert!(Scenario::by_name("nope").is_none());
        assert!(Scenario::names().contains("burst"));
    }

    #[test]
    fn burst_rate_square_waves() {
        let p = ArrivalProcess::PoissonBurst {
            base_rps: 10.0,
            burst_rps: 100.0,
            period_s: 2.0,
            burst_frac: 0.25,
        };
        assert_eq!(p.rate_at(0.1), 100.0); // in the burst window
        assert_eq!(p.rate_at(1.0), 10.0); // quiet phase
        assert_eq!(p.rate_at(2.1), 100.0); // next period's burst
    }

    #[test]
    fn diurnal_rate_spans_trough_to_peak() {
        let p = ArrivalProcess::DiurnalRamp { trough_rps: 20.0, peak_rps: 800.0, period_s: 60.0 };
        assert!((p.rate_at(0.0) - 20.0).abs() < 1e-9);
        assert!((p.rate_at(30.0) - 800.0).abs() < 1e-9);
        assert!((p.rate_at(60.0) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn steady_trace_is_clockwork() {
        let sc = Scenario::by_name("steady").unwrap();
        let trace = sc.generate(10, 512, 1);
        // 400 rps → one arrival every 2500 µs, exactly.
        for (i, t) in trace.iter().enumerate() {
            assert_eq!(t.at_us, 2500 * (i as u64 + 1));
        }
    }

    #[test]
    fn generate_is_seed_deterministic_with_monotone_times() {
        for sc in Scenario::catalog() {
            let a = sc.generate(200, 512, 77);
            let b = sc.generate(200, 512, 77);
            assert_eq!(a, b, "{} must be replayable", sc.name);
            assert!(a.windows(2).all(|w| w[0].at_us <= w[1].at_us), "{}", sc.name);
            assert!(a.iter().enumerate().all(|(i, t)| t.req.id == i as u64));
        }
    }
}
