//! Deterministic fault injection for the serving plane.
//!
//! [`ChaosBackend`] decorates any [`ExecBackend`] and injects faults
//! into `discover`/`compile`/`execute`/`measure` according to a seeded
//! [`FaultPlan`]: transient errors, persistent compile failures,
//! latency outliers, and stalls (modeled as timeout errors, so a
//! "hung" measure is bounded by the plan's stall budget instead of
//! blocking the executor thread).
//!
//! **Determinism.**  The fate of every injected call is a pure function
//! of `(plan.seed, verb, shape, variant fingerprint, attempt ordinal)`
//! — each call seeds a fresh [`Rng`] from that tuple and takes a single
//! draw.  Fates therefore do not depend on call interleaving across
//! buckets, and two runs with the same plan seed inject *exactly* the
//! same faults at the same points: chaos runs can be pinned
//! bit-for-bit in tests.  The attempt ordinal is per
//! `(verb, shape, variant)`, so a retry of a failed call re-rolls its
//! fate (transient faults clear under retry) while a *persistent*
//! compile failure deliberately ignores the ordinal (it never clears).
//!
//! **Clean calls pass values through untouched.**  When a call's fate
//! is clean, the inner backend's result is returned bit-for-bit — a
//! chaos run that converges to a winner converges to the *same* winner
//! as the fault-free run, which is what the convergence tests pin.
//! Injected latency outliers spike exactly one of the `iters`
//! measurement samples and aggregate with [`median`], so with
//! `iters >= 3` a single spike cannot move the reported latency at all
//! (see `ISSUE 6`'s outlier-robustness satellite).

use std::collections::HashMap;

use anyhow::anyhow;

use super::backend::{ExecBackend, ExecHandle, ShapeKey, VariantDesc};
use crate::metrics::median;
use crate::util::rng::Rng;
use crate::workload::Workload;
use crate::Result;

/// Per-verb transient-fault probabilities (each in [0, 1]).
#[derive(Debug, Clone, Copy, Default)]
pub struct VerbRates {
    /// P(transient fault) per `discover` call.
    pub discover: f64,
    /// P(transient fault) per `compile` call.
    pub compile: f64,
    /// P(transient fault) per `execute` call.
    pub execute: f64,
    /// P(transient fault) per `measure` call.
    pub measure: f64,
}

impl VerbRates {
    /// The same rate for every verb.
    pub fn uniform(rate: f64) -> Self {
        VerbRates { discover: rate, compile: rate, execute: rate, measure: rate }
    }

    fn of(&self, verb: Verb) -> f64 {
        match verb {
            Verb::Discover => self.discover,
            Verb::Compile => self.compile,
            Verb::Execute => self.execute,
            Verb::Measure => self.measure,
        }
    }
}

/// A seeded fault schedule: what [`ChaosBackend`] injects, and how
/// often.  All rates are probabilities per call; the default plan is
/// fully disabled (every rate 0).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Seed of the fault schedule.  Same seed ⇒ bit-identical faults.
    pub seed: u64,
    /// Transient-error rates per verb.  Transient faults re-roll on
    /// retry, so retry-with-backoff clears them.
    pub transient: VerbRates,
    /// P(persistent compile failure) per (shape, variant).  Persistent
    /// failures do NOT re-roll on retry — the variant never compiles,
    /// modeling a toolchain bug or a missing artifact.
    pub compile_fail_rate: f64,
    /// P(latency outlier) per `measure` call.  An outlier spikes one of
    /// the call's measurement samples by [`FaultPlan::outlier_mult`].
    pub outlier_rate: f64,
    /// Multiplier applied to the spiked sample of an outlier fault.
    pub outlier_mult: f64,
    /// P(stall) per `execute`/`measure` call.  A stall is surfaced as a
    /// timeout error after [`FaultPlan::stall_us`] modeled µs — the
    /// call is bounded, never hung.
    pub stall_rate: f64,
    /// Modeled duration of a stall before its timeout fires, µs.
    pub stall_us: f64,
    /// Stop injecting after this many faults (a "brown-out" that
    /// heals), letting tests drive the quarantine → cooldown → re-probe
    /// → recovery lifecycle deterministically.  `None` = never heals.
    pub max_injected: Option<usize>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            transient: VerbRates::default(),
            compile_fail_rate: 0.0,
            outlier_rate: 0.0,
            outlier_mult: 25.0,
            stall_rate: 0.0,
            stall_us: 50_000.0,
            max_injected: None,
        }
    }
}

impl FaultPlan {
    /// A plan that injects nothing (all rates zero).
    pub fn disabled() -> Self {
        FaultPlan::default()
    }

    /// The CLI's `--chaos <seed> --fault-rate <p>` plan: transient
    /// faults on every verb at `rate`, latency outliers at `rate`, and
    /// persistent compile failures + stalls at `rate / 4`.
    pub fn uniform(seed: u64, rate: f64) -> Self {
        FaultPlan {
            seed,
            transient: VerbRates::uniform(rate),
            compile_fail_rate: rate / 4.0,
            outlier_rate: rate,
            stall_rate: rate / 4.0,
            ..FaultPlan::default()
        }
    }

    /// Does this plan inject anything at all?
    pub fn is_active(&self) -> bool {
        let t = self.transient;
        t.discover > 0.0
            || t.compile > 0.0
            || t.execute > 0.0
            || t.measure > 0.0
            || self.compile_fail_rate > 0.0
            || self.outlier_rate > 0.0
            || self.stall_rate > 0.0
    }
}

/// What [`ChaosBackend`] has injected so far, by kind.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosCounters {
    /// Transient errors injected.
    pub transient: usize,
    /// Persistent compile failures injected (one per failing attempt).
    pub compile_persistent: usize,
    /// Latency outliers injected into `measure` samples.
    pub outliers: usize,
    /// Stalls injected (surfaced as timeout errors).
    pub stalls: usize,
}

impl ChaosCounters {
    /// Total faults injected.
    pub fn total(&self) -> usize {
        self.transient + self.compile_persistent + self.outliers + self.stalls
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Verb {
    Discover,
    Compile,
    Execute,
    Measure,
}

impl Verb {
    fn tag(self) -> u64 {
        match self {
            Verb::Discover => 1,
            Verb::Compile => 2,
            Verb::Execute => 3,
            Verb::Measure => 4,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Verb::Discover => "discover",
            Verb::Compile => "compile",
            Verb::Execute => "execute",
            Verb::Measure => "measure",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fate {
    Clean,
    Transient,
    Stall,
    Outlier,
}

/// Mix a call's identity into a seed: order-independent, so a call's
/// fate does not depend on what other buckets did before it.
fn mix(verb: u64, shape: ShapeKey, fp: u64, attempt: u64) -> u64 {
    let shape64 = ((shape.0 as u64) << 32) | shape.1 as u64;
    shape64
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ fp.rotate_left(17)
        ^ verb.wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ attempt.wrapping_mul(0x94D0_49BB_1331_11EB)
}

/// Fault-injecting decorator over any [`ExecBackend`].
///
/// Wrap a backend and pass the result to the router/executor exactly
/// like the inner backend — the executor's retry, circuit-breaker and
/// fallback machinery then has something real to push against.  See the
/// module docs for the determinism argument.
pub struct ChaosBackend<B: ExecBackend> {
    inner: B,
    plan: FaultPlan,
    /// Attempt ordinals per (verb, shape, variant fingerprint): the
    /// re-roll axis that lets retries clear transient faults.
    attempts: HashMap<(u64, ShapeKey, u64), u64>,
    /// Variant fingerprint per issued handle, so execute/measure fates
    /// key on the variant identity rather than the opaque handle.
    handle_fp: HashMap<ExecHandle, u64>,
    counters: ChaosCounters,
    /// Modeled µs spent inside injected stalls before their timeouts
    /// fired (accounting only; nothing sleeps).
    stall_clock_us: f64,
}

impl<B: ExecBackend> ChaosBackend<B> {
    /// Wrap `inner` with the fault schedule `plan`.
    pub fn new(inner: B, plan: FaultPlan) -> Self {
        ChaosBackend {
            inner,
            plan,
            attempts: HashMap::new(),
            handle_fp: HashMap::new(),
            counters: ChaosCounters::default(),
            stall_clock_us: 0.0,
        }
    }

    /// What has been injected so far.
    pub fn counters(&self) -> &ChaosCounters {
        &self.counters
    }

    /// Modeled µs spent inside injected stalls.
    pub fn stall_clock_us(&self) -> f64 {
        self.stall_clock_us
    }

    /// The inner backend.
    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Has the brown-out healed (injection budget exhausted)?
    fn healed(&self) -> bool {
        matches!(self.plan.max_injected, Some(max) if self.counters.total() >= max)
    }

    /// Roll this call's fate.  One draw per call, freshly seeded from
    /// the call's identity tuple (see module docs).
    fn fate(&mut self, verb: Verb, shape: ShapeKey, fp: u64) -> Fate {
        if self.healed() {
            return Fate::Clean;
        }
        let key = (verb.tag(), shape, fp);
        let attempt = *self
            .attempts
            .entry(key)
            .and_modify(|a| *a += 1)
            .or_insert(0);
        let r = Rng::seed_from(self.plan.seed ^ mix(verb.tag(), shape, fp, attempt)).f64();
        let t = self.plan.transient.of(verb);
        let s = if matches!(verb, Verb::Execute | Verb::Measure) { self.plan.stall_rate } else { 0.0 };
        let o = if verb == Verb::Measure { self.plan.outlier_rate } else { 0.0 };
        if r < t {
            Fate::Transient
        } else if r < t + s {
            Fate::Stall
        } else if r < t + s + o {
            Fate::Outlier
        } else {
            Fate::Clean
        }
    }

    /// Is (shape, variant) scheduled to *persistently* fail to compile?
    /// Attempt-independent: the same variant fails on every retry.
    fn compile_persistently_fails(&self, shape: ShapeKey, fp: u64) -> bool {
        if self.plan.compile_fail_rate <= 0.0 || self.healed() {
            return false;
        }
        // Distinct salt + fixed attempt keep this draw disjoint from
        // the transient schedule.
        let r = Rng::seed_from(
            self.plan.seed ^ mix(Verb::Compile.tag(), shape, fp ^ 0xC0FF_EE00_D15E_A5ED, u64::MAX),
        )
        .f64();
        r < self.plan.compile_fail_rate
    }

    fn transient_err(&mut self, verb: Verb, shape: ShapeKey) -> anyhow::Error {
        self.counters.transient += 1;
        anyhow!("injected transient fault: {} on b{}s{}", verb.name(), shape.0, shape.1)
    }

    fn stall_err(&mut self, verb: Verb, shape: ShapeKey) -> anyhow::Error {
        self.counters.stalls += 1;
        self.stall_clock_us += self.plan.stall_us;
        anyhow!(
            "injected stall: {} on b{}s{} timed out after {:.0}µs",
            verb.name(),
            shape.0,
            shape.1,
            self.plan.stall_us
        )
    }
}

impl<B: ExecBackend> ExecBackend for ChaosBackend<B> {
    fn platform(&self) -> String {
        self.inner.platform()
    }

    fn discover(&mut self) -> Result<Vec<(ShapeKey, Vec<VariantDesc>)>> {
        match self.fate(Verb::Discover, (0, 0), 0) {
            Fate::Clean => self.inner.discover(),
            _ => Err(self.transient_err(Verb::Discover, (0, 0))),
        }
    }

    fn bucket_workload(&self, shape: ShapeKey) -> Workload {
        self.inner.bucket_workload(shape)
    }

    fn compile(&mut self, shape: ShapeKey, variant: &VariantDesc) -> Result<ExecHandle> {
        let fp = variant.config.fingerprint();
        if self.compile_persistently_fails(shape, fp) {
            self.counters.compile_persistent += 1;
            return Err(anyhow!(
                "injected persistent compile failure: {} on b{}s{}",
                variant.artifact_id,
                shape.0,
                shape.1
            ));
        }
        match self.fate(Verb::Compile, shape, fp) {
            Fate::Clean => {
                let h = self.inner.compile(shape, variant)?;
                self.handle_fp.insert(h, fp);
                Ok(h)
            }
            _ => Err(self.transient_err(Verb::Compile, shape)),
        }
    }

    fn execute(&mut self, handle: ExecHandle, shape: ShapeKey) -> Result<f64> {
        let fp = self.handle_fp.get(&handle).copied().unwrap_or(handle as u64);
        match self.fate(Verb::Execute, shape, fp) {
            // Clean executes pass the inner latency through UNTOUCHED —
            // serving latencies of a surviving chaos run are
            // bit-identical to the fault-free run's.
            Fate::Clean | Fate::Outlier => self.inner.execute(handle, shape),
            Fate::Transient => Err(self.transient_err(Verb::Execute, shape)),
            Fate::Stall => Err(self.stall_err(Verb::Execute, shape)),
        }
    }

    fn measure(&mut self, handle: ExecHandle, shape: ShapeKey, warmup: usize, iters: usize) -> Result<f64> {
        let fp = self.handle_fp.get(&handle).copied().unwrap_or(handle as u64);
        match self.fate(Verb::Measure, shape, fp) {
            Fate::Clean => self.inner.measure(handle, shape, warmup, iters),
            Fate::Transient => Err(self.transient_err(Verb::Measure, shape)),
            Fate::Stall => Err(self.stall_err(Verb::Measure, shape)),
            Fate::Outlier => {
                // Spike exactly one of the call's samples; the median
                // aggregate absorbs it bit-for-bit when iters >= 3.
                self.counters.outliers += 1;
                let base = self.inner.measure(handle, shape, warmup, iters)?;
                let mut samples = vec![base; iters.max(1)];
                samples[0] = base * self.plan.outlier_mult;
                Ok(median(&samples))
            }
        }
    }

    fn prefetch(&mut self, upcoming: &[ShapeKey]) {
        self.inner.prefetch(upcoming);
    }

    fn release(&mut self, shape: ShapeKey) {
        self.inner.release(shape);
    }

    fn release_all(&mut self) {
        self.inner.release_all();
    }

    fn backoff(&mut self, us: f64) {
        // Delegate so virtual-clock backends keep sim tests instant.
        self.inner.backoff(us);
    }

    fn injected_faults(&self) -> usize {
        self.counters.total()
    }

    fn virtual_clock_us(&self) -> f64 {
        // Injected stalls are modeled time the inner clock never saw.
        self.inner.virtual_clock_us() + self.stall_clock_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::model::SimGpu;
    use crate::serving::SimBackend;

    fn compiled_default(chaos: &mut ChaosBackend<SimBackend>, shape: ShapeKey) -> ExecHandle {
        let universe = chaos.discover().unwrap();
        let (_, vs) = universe.iter().find(|(k, _)| *k == shape).unwrap();
        chaos.compile(shape, &vs[0]).unwrap()
    }

    #[test]
    fn disabled_plan_is_bitwise_transparent() {
        let shape = (4, 256);
        let mut clean = SimBackend::new(SimGpu::a100(), 1);
        let universe = clean.discover().unwrap();
        let (_, vs) = universe.iter().find(|(k, _)| *k == shape).unwrap();
        let hc = clean.compile(shape, &vs[0]).unwrap();
        let want_m = clean.measure(hc, shape, 1, 3).unwrap();
        let want_e = clean.execute(hc, shape).unwrap();

        let mut chaos = ChaosBackend::new(SimBackend::new(SimGpu::a100(), 1), FaultPlan::disabled());
        let h = compiled_default(&mut chaos, shape);
        assert_eq!(chaos.measure(h, shape, 1, 3).unwrap().to_bits(), want_m.to_bits());
        assert_eq!(chaos.execute(h, shape).unwrap().to_bits(), want_e.to_bits());
        assert_eq!(chaos.injected_faults(), 0);
    }

    #[test]
    fn a_single_injected_outlier_cannot_move_a_median_measurement() {
        let shape = (4, 256);
        let mut clean = SimBackend::new(SimGpu::a100(), 1);
        let universe = clean.discover().unwrap();
        let (_, vs) = universe.iter().find(|(k, _)| *k == shape).unwrap();
        let hc = clean.compile(shape, &vs[0]).unwrap();
        let want = clean.measure(hc, shape, 1, 3).unwrap();

        let plan = FaultPlan { seed: 9, outlier_rate: 1.0, ..FaultPlan::default() };
        let mut chaos = ChaosBackend::new(SimBackend::new(SimGpu::a100(), 1), plan);
        let h = compiled_default(&mut chaos, shape);
        let got = chaos.measure(h, shape, 1, 3).unwrap();
        assert!(chaos.counters().outliers > 0, "outlier fault must fire at rate 1.0");
        assert_eq!(got.to_bits(), want.to_bits(), "median absorbs a single spiked sample bitwise");
    }

    #[test]
    fn fault_fates_are_bit_reproducible_per_seed() {
        let run = |seed: u64| -> (Vec<String>, ChaosCounters) {
            let plan = FaultPlan {
                seed,
                transient: VerbRates { measure: 0.5, execute: 0.3, ..VerbRates::default() },
                stall_rate: 0.2,
                ..FaultPlan::default()
            };
            let mut chaos = ChaosBackend::new(SimBackend::new(SimGpu::a100(), 1), plan);
            let shape = (4, 256);
            let h = compiled_default(&mut chaos, shape);
            let mut trace = Vec::new();
            for _ in 0..20 {
                match chaos.measure(h, shape, 1, 3) {
                    Ok(v) => trace.push(format!("ok:{:016x}", v.to_bits())),
                    Err(e) => trace.push(format!("err:{e}")),
                }
                match chaos.execute(h, shape) {
                    Ok(v) => trace.push(format!("ok:{:016x}", v.to_bits())),
                    Err(e) => trace.push(format!("err:{e}")),
                }
            }
            (trace, chaos.counters().clone())
        };
        let (t1, c1) = run(7);
        let (t2, c2) = run(7);
        assert_eq!(t1, t2, "same seed, same fault schedule");
        assert_eq!(c1, c2);
        assert!(c1.total() > 0, "rates this high must inject something in 40 calls");
        let (t3, _) = run(8);
        assert_ne!(t1, t3, "different seeds, different schedules");
    }

    #[test]
    fn persistent_compile_failures_never_clear_but_transients_reroll() {
        let shape = (1, 128);
        let plan = FaultPlan { seed: 3, compile_fail_rate: 1.0, ..FaultPlan::default() };
        let mut chaos = ChaosBackend::new(SimBackend::new(SimGpu::a100(), 1), plan);
        let universe = chaos.discover().unwrap();
        let (_, vs) = universe.iter().find(|(k, _)| *k == shape).unwrap();
        for _ in 0..3 {
            let err = chaos.compile(shape, &vs[0]).unwrap_err();
            assert!(err.to_string().contains("persistent"), "{err}");
        }
        assert_eq!(chaos.counters().compile_persistent, 3);

        // Transient faults at rate 1.0 always fail too, but each retry
        // re-rolls (the attempt ordinal advances) — so at a rate < 1 a
        // retry can clear it; the executor's retry loop leans on this.
        let plan = FaultPlan {
            seed: 3,
            transient: VerbRates { measure: 1.0, ..VerbRates::default() },
            ..FaultPlan::default()
        };
        let mut chaos = ChaosBackend::new(SimBackend::new(SimGpu::a100(), 1), plan);
        let h = compiled_default(&mut chaos, shape);
        for _ in 0..3 {
            assert!(chaos.measure(h, shape, 1, 3).is_err());
        }
        assert_eq!(chaos.counters().transient, 3);
    }

    #[test]
    fn brownout_heals_after_the_injection_budget() {
        let shape = (1, 128);
        let plan = FaultPlan {
            seed: 5,
            transient: VerbRates { measure: 1.0, ..VerbRates::default() },
            max_injected: Some(2),
            ..FaultPlan::default()
        };
        let mut chaos = ChaosBackend::new(SimBackend::new(SimGpu::a100(), 1), plan);
        let h = compiled_default(&mut chaos, shape);
        assert!(chaos.measure(h, shape, 1, 3).is_err());
        assert!(chaos.measure(h, shape, 1, 3).is_err());
        assert!(chaos.measure(h, shape, 1, 3).is_ok(), "budget exhausted: the fault clears");
        assert_eq!(chaos.injected_faults(), 2);
    }
}
