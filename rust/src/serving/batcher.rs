//! Sequence-length bucketing + dynamic batching.
//!
//! AOT compilation fixes tensor shapes, so the server routes each request
//! to the smallest compiled (batch, seq) bucket that fits — the standard
//! padded-bucket strategy of XLA/TPU serving stacks.  Within a bucket,
//! requests are batched FIFO: a batch closes when it reaches the bucket's
//! largest compiled batch size or when the oldest request has waited
//! `max_wait_us`.

use std::collections::VecDeque;
use std::time::Instant;

use super::Request;

/// The compiled shape grid: which (batch, seq) pairs have artifacts.
#[derive(Debug, Clone)]
pub struct BucketPolicy {
    /// Sorted distinct seq lengths with compiled artifacts.
    pub seq_buckets: Vec<usize>,
    /// For each seq bucket, sorted batch sizes available.
    pub batch_sizes: Vec<Vec<usize>>,
    /// Deadline after which a non-full batch is flushed.
    pub max_wait_us: u64,
}

impl BucketPolicy {
    /// Build the grid from compiled `(seq, batch)` pairs.
    pub fn new(mut pairs: Vec<(usize, usize)>, max_wait_us: u64) -> Self {
        pairs.sort();
        let mut seq_buckets: Vec<usize> = Vec::new();
        let mut batch_sizes: Vec<Vec<usize>> = Vec::new();
        for (seq, batch) in pairs {
            match seq_buckets.binary_search(&seq) {
                Ok(i) => {
                    if !batch_sizes[i].contains(&batch) {
                        batch_sizes[i].push(batch);
                        batch_sizes[i].sort();
                    }
                }
                Err(i) => {
                    seq_buckets.insert(i, seq);
                    batch_sizes.insert(i, vec![batch]);
                }
            }
        }
        BucketPolicy { seq_buckets, batch_sizes, max_wait_us }
    }

    /// Build a grid from compiled `(seq, batch)` pairs under a device
    /// memory budget: pairs whose resident footprint (per the caller's
    /// `bytes_of(seq, batch)` model — typically
    /// [`crate::workload::Workload::kv_cache_bytes`] of the bucket
    /// workload) exceeds `capacity_bytes` are dropped before the grid is
    /// built, so bucket-shape choice is tuned jointly with the kernel
    /// variants under one capacity budget.
    pub fn memory_aware(
        pairs: Vec<(usize, usize)>,
        max_wait_us: u64,
        capacity_bytes: usize,
        bytes_of: impl Fn(usize, usize) -> usize,
    ) -> Self {
        let kept = pairs
            .into_iter()
            .filter(|&(seq, batch)| bytes_of(seq, batch) <= capacity_bytes)
            .collect();
        BucketPolicy::new(kept, max_wait_us)
    }

    /// Smallest seq bucket that fits `tokens`, if any.
    pub fn bucket_for(&self, tokens: usize) -> Option<usize> {
        let i = self.seq_buckets.partition_point(|&s| s < tokens);
        (i < self.seq_buckets.len()).then(|| i)
    }

    /// Largest compiled batch size for bucket `i`.
    pub fn max_batch(&self, i: usize) -> usize {
        self.batch_sizes[i].last().copied().unwrap_or(1)
    }

    /// Largest compiled batch size <= n (pad up to the next compiled
    /// size when flushing a partial batch).
    pub fn batch_shape_for(&self, i: usize, n: usize) -> usize {
        let sizes = &self.batch_sizes[i];
        sizes
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.max_batch(i))
    }
}

/// A batch ready for execution.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Index into the policy's seq buckets.
    pub bucket: usize,
    /// The bucket's padded sequence length.
    pub seq_len: usize,
    /// Compiled batch shape (>= requests.len(); remainder is padding).
    pub batch_shape: usize,
    /// The live requests riding in this batch.
    pub requests: Vec<Request>,
    /// When the batch was closed (latency accounting).
    pub formed_at: Instant,
}

impl Batch {
    /// Fraction of the compiled batch doing useful work.
    pub fn occupancy(&self) -> f64 {
        self.requests.len() as f64 / self.batch_shape.max(1) as f64
    }
}

#[derive(Debug)]
struct PendingQueue {
    items: VecDeque<(Request, Instant)>,
}

/// FIFO dynamic batcher over seq buckets.
#[derive(Debug)]
pub struct DynamicBatcher {
    policy: BucketPolicy,
    queues: Vec<PendingQueue>,
    /// Requests dropped because no bucket fits them.
    pub rejected: Vec<Request>,
}

impl DynamicBatcher {
    /// An empty batcher over `policy`'s buckets.
    pub fn new(policy: BucketPolicy) -> Self {
        let queues = (0..policy.seq_buckets.len())
            .map(|_| PendingQueue { items: VecDeque::new() })
            .collect();
        DynamicBatcher { policy, queues, rejected: Vec::new() }
    }

    /// The underlying bucket policy.
    pub fn policy(&self) -> &BucketPolicy {
        &self.policy
    }

    /// Enqueue a request; returns its bucket or None when rejected.
    pub fn push(&mut self, req: Request, now: Instant) -> Option<usize> {
        match self.policy.bucket_for(req.tokens) {
            Some(i) => {
                self.queues[i].items.push_back((req, now));
                Some(i)
            }
            None => {
                self.rejected.push(req);
                None
            }
        }
    }

    /// Requests currently queued across all buckets.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.items.len()).sum()
    }

    /// Pop the next ready batch: a full batch from any bucket, else an
    /// expired partial batch (oldest request waited > max_wait_us).
    /// `drain=true` flushes partial batches immediately (shutdown).
    pub fn next_batch(&mut self, now: Instant, drain: bool) -> Option<Batch> {
        // Full batches first (throughput), oldest bucket first.
        let mut best: Option<(usize, Instant)> = None;
        for (i, q) in self.queues.iter().enumerate() {
            let Some(&(_, oldest)) = q.items.front() else {
                continue;
            };
            let full = q.items.len() >= self.policy.max_batch(i);
            let expired = now.duration_since(oldest).as_micros() as u64 >= self.policy.max_wait_us;
            if full || expired || drain {
                if best.map(|(_, t)| oldest < t).unwrap_or(true) {
                    best = Some((i, oldest));
                }
            }
        }
        let (i, _) = best?;
        let take = self.queues[i].items.len().min(self.policy.max_batch(i));
        let requests: Vec<Request> = self.queues[i]
            .items
            .drain(..take)
            .map(|(r, _)| r)
            .collect();
        let batch_shape = self.policy.batch_shape_for(i, requests.len());
        Some(Batch {
            bucket: i,
            seq_len: self.policy.seq_buckets[i],
            batch_shape,
            requests,
            formed_at: now,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BucketPolicy {
        BucketPolicy::new(
            vec![(128, 1), (128, 2), (128, 4), (256, 1), (256, 2)],
            10_000, // 10 ms
        )
    }

    fn req(id: u64, tokens: usize) -> Request {
        Request { id, tokens }
    }

    #[test]
    fn buckets_are_sorted_and_deduped() {
        let p = policy();
        assert_eq!(p.seq_buckets, vec![128, 256]);
        assert_eq!(p.batch_sizes[0], vec![1, 2, 4]);
        assert_eq!(p.max_batch(0), 4);
    }

    #[test]
    fn memory_aware_grid_drops_over_budget_shapes() {
        // Footprint model: batch x seq "tokens" of 1 B each; budget 512
        // keeps (128,1), (128,2), (128,4), (256,1), (256,2) minus the
        // two shapes above 512 B.
        let p = BucketPolicy::memory_aware(
            vec![(128, 1), (128, 2), (128, 4), (256, 1), (256, 2)],
            10_000,
            512,
            |seq, batch| seq * batch,
        );
        assert_eq!(p.seq_buckets, vec![128, 256]);
        assert_eq!(p.batch_sizes[0], vec![1, 2, 4], "512 B exactly fits (128,4)");
        assert_eq!(p.batch_sizes[1], vec![1, 2], "(256,2) = 512 B exactly fits");
        let tight = BucketPolicy::memory_aware(
            vec![(128, 1), (128, 2), (128, 4), (256, 1), (256, 2)],
            10_000,
            300,
            |seq, batch| seq * batch,
        );
        assert_eq!(tight.batch_sizes[0], vec![1, 2], "(128,4) over budget");
        assert_eq!(tight.batch_sizes[1], vec![1], "(256,2) over budget");
        // Zero capacity with a nonzero footprint model empties the grid.
        let none =
            BucketPolicy::memory_aware(vec![(128, 1)], 10_000, 0, |seq, batch| seq * batch);
        assert!(none.seq_buckets.is_empty());
    }

    #[test]
    fn memory_aware_with_infinite_budget_equals_plain_new() {
        let pairs = vec![(128, 1), (128, 2), (256, 1)];
        let a = BucketPolicy::new(pairs.clone(), 10_000);
        let b = BucketPolicy::memory_aware(pairs, 10_000, usize::MAX, |seq, batch| seq * batch);
        assert_eq!(a.seq_buckets, b.seq_buckets);
        assert_eq!(a.batch_sizes, b.batch_sizes);
    }

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let p = policy();
        assert_eq!(p.bucket_for(100), Some(0));
        assert_eq!(p.bucket_for(128), Some(0));
        assert_eq!(p.bucket_for(129), Some(1));
        assert_eq!(p.bucket_for(300), None);
    }

    #[test]
    fn batch_shape_pads_up() {
        let p = policy();
        assert_eq!(p.batch_shape_for(0, 1), 1);
        assert_eq!(p.batch_shape_for(0, 3), 4);
        assert_eq!(p.batch_shape_for(1, 2), 2);
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        for i in 0..4 {
            b.push(req(i, 100), t);
        }
        let batch = b.next_batch(t, false).expect("full batch ready");
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(batch.batch_shape, 4);
        assert_eq!(batch.occupancy(), 1.0);
        assert!(b.next_batch(t, false).is_none());
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b = DynamicBatcher::new(policy());
        let t0 = Instant::now();
        b.push(req(1, 100), t0);
        assert!(b.next_batch(t0, false).is_none(), "must wait");
        let later = t0 + std::time::Duration::from_micros(10_001);
        let batch = b.next_batch(later, false).expect("deadline flush");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.batch_shape, 1);
    }

    #[test]
    fn drain_flushes_everything() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        b.push(req(1, 100), t);
        b.push(req(2, 200), t);
        let mut got = 0;
        while let Some(batch) = b.next_batch(t, true) {
            got += batch.requests.len();
        }
        assert_eq!(got, 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        let n = 100;
        for i in 0..n {
            b.push(req(i, 64 + (i as usize * 37) % 200), t);
        }
        let mut seen = std::collections::HashSet::new();
        while let Some(batch) = b.next_batch(t, true) {
            for r in batch.requests {
                assert!(seen.insert(r.id), "duplicate {}", r.id);
            }
        }
        assert_eq!(seen.len() as u64 + b.rejected.len() as u64, n);
    }

    #[test]
    fn oversize_requests_rejected() {
        let mut b = DynamicBatcher::new(policy());
        assert!(b.push(req(1, 1000), Instant::now()).is_none());
        assert_eq!(b.rejected.len(), 1);
    }

    #[test]
    fn fifo_within_bucket() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        for i in 0..6 {
            b.push(req(i, 100), t);
        }
        let first = b.next_batch(t, false).unwrap();
        assert_eq!(first.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }
}
