//! vLLM-style serving layer: request router, seq-length bucketing,
//! dynamic batching, and **off-critical-path autotuning** (paper Q4.4)
//! — on a pluggable execution backend, so the whole path runs in
//! default builds.
//!
//! Architecture (single-process, mirroring a sharded vLLM engine):
//!
//! ```text
//!  loadgen::Scenario ──► TimedRequest trace (seeded arrivals × mixes)
//!                              │
//!  clients ──► Router ──► BucketQueue(seq≤128) ──┐
//!                    └──► BucketQueue(seq≤256) ──┤  formed batches
//!                                                ▼
//!                                         PlacementPolicy
//!                                 (bucket-affinity | least-loaded)
//!                          ┌─────────────┼─────────────┐
//!                          ▼             ▼             ▼
//!                       shard 0       shard 1  ...  shard N-1
//!                   (ExecutorThreads: each owns its own ExecBackend,
//!                          │  tuning queue and breaker state;
//!                          │  idle? → run one tuning measurement and
//!                          │          maybe swap the active variant)
//!                          ▼
//!                       replies (reaped in dispatch order)
//!                          │
//!        ┌─────────────────┴─────────────────┐
//!        ▼                                   ▼
//!   SimBackend                          PjrtBackend
//! (always available: the              (feature `pjrt`: real
//!  analytical platform models,         AOT HLO artifacts on
//!  deterministic virtual-clock         the XLA PJRT CPU
//!  latencies — a100/mi250/h100)        client)
//! ```
//!
//! The executor owns all backend state on one thread — PJRT objects are
//! not `Send`, so the backend is *constructed inside* that thread and
//! the router talks to it through channels; the same shape works for
//! the trivially-`Send` sim backend.  Q4.4's *"perform autotuning based
//! on workload metrics using idle GPU times"* falls out naturally: the
//! executor runs one background tuning measurement (through
//! [`backend::ExecBackend::measure`]) whenever its request queue is
//! empty, and hot-swaps the per-bucket active kernel variant when
//! tuning finds a faster one.

pub mod backend;
pub mod batcher;
pub mod chaos;
pub mod executor;
pub mod loadgen;
pub mod router;
pub mod shard;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use backend::{EvalLogBackend, ExecBackend, SimBackend};
pub use batcher::{Batch, BucketPolicy, DynamicBatcher};
pub use chaos::{ChaosBackend, ChaosCounters, FaultPlan, VerbRates};
pub use executor::{ExecOutcome, ExecutorCommand, ExecutorHandle, ExecutorStats};
pub use loadgen::{ArrivalProcess, Scenario, TimedRequest, TrafficClass};
pub use router::{Router, ServeReport, ServerConfig};
pub use shard::{PlacementPolicy, ShardSet, ShardUtil};

/// One inference request: a prompt of `tokens` tokens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Caller-assigned request id.
    pub id: u64,
    /// Prompt length in tokens.
    pub tokens: usize,
}

/// Completion record for one request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// The request's token count.
    pub tokens: usize,
    /// Seq-length bucket the request was served in.
    pub bucket_seq: usize,
    /// Batch size it shared an execution with.
    pub batch_size: usize,
    /// End-to-end latency (enqueue -> reply), µs.
    pub latency_us: f64,
    /// Pure execution latency of the batch it rode in, µs (wall-clock
    /// on PJRT, model-derived on the sim backend).
    pub exec_us: f64,
    /// Which kernel-config variant served it (artifact id).
    pub variant: String,
}
