//! vLLM-style serving layer: request router, seq-length bucketing,
//! dynamic batching, and **off-critical-path autotuning** (paper Q4.4).
//!
//! Architecture (single-process, mirroring a vLLM engine worker):
//!
//! ```text
//!  clients ──► Router ──► BucketQueue(seq≤128) ──┐
//!                    └──► BucketQueue(seq≤256) ──┤   commands
//!                                                ▼
//!                                        ExecutorThread (owns PJRT)
//!                                          │  idle? → run one tuning
//!                                          │          measurement and
//!                                          │          maybe swap the
//!                                          ▼          active variant
//!                                       replies
//! ```
//!
//! PJRT objects are not `Send`, so **all** XLA work lives on one executor
//! thread; the router talks to it through channels.  Q4.4's *"perform
//! autotuning based on workload metrics using idle GPU times"* falls out
//! naturally: the executor runs one background tuning measurement
//! whenever its request queue is empty, and hot-swaps the per-bucket
//! active kernel variant when tuning finds a faster one.

pub mod batcher;
#[cfg(feature = "pjrt")]
pub mod executor;
pub mod router;

pub use batcher::{Batch, BucketPolicy, DynamicBatcher};
#[cfg(feature = "pjrt")]
pub use executor::{ExecutorCommand, ExecutorHandle, ExecutorStats};
#[cfg(feature = "pjrt")]
pub use router::{Router, ServeReport};
pub use router::ServerConfig;

/// One inference request: a prompt of `tokens` tokens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Caller-assigned request id.
    pub id: u64,
    /// Prompt length in tokens.
    pub tokens: usize,
}

/// Completion record for one request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// The request's token count.
    pub tokens: usize,
    /// Seq-length bucket the request was served in.
    pub bucket_seq: usize,
    /// Batch size it shared an execution with.
    pub batch_size: usize,
    /// End-to-end latency (enqueue -> reply), µs.
    pub latency_us: f64,
    /// Pure execution latency of the batch it rode in, µs.
    pub exec_us: f64,
    /// Which kernel-config variant served it (artifact id).
    pub variant: String,
}
