//! Pluggable execution backends for the serving plane.
//!
//! The serving executor used to be hard-wired to the PJRT
//! `runtime::Engine` and therefore `#[cfg(feature = "pjrt")]`-gated out
//! of every default build — the router → batcher → executor →
//! idle-tuning path was dead code in tier-1 CI.  [`ExecBackend`] is the
//! seam that fixes that: the executor is generic over *how* a
//! (workload-bucket, kernel-config) variant is compiled, executed and
//! measured, and two implementations plug in:
//!
//! - [`SimBackend`] — always available.  Latencies come from the
//!   analytical platform models ([`crate::platform::model`]) through a
//!   [`SimEvaluator`], so they are deterministic, bit-reproducible, and
//!   need no GPU/XLA toolchain.  A seeded generator lays out the
//!   compiled-shape grid and the per-bucket variant candidates, and a
//!   **virtual clock** accumulates the modeled execute/measure/compile
//!   time (nothing sleeps; wall-clock stays near zero).  This is what
//!   `portatune serve` runs on by default, and what lets the same trace
//!   be replayed on a100 vs mi250 vs h100 without hardware.
//! - `PjrtBackend` (feature `pjrt`) — the real path: HLO-text artifacts
//!   from the AOT manifest compiled on the XLA PJRT CPU client and
//!   executed with device-resident weights.  PJRT handles are not
//!   `Send`, which is why backends are *constructed inside* the
//!   executor thread (see [`crate::serving::executor::ExecutorHandle::spawn`]).
//!
//! The contract deliberately mirrors the autotuner's evaluator split:
//! `measure` is the serving twin of [`crate::autotuner::Evaluator`]'s
//! `evaluate` — the executor folds its results into per-bucket
//! [`crate::autotuner::search::Recorder`]s, so idle-time tuning (paper
//! Q4.4) shares the fidelity-correct bookkeeping with every search
//! strategy.

use std::path::PathBuf;

use crate::autotuner::{Evaluator, SimEvaluator};
use crate::config::{spaces, Config};
use crate::kernels::baselines::triton_codegen;
use crate::platform::model::SimGpu;
use crate::util::rng::Rng;
use crate::workload::{DType, Workload};
use crate::Result;

/// Key of a compiled model shape: (batch, seq).
pub type ShapeKey = (usize, usize);

/// Opaque handle to a backend-compiled executable.  Handles are only
/// meaningful to the backend that issued them; the executor treats them
/// as tokens and memoizes one per (shape, variant).
pub type ExecHandle = usize;

/// What the executor knows about one candidate kernel variant of a
/// compiled model shape — everything backend-independent.
#[derive(Debug, Clone)]
pub struct VariantDesc {
    /// Stable identifier (artifact id on PJRT, synthetic id on sim) —
    /// what swap events and stats report.
    pub artifact_id: String,
    /// The kernel configuration this variant was built with (the
    /// recorder / tuning-cache key).
    pub config: Config,
    /// HLO-text artifact path (PJRT backends only; sim has none).
    pub path: Option<PathBuf>,
}

/// One execution platform the serving plane can run on.
///
/// Implementations own all platform state (clients, device buffers,
/// model tables) and hand the executor opaque [`ExecHandle`]s.  The
/// executor guarantees it calls [`ExecBackend::compile`] at most once
/// per (shape, variant) — backends need not memoize — and only ever
/// calls `execute`/`measure` with handles that backend issued.
///
/// Backends are constructed *inside* the executor thread (via the
/// factory passed to [`crate::serving::executor::ExecutorHandle::spawn`]),
/// so they never need to be `Send`: PJRT handles are not, and that
/// constraint shaped this whole API.
pub trait ExecBackend {
    /// Stable platform fingerprint — the tuning-cache key component, so
    /// bucket winners tuned on one platform are never served to another.
    fn platform(&self) -> String;

    /// The compiled-model universe: every (batch, seq) shape the
    /// backend can serve, each with its candidate kernel variants in
    /// preference order (index 0 is the cold-start default).
    fn discover(&mut self) -> Result<Vec<(ShapeKey, Vec<VariantDesc>)>>;

    /// The synthetic tuning workload of a serving bucket — the
    /// attention geometry of the served model at this (batch, seq)
    /// shape.  Part of the tuning-cache key for the bucket's winner.
    fn bucket_workload(&self, shape: ShapeKey) -> Workload;

    /// Compile one variant of `shape` to an executable handle.  An
    /// error means the variant cannot run on this platform (missing
    /// artifact, over-budget config, ...) — the executor records it as
    /// invalid, exactly like a platform-rejected tuning config.
    fn compile(&mut self, shape: ShapeKey, variant: &VariantDesc) -> Result<ExecHandle>;

    /// Execute one request batch through `handle`; returns the pure
    /// execution latency in µs.
    fn execute(&mut self, handle: ExecHandle, shape: ShapeKey) -> Result<f64>;

    /// Measure `handle` as a tuning candidate (`warmup` unmeasured
    /// runs, then the representative latency of `iters` measured runs),
    /// in µs.  This is the call the executor's idle-time tuning drives
    /// its per-bucket [`crate::autotuner::search::Recorder`]s through.
    ///
    /// Implementations must aggregate the `iters` samples
    /// outlier-robustly — median ([`crate::metrics::median`]) rather
    /// than mean — so a single latency spike (scheduler hiccup, or an
    /// injected [`crate::serving::ChaosBackend`] outlier) cannot crown
    /// a wrong tuning variant.
    fn measure(&mut self, handle: ExecHandle, shape: ShapeKey, warmup: usize, iters: usize) -> Result<f64>;

    /// Hint that measurements for `upcoming` shapes are imminent, so
    /// the backend may prepare measurement inputs off the critical path
    /// (the PJRT backend pre-generates activation tensors on the shared
    /// worker pool).  Purely a wall-clock optimization; default no-op.
    fn prefetch(&mut self, upcoming: &[ShapeKey]) {
        let _ = upcoming;
    }

    /// `shape` has no queued measurements left: memoized measurement
    /// inputs (tens of MB per shape on PJRT) may be dropped.
    fn release(&mut self, shape: ShapeKey) {
        let _ = shape;
    }

    /// The tuning queue is fully drained: drop every memoized input.
    fn release_all(&mut self) {}

    /// Wait out a retry backoff of `us` microseconds.  The default
    /// sleeps wall-clock (right for real devices); virtual-clock
    /// backends override this to advance their modeled clock instead,
    /// which is what keeps fault-injection tests instant.
    fn backoff(&mut self, us: f64) {
        std::thread::sleep(std::time::Duration::from_micros(us as u64));
    }

    /// Faults injected into this backend so far — nonzero only on
    /// fault-injecting decorators ([`crate::serving::ChaosBackend`]);
    /// surfaced through executor stats so reports can prove a chaos
    /// run actually exercised the recovery machinery.
    fn injected_faults(&self) -> usize {
        0
    }

    /// Accumulated virtual-clock time (µs) this backend has modeled —
    /// the deterministic time base sharded serving reports use for
    /// makespan/throughput math.  Wall-clock backends return 0.0 (their
    /// time lives in the report's wall-clock fields instead); the sim
    /// backend returns its modeled compile/execute/measure/backoff
    /// total, and decorators add any virtual time they injected
    /// themselves (chaos stalls).
    fn virtual_clock_us(&self) -> f64 {
        0.0
    }
}

/// The conservative default variant: small tiles, one stage — valid on
/// every modeled platform (fits the MI250's 64 KiB LDS at f32/head 128),
/// deliberately far from any platform's optimum so idle tuning has
/// headroom to demonstrate.
fn default_variant_config() -> Config {
    Config::new(&[
        ("BLOCK_M", 32),
        ("BLOCK_N", 32),
        ("num_warps", 4),
        ("num_stages", 1),
        ("waves_per_eu", 0),
    ])
}

/// Compact artifact-id spelling of a sim variant config.
fn sim_artifact_id(shape: ShapeKey, cfg: &Config) -> String {
    format!(
        "sim/b{}_s{}/m{}n{}w{}st{}e{}",
        shape.0,
        shape.1,
        cfg.req("BLOCK_M"),
        cfg.req("BLOCK_N"),
        cfg.req("num_warps"),
        cfg.req("num_stages"),
        cfg.req("waves_per_eu"),
    )
}

/// Attention geometry of the simulated served model.
#[derive(Debug, Clone, Copy)]
pub struct SimModelGeom {
    /// Query heads per block.
    pub q_heads: usize,
    /// KV heads per block (GQA).
    pub kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
}

impl Default for SimModelGeom {
    /// The paper's Llama-3.1-8B geometry (32 query / 8 KV heads, 128
    /// head dim) — the same model every tuning experiment uses.
    fn default() -> Self {
        SimModelGeom { q_heads: 32, kv_heads: 8, head_dim: 128 }
    }
}

impl SimModelGeom {
    /// The synthetic tuning workload of a serving bucket at this
    /// geometry — the ONE definition both backends delegate their
    /// [`ExecBackend::bucket_workload`] to.  This workload is the
    /// tuning-cache key, so the two implementations must never drift
    /// (a dtype or causality difference would silently break warm
    /// starts against persisted winners).
    pub fn bucket_workload(&self, shape: ShapeKey) -> Workload {
        Workload::Attention {
            batch: shape.0,
            q_heads: self.q_heads,
            kv_heads: self.kv_heads,
            seq_len: shape.1,
            head_dim: self.head_dim,
            dtype: DType::F32,
            causal: true,
        }
    }
}

/// The always-available serving backend: an analytically modeled GPU.
///
/// Latency of a (shape, config) pair is
/// [`SimGpu::latency_us`] through a [`SimEvaluator`] — a pure function,
/// so replays are bit-reproducible and the acceptance contract
/// *tuned mean exec ≤ cold mean exec* holds deterministically (the
/// tuned variant is the per-bucket argmin over the same model).  The
/// `seed` drives the per-bucket variant candidates (sampled from the
/// Triton-sized sim space, deduped, behind the conservative default at
/// index 0), so different seeds serve different candidate sets.
pub struct SimBackend {
    /// The analytical evaluator: platform model + codegen quality.
    /// `workload` is re-pointed at the bucket being served per call.
    eval: SimEvaluator,
    geom: SimModelGeom,
    shapes: Vec<ShapeKey>,
    variants_per_bucket: usize,
    seed: u64,
    /// Handle table: compiled configs, indexed by [`ExecHandle`].
    compiled: Vec<Config>,
    /// Virtual clock (µs): accumulated modeled compile/execute/measure
    /// time.  Nothing sleeps — this is what a real device *would* have
    /// spent, so reports can cite device-time without wall-clock noise.
    clock_us: f64,
    /// Modeled cost of one compile on the virtual clock (µs).  The
    /// paper: "compilation time accounts for around 80% of the
    /// autotuning time".
    compile_cost_us: f64,
    /// Device-memory budget (bytes) for the resident KV cache of the
    /// largest bucket served.  Defaults to the full
    /// [`crate::platform::spec::GpuSpec::hbm_bytes`] capacity; tests and
    /// capacity experiments shrink it.  Shapes whose
    /// bucket workload pins more KV cache than this are dropped at
    /// [`ExecBackend::discover`] time, so bucket-grid choice and kernel
    /// variants are tuned jointly under one capacity budget.
    mem_budget_bytes: usize,
}

impl SimBackend {
    /// A sim backend for `gpu` with the default shape grid
    /// (batch 1/2/4/8 × seq 128/256/512), Llama-3 geometry, the
    /// vendor's Triton codegen model, and 6 variant candidates per
    /// bucket drawn with `seed`.
    pub fn new(gpu: SimGpu, seed: u64) -> Self {
        let vendor = gpu.spec.vendor;
        let mem_budget_bytes = gpu.spec.hbm_bytes;
        let geom = SimModelGeom::default();
        // The workload field is re-pointed per bucket; seed it with the
        // first shape's geometry so the evaluator is always coherent.
        let w = Workload::Attention {
            batch: 1,
            q_heads: geom.q_heads,
            kv_heads: geom.kv_heads,
            seq_len: 128,
            head_dim: geom.head_dim,
            dtype: DType::F32,
            causal: true,
        };
        SimBackend {
            eval: SimEvaluator::new(gpu, w, triton_codegen(vendor)),
            geom,
            shapes: [1usize, 2, 4, 8]
                .into_iter()
                .flat_map(|b| [128usize, 256, 512].into_iter().map(move |s| (b, s)))
                .collect(),
            variants_per_bucket: 6,
            seed,
            compiled: Vec::new(),
            clock_us: 0.0,
            compile_cost_us: 250_000.0,
            mem_budget_bytes,
        }
    }

    /// Replace the compiled (batch, seq) shape grid.
    pub fn with_shapes(mut self, shapes: &[ShapeKey]) -> Self {
        self.shapes = shapes.to_vec();
        self
    }

    /// Shrink (or grow) the device-memory budget the bucket grid is
    /// discovered under.  Shapes whose bucket workload would pin a KV
    /// cache larger than `bytes` are not served.
    pub fn with_mem_budget(mut self, bytes: usize) -> Self {
        self.mem_budget_bytes = bytes;
        self
    }

    /// The active device-memory budget (bytes).
    pub fn mem_budget_bytes(&self) -> usize {
        self.mem_budget_bytes
    }

    /// Candidate variants per bucket (≥ 1; index 0 is always the
    /// conservative default).
    pub fn with_variants_per_bucket(mut self, n: usize) -> Self {
        self.variants_per_bucket = n.max(1);
        self
    }

    /// The virtual device clock: total modeled µs spent compiling,
    /// executing and measuring so far.
    pub fn clock_us(&self) -> f64 {
        self.clock_us
    }

    fn config_of(&self, handle: ExecHandle) -> Result<Config> {
        self.compiled
            .get(handle)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown exec handle {handle}"))
    }

    /// Model latency of `cfg` for `shape`'s bucket workload.
    fn model_us(&mut self, cfg: &Config, shape: ShapeKey) -> Result<f64> {
        self.eval.workload = self.bucket_workload(shape);
        self.eval
            .evaluate(cfg)
            .map_err(|e| anyhow::anyhow!("{e}"))
    }
}

impl ExecBackend for SimBackend {
    fn platform(&self) -> String {
        // Same fingerprint as the tuning evaluators for this model
        // (`sim-a100/model-v3`, ...), so serving winners and tuning
        // winners share the cache namespace rules.
        self.eval.name()
    }

    fn discover(&mut self) -> Result<Vec<(ShapeKey, Vec<VariantDesc>)>> {
        let space = spaces::attention_sim_space();
        let smem_budget = self.eval.gpu.spec.smem_per_block;
        let mut out = Vec::with_capacity(self.shapes.len());
        for &shape in &self.shapes {
            let w = self.bucket_workload(shape);
            // Memory-aware bucket grid: a shape whose resident KV cache
            // would not fit the device budget is never served — the
            // capacity dimension prunes buckets exactly like an invalid
            // tile prunes a config subtree.
            if w.kv_cache_bytes() > self.mem_budget_bytes {
                continue;
            }
            let mut configs = vec![default_variant_config()];
            // Seeded, per-shape draw: deterministic per (seed, shape),
            // independent of the other buckets.  Draws whose on-chip
            // footprint cannot fit this platform's per-block budget are
            // rejected up front instead of burning a compile to fail.
            let mix = ((shape.0 as u64) << 32 | shape.1 as u64)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut rng = Rng::seed_from(self.seed ^ mix);
            let mut stall = 0usize;
            while configs.len() < self.variants_per_bucket && stall < 200 {
                match space.sample(&w, &mut rng, 200) {
                    Some(c)
                        if c.mem_bytes(&w) <= smem_budget
                            && !configs.iter().any(|k| k.fingerprint() == c.fingerprint()) =>
                    {
                        configs.push(c);
                        stall = 0;
                    }
                    _ => stall += 1,
                }
            }
            let variants = configs
                .into_iter()
                .map(|cfg| VariantDesc {
                    artifact_id: sim_artifact_id(shape, &cfg),
                    config: cfg,
                    path: None,
                })
                .collect();
            out.push((shape, variants));
        }
        Ok(out)
    }

    fn bucket_workload(&self, shape: ShapeKey) -> Workload {
        self.geom.bucket_workload(shape)
    }

    fn compile(&mut self, shape: ShapeKey, variant: &VariantDesc) -> Result<ExecHandle> {
        // Compiling an over-budget config fails exactly like the real
        // toolchain would — the executor counts it invalid and the
        // bucket still activates its best working variant.
        let w = self.bucket_workload(shape);
        self.eval
            .gpu
            .validate_attention(&variant.config, &w)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        self.clock_us += self.compile_cost_us;
        self.compiled.push(variant.config.clone());
        Ok(self.compiled.len() - 1)
    }

    fn execute(&mut self, handle: ExecHandle, shape: ShapeKey) -> Result<f64> {
        let cfg = self.config_of(handle)?;
        let us = self.model_us(&cfg, shape)?;
        self.clock_us += us;
        Ok(us)
    }

    fn measure(&mut self, handle: ExecHandle, shape: ShapeKey, warmup: usize, iters: usize) -> Result<f64> {
        let cfg = self.config_of(handle)?;
        let us = self.model_us(&cfg, shape)?;
        // The model is noise-free (every sample equals the model, so
        // the median aggregate IS the model value); warmup+iters only
        // advance the virtual clock.
        self.clock_us += us * (warmup + iters.max(1)) as f64;
        Ok(us)
    }

    fn backoff(&mut self, us: f64) {
        // Virtual clock: retries cost modeled time, never wall-clock.
        self.clock_us += us;
    }

    fn virtual_clock_us(&self) -> f64 {
        self.clock_us
    }
}

/// Decorator that appends every full-fidelity tuning measurement the
/// executor takes to a JSONL eval log — the serving half of
/// `--log-evals PATH` (the tuning half wraps the evaluator in a
/// [`crate::surrogate::LoggingEvaluator`]).  Results pass through
/// bit-identical; the only side effect is the appended line, so a
/// logged serve replays exactly like an unlogged one.  Handles are
/// mapped back to configs via a compile-time mirror of the inner
/// backend's handle table (the executor compiles each (shape, variant)
/// at most once, so the mirror stays small).
pub struct EvalLogBackend<B: ExecBackend> {
    inner: B,
    log: crate::surrogate::EvalLogWriter,
    compiled: std::collections::HashMap<ExecHandle, Config>,
}

impl<B: ExecBackend> EvalLogBackend<B> {
    /// Wrap `inner` so its tuning measurements append to `log`.
    pub fn new(inner: B, log: crate::surrogate::EvalLogWriter) -> Self {
        EvalLogBackend { inner, log, compiled: std::collections::HashMap::new() }
    }
}

impl<B: ExecBackend> ExecBackend for EvalLogBackend<B> {
    fn platform(&self) -> String {
        self.inner.platform()
    }

    fn discover(&mut self) -> Result<Vec<(ShapeKey, Vec<VariantDesc>)>> {
        self.inner.discover()
    }

    fn bucket_workload(&self, shape: ShapeKey) -> Workload {
        self.inner.bucket_workload(shape)
    }

    fn compile(&mut self, shape: ShapeKey, variant: &VariantDesc) -> Result<ExecHandle> {
        let h = self.inner.compile(shape, variant)?;
        self.compiled.insert(h, variant.config.clone());
        Ok(h)
    }

    fn execute(&mut self, handle: ExecHandle, shape: ShapeKey) -> Result<f64> {
        self.inner.execute(handle, shape)
    }

    fn measure(&mut self, handle: ExecHandle, shape: ShapeKey, warmup: usize, iters: usize) -> Result<f64> {
        let us = self.inner.measure(handle, shape, warmup, iters)?;
        if let Some(cfg) = self.compiled.get(&handle) {
            let w = self.inner.bucket_workload(shape);
            let platform = self.inner.platform();
            // Logging is best-effort: a full disk must not fail the
            // measurement that already succeeded.
            let _ = self.log.append(&platform, &w, cfg, us, 1.0);
        }
        Ok(us)
    }

    fn prefetch(&mut self, upcoming: &[ShapeKey]) {
        self.inner.prefetch(upcoming);
    }

    fn release(&mut self, shape: ShapeKey) {
        self.inner.release(shape);
    }

    fn release_all(&mut self) {
        self.inner.release_all();
    }

    fn backoff(&mut self, us: f64) {
        self.inner.backoff(us);
    }

    fn injected_faults(&self) -> usize {
        self.inner.injected_faults()
    }

    fn virtual_clock_us(&self) -> f64 {
        self.inner.virtual_clock_us()
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;

    use super::*;
    use crate::runtime::{Engine, Executable, Manifest, TensorF32};

    /// The real execution backend: AOT HLO-text artifacts compiled on
    /// the XLA PJRT CPU client, weights uploaded once as device buffers
    /// (the request path only moves activations — §Perf L3).
    ///
    /// Not `Send` (PJRT handles are thread-bound), which is fine: the
    /// executor constructs its backend inside its own thread.
    pub struct PjrtBackend {
        engine: Engine,
        manifest: Manifest,
        hidden: usize,
        geom: SimModelGeom,
        /// Weights uploaded ONCE as device buffers.
        weights: Vec<xla::PjRtBuffer>,
        /// Handle table: compiled executables, indexed by [`ExecHandle`].
        compiled: Vec<Executable>,
        /// Synthetic measurement inputs, memoized per bucket shape and
        /// generated ahead of need on the shared worker pool (the
        /// tensors are deterministic per shape, so caching changes
        /// nothing but wall-clock).
        tune_inputs: HashMap<ShapeKey, TensorF32>,
    }

    impl PjrtBackend {
        /// Build the backend over a manifest's transformer-block
        /// artifacts: create the CPU PJRT client and upload the
        /// deterministic synthetic weights.
        pub fn new(manifest: Manifest) -> crate::Result<Self> {
            let engine = Engine::cpu()?;
            let model = &manifest.model;
            let weights = model
                .param_order
                .iter()
                .enumerate()
                .map(|(i, name)| {
                    let shape = &model.param_shapes[name];
                    // Small magnitudes keep block outputs numerically tame.
                    let mut t = TensorF32::random(shape, 0x5EED + i as u64);
                    let scale = 1.0 / (model.hidden as f32).sqrt();
                    for v in &mut t.data {
                        *v *= scale;
                    }
                    engine.upload(&t)
                })
                .collect::<crate::Result<Vec<_>>>()?;
            Ok(PjrtBackend {
                hidden: model.hidden,
                geom: SimModelGeom {
                    q_heads: model.n_q_heads,
                    kv_heads: model.n_kv_heads,
                    head_dim: model.head_dim,
                },
                engine,
                weights,
                manifest,
                compiled: Vec::new(),
                tune_inputs: HashMap::new(),
            })
        }

        /// All-args vector for one activation buffer (weights are
        /// device-resident).
        fn args<'b>(&'b self, x_buf: &'b xla::PjRtBuffer) -> Vec<&'b xla::PjRtBuffer> {
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
            args.push(x_buf);
            args.extend(self.weights.iter());
            args
        }
    }

    impl ExecBackend for PjrtBackend {
        fn platform(&self) -> String {
            crate::platform::PlatformId::CpuPjrt.fingerprint()
        }

        fn discover(&mut self) -> crate::Result<Vec<(ShapeKey, Vec<VariantDesc>)>> {
            let mut buckets: Vec<(ShapeKey, Vec<VariantDesc>)> = Vec::new();
            for a in self.manifest.model_artifacts() {
                let (Some(batch), Some(seq)) = (a.workload.batch, a.workload.seq_len) else {
                    continue;
                };
                let desc = VariantDesc {
                    artifact_id: a.id.clone(),
                    config: variant_config(&a.id),
                    path: Some(self.manifest.root.join(&a.path)),
                };
                match buckets.iter_mut().find(|(k, _)| *k == (batch, seq)) {
                    Some((_, vs)) => vs.push(desc),
                    None => buckets.push(((batch, seq), vec![desc])),
                }
            }
            Ok(buckets)
        }

        fn bucket_workload(&self, shape: ShapeKey) -> Workload {
            self.geom.bucket_workload(shape)
        }

        fn compile(&mut self, _shape: ShapeKey, variant: &VariantDesc) -> crate::Result<ExecHandle> {
            let path = variant
                .path
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("variant {} has no artifact path", variant.artifact_id))?;
            let exe = self.engine.load_hlo_text(path)?;
            self.compiled.push(exe);
            Ok(self.compiled.len() - 1)
        }

        fn execute(&mut self, handle: ExecHandle, shape: ShapeKey) -> crate::Result<f64> {
            // Synthetic embedded prompt activations for the batch;
            // weights are already device-resident.
            let x = TensorF32::random(&[shape.0, shape.1, self.hidden], 0xAB + shape.1 as u64);
            let x_buf = self.engine.upload(&x)?;
            let args = self.args(&x_buf);
            let exe = &self.compiled[handle];
            let t0 = std::time::Instant::now();
            let out = exe.run_buffers(&args)?;
            let exec_us = t0.elapsed().as_secs_f64() * 1e6;
            debug_assert_eq!(out.len(), shape.0 * shape.1 * self.hidden);
            Ok(exec_us)
        }

        fn measure(&mut self, handle: ExecHandle, shape: ShapeKey, warmup: usize, iters: usize) -> crate::Result<f64> {
            if !self.tune_inputs.contains_key(&shape) {
                // Prefetch miss (e.g. shape beyond the lookahead window).
                let t = TensorF32::random(&[shape.0, shape.1, self.hidden], 0xEE);
                self.tune_inputs.insert(shape, t);
            }
            let x_buf = self.engine.upload(&self.tune_inputs[&shape])?;
            let args = self.args(&x_buf);
            self.compiled[handle].time_us_buffers(&args, warmup, iters)
        }

        /// Generate (on the shared worker pool, in parallel) the
        /// synthetic input tensors for the `upcoming` shapes that don't
        /// have one memoized yet.  The tensors are deterministic per
        /// shape, so this is purely a wall-clock optimization: the
        /// executor thread measures while the pool fills buffers for
        /// upcoming shapes.
        fn prefetch(&mut self, upcoming: &[ShapeKey]) {
            let hidden = self.hidden;
            let todo: Vec<ShapeKey> = upcoming
                .iter()
                .copied()
                .filter(|k| !self.tune_inputs.contains_key(k))
                .collect();
            if todo.is_empty() {
                return;
            }
            let mut made: Vec<Option<TensorF32>> = vec![None; todo.len()];
            crate::util::pool::global().scope(|s| {
                for (key, slot) in todo.iter().zip(made.iter_mut()) {
                    let key = *key;
                    s.spawn(move || {
                        *slot = Some(TensorF32::random(&[key.0, key.1, hidden], 0xEE));
                    });
                }
            });
            for (key, tensor) in todo.into_iter().zip(made) {
                if let Some(t) = tensor {
                    self.tune_inputs.insert(key, t);
                }
            }
        }

        fn release(&mut self, shape: ShapeKey) {
            self.tune_inputs.remove(&shape);
        }

        fn release_all(&mut self) {
            self.tune_inputs.clear();
        }
    }

    /// Parse the kernel config out of a model artifact id
    /// (`model/b1_s128/bq32_bk64_u2` -> block_q=32,block_k=64,unroll=2).
    fn variant_config(artifact_id: &str) -> Config {
        let mut cfg = Config::default();
        if let Some(last) = artifact_id.rsplit('/').next() {
            for part in last.split('_') {
                if let Some(v) = part.strip_prefix("bq").and_then(|s| s.parse().ok()) {
                    cfg.set("block_q", v);
                } else if let Some(v) = part.strip_prefix("bk").and_then(|s| s.parse().ok()) {
                    cfg.set("block_k", v);
                } else if let Some(v) = part.strip_prefix('u').and_then(|s| s.parse().ok()) {
                    cfg.set("unroll", v);
                }
            }
        }
        cfg
    }

    #[cfg(test)]
    mod tests {
        use super::variant_config;

        #[test]
        fn artifact_id_config_roundtrip() {
            let cfg = variant_config("model/b1_s128/bq32_bk64_u2");
            assert_eq!(cfg.req("block_q"), 32);
            assert_eq!(cfg.req("block_k"), 64);
            assert_eq!(cfg.req("unroll"), 2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PlatformId;

    #[test]
    fn default_variant_is_valid_on_every_modeled_platform() {
        // The cold-start variant must serve everywhere, or a platform
        // could boot with nothing executable.
        let cfg = default_variant_config();
        for gpu in [SimGpu::a100(), SimGpu::mi250(), SimGpu::h100()] {
            let mut b = SimBackend::new(gpu.clone(), 0);
            for &shape in &b.shapes.clone() {
                let w = b.bucket_workload(shape);
                assert!(
                    gpu.validate_attention(&cfg, &w).is_ok(),
                    "{}: default variant invalid for {shape:?}",
                    gpu.spec.name
                );
                // And compile/execute go through end to end.
                let desc = VariantDesc {
                    artifact_id: sim_artifact_id(shape, &cfg),
                    config: cfg.clone(),
                    path: None,
                };
                let h = b.compile(shape, &desc).unwrap();
                assert!(b.execute(h, shape).unwrap() > 0.0);
            }
        }
    }

    #[test]
    fn discover_is_deterministic_per_seed_and_differs_across_seeds() {
        let ids = |seed: u64| -> Vec<String> {
            SimBackend::new(SimGpu::a100(), seed)
                .discover()
                .unwrap()
                .into_iter()
                .flat_map(|(_, vs)| vs.into_iter().map(|v| v.artifact_id))
                .collect()
        };
        assert_eq!(ids(7), ids(7), "same seed, same candidate set");
        assert_ne!(ids(7), ids(8), "different seeds draw different candidates");
    }

    #[test]
    fn discover_buckets_have_default_first_and_distinct_variants() {
        let mut b = SimBackend::new(SimGpu::mi250(), 3);
        let universe = b.discover().unwrap();
        assert!(!universe.is_empty());
        let default_fp = default_variant_config().fingerprint();
        for (shape, vs) in &universe {
            assert!(vs.len() >= 2, "{shape:?}: need tuning headroom");
            assert_eq!(vs[0].config.fingerprint(), default_fp, "{shape:?}: index 0 is the default");
            let mut fps: Vec<u64> = vs.iter().map(|v| v.config.fingerprint()).collect();
            fps.sort_unstable();
            fps.dedup();
            assert_eq!(fps.len(), vs.len(), "{shape:?}: duplicate variants");
        }
    }

    #[test]
    fn measure_is_deterministic_and_matches_execute() {
        let mut b = SimBackend::new(SimGpu::a100(), 1);
        let shape = (4, 256);
        let desc = VariantDesc {
            artifact_id: sim_artifact_id(shape, &default_variant_config()),
            config: default_variant_config(),
            path: None,
        };
        let h = b.compile(shape, &desc).unwrap();
        let m1 = b.measure(h, shape, 1, 3).unwrap();
        let m2 = b.measure(h, shape, 1, 3).unwrap();
        let e = b.execute(h, shape).unwrap();
        assert_eq!(m1.to_bits(), m2.to_bits(), "the model is noise-free");
        assert_eq!(m1.to_bits(), e.to_bits(), "measure and execute agree on the model");
    }

    #[test]
    fn virtual_clock_advances_without_wall_time() {
        let mut b = SimBackend::new(SimGpu::a100(), 1);
        assert_eq!(b.clock_us(), 0.0);
        let shape = (1, 128);
        let desc = VariantDesc {
            artifact_id: "sim/test".into(),
            config: default_variant_config(),
            path: None,
        };
        let h = b.compile(shape, &desc).unwrap();
        let after_compile = b.clock_us();
        assert!(after_compile > 0.0, "compiles cost modeled time");
        b.execute(h, shape).unwrap();
        assert!(b.clock_us() > after_compile);
        b.measure(h, shape, 1, 3).unwrap();
        assert!(b.clock_us() > after_compile);
    }

    #[test]
    fn compile_rejects_platform_invalid_configs() {
        // Big staging blows the MI250's 64 KiB LDS — the exact effect
        // behind the paper's Fig 4 missing bars, now on the serve path.
        let mut b = SimBackend::new(SimGpu::mi250(), 0);
        let cfg = Config::new(&[
            ("BLOCK_M", 128),
            ("BLOCK_N", 128),
            ("num_warps", 4),
            ("num_stages", 3),
            ("waves_per_eu", 0),
        ]);
        let desc = VariantDesc { artifact_id: "sim/huge".into(), config: cfg, path: None };
        let err = b.compile((1, 256), &desc).unwrap_err();
        assert!(err.to_string().contains("shared memory"), "{err}");
    }

    #[test]
    fn default_budget_serves_the_whole_shape_grid() {
        // The stock grid's largest bucket (batch 8, seq 512) pins
        // 8*512*8*128*2*4 B = 32 MiB of KV cache — nowhere near the
        // 64-80 GiB device budgets, so nothing is filtered by default.
        let mut b = SimBackend::new(SimGpu::a100(), 0);
        let shapes = b.shapes.clone();
        let served: Vec<ShapeKey> = b.discover().unwrap().into_iter().map(|(k, _)| k).collect();
        assert_eq!(served, shapes);
    }

    #[test]
    fn tiny_budget_filters_oversized_buckets() {
        let mut b = SimBackend::new(SimGpu::a100(), 0)
            .with_shapes(&[(1, 128), (8, 512)])
            .with_mem_budget(Workload::Attention {
                batch: 1,
                q_heads: 32,
                kv_heads: 8,
                seq_len: 128,
                head_dim: 128,
                dtype: DType::F32,
                causal: true,
            }
            .kv_cache_bytes());
        let served: Vec<ShapeKey> = b.discover().unwrap().into_iter().map(|(k, _)| k).collect();
        assert_eq!(served, vec![(1, 128)], "the 8x512 bucket exceeds the KV budget");
    }

    #[test]
    fn discovered_variants_fit_the_platform_memory_budget() {
        // Even on the smallest-LDS platform, every candidate the
        // backend proposes must survive its own compile-time memory
        // check — no variant is born dead.
        let mut b = SimBackend::new(SimGpu::mi250(), 3);
        for (shape, vs) in b.discover().unwrap() {
            let w = SimModelGeom::default().bucket_workload(shape);
            for v in vs {
                assert!(
                    v.config.mem_bytes(&w) <= crate::platform::spec::MI250.smem_per_block,
                    "{shape:?}: {} overflows LDS",
                    v.artifact_id
                );
            }
        }
    }

    #[test]
    fn platform_fingerprints_match_the_tuning_evaluators() {
        assert_eq!(
            SimBackend::new(SimGpu::a100(), 0).platform(),
            PlatformId::SimA100.fingerprint()
        );
        assert_eq!(
            SimBackend::new(SimGpu::h100(), 0).platform(),
            PlatformId::SimH100.fingerprint()
        );
    }
}
