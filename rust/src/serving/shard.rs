//! Executor sharding: N executor threads per backend, one placement
//! policy, shared admission control.
//!
//! A [`ShardSet`] owns N [`ExecutorHandle`]s spawned from one backend
//! factory — each shard is a full executor (its own backend instance,
//! tuning queue, breaker state, virtual clock), so shards fail, tune
//! and quarantine independently.  The router keeps a single
//! [`DynamicBatcher`](super::batcher::DynamicBatcher) in front (batch
//! composition is shard-count-independent, which is what makes
//! throughput-scaling comparisons apples-to-apples) and asks the
//! [`PlacementPolicy`] which shard runs each formed batch.
//!
//! Everything here is deterministic on the sim backend: placement is a
//! pure function of the batch key and integer load counters (ties break
//! to the lowest shard index), so same-seed replays land every batch on
//! the same shard and `ServeReport::replay_digest` stays bit-identical
//! across runs — the property the sharding test suite pins.

use std::sync::mpsc::channel;

use super::backend::{ExecBackend, ShapeKey};
use super::batcher::Batch;
use super::executor::{ExecutorCommand, ExecutorHandle, ExecutorStats};
use crate::cache::TuningCache;
use crate::util::fnv::Fnv64;
use crate::Result;

/// Which shard a formed batch executes on.
///
/// Policies are pure functions of `(batch key, load counters, liveness)`
/// with deterministic tie-breaking, so sim replays are bit-reproducible
/// under every policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Hash the (bucket, padded batch shape) key onto a home shard:
    /// every batch of one compiled shape lands on the same shard, so
    /// each shard compiles/warms only its own slice of the shape grid.
    /// Dead home shards are walked past, wrapping, to the next live one.
    BucketAffinity,
    /// Send the batch to the live shard with the fewest batches
    /// currently outstanding; ties go to the lowest shard index.  Best
    /// raw balance, at the cost of every shard eventually compiling
    /// every shape.
    LeastLoaded,
}

impl Default for PlacementPolicy {
    fn default() -> Self {
        PlacementPolicy::BucketAffinity
    }
}

impl PlacementPolicy {
    /// Short name for flags, reports and digests.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::BucketAffinity => "bucket-affinity",
            PlacementPolicy::LeastLoaded => "least-loaded",
        }
    }

    /// Pick the shard for `batch` given per-shard outstanding-batch
    /// counts and liveness flags (both length = shard count).  Returns
    /// `None` only when every shard is dead.
    pub fn place(&self, batch: &Batch, outstanding: &[usize], dead: &[bool]) -> Option<usize> {
        let n = outstanding.len();
        debug_assert_eq!(n, dead.len());
        if n == 0 || dead.iter().all(|&d| d) {
            return None;
        }
        match self {
            PlacementPolicy::BucketAffinity => {
                // FNV over the full compiled-shape key: bucket index
                // alone has too few distinct values to spread, and the
                // padded shape is what the executor actually compiles.
                let mut h = Fnv64::new();
                h.write_u64(batch.bucket as u64);
                h.write_u64(batch.batch_shape as u64);
                let home = (h.finish() % n as u64) as usize;
                (0..n).map(|i| (home + i) % n).find(|&i| !dead[i])
            }
            PlacementPolicy::LeastLoaded => (0..n)
                .filter(|&i| !dead[i])
                .min_by_key(|&i| (outstanding[i], i)),
        }
    }
}

impl std::str::FromStr for PlacementPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "bucket" | "bucket-affinity" | "affinity" => Ok(PlacementPolicy::BucketAffinity),
            "least-loaded" | "least" | "load" => Ok(PlacementPolicy::LeastLoaded),
            other => anyhow::bail!(
                "unknown placement policy '{other}' (expected bucket-affinity or least-loaded)"
            ),
        }
    }
}

/// Per-shard work accounting for one trace replay — the rollup rows of
/// `ServeReport` and the CLI's per-shard utilization table.
#[derive(Debug, Clone, Default)]
pub struct ShardUtil {
    /// Shard index.
    pub shard: usize,
    /// Batches dispatched to this shard during the replay.
    pub batches: usize,
    /// Requests inside those batches.
    pub requests: usize,
    /// Virtual-clock time this shard's backend spent on the replay, µs
    /// (0.0 on wall-clock backends, which don't model a clock).
    pub busy_us: f64,
}

impl ShardUtil {
    /// Busy fraction of the replay's modeled makespan, clamped to
    /// [0, 1]; 0.0 when no modeled time elapsed.
    pub fn utilization(&self, makespan_us: f64) -> f64 {
        if makespan_us <= 0.0 {
            0.0
        } else {
            (self.busy_us / makespan_us).clamp(0.0, 1.0)
        }
    }
}

/// N executor shards over one backend factory, plus the placement
/// policy that routes batches among them.
pub struct ShardSet {
    handles: Vec<ExecutorHandle>,
    placement: PlacementPolicy,
}

impl ShardSet {
    /// Spawn `shards` executors, each over its own backend built by
    /// `make(shard_index)`.  Every shard must discover the same shape
    /// grid (they serve one model); a mismatch is a configuration error.
    ///
    /// The persistent tuning `cache` is wired to shard 0 only: winners
    /// are deterministic per backend, so one writer is enough, and a
    /// single writer is what keeps concurrent cache-file saves from
    /// racing.  Sibling shards cold-tune to the same winners.
    pub fn spawn<B, F>(
        make: F,
        shards: usize,
        placement: PlacementPolicy,
        idle_tuning: bool,
        cache: Option<TuningCache>,
    ) -> Result<Self>
    where
        B: ExecBackend + 'static,
        F: Fn(usize) -> Result<B> + Send + Clone + 'static,
    {
        anyhow::ensure!(shards >= 1, "need at least one shard");
        let mut cache = cache;
        let mut handles = Vec::with_capacity(shards);
        for i in 0..shards {
            let mk = make.clone();
            let shard_cache = if i == 0 { cache.take() } else { None };
            handles.push(ExecutorHandle::spawn(move || mk(i), idle_tuning, shard_cache)?);
        }
        Self::from_handles(handles, placement)
    }

    /// Wrap already-spawned executors as a shard set (single-shard
    /// compatibility path, and the seam tests use to mix backends).
    pub fn from_handles(handles: Vec<ExecutorHandle>, placement: PlacementPolicy) -> Result<Self> {
        anyhow::ensure!(!handles.is_empty(), "need at least one shard");
        for (i, h) in handles.iter().enumerate().skip(1) {
            anyhow::ensure!(
                h.shapes == handles[0].shapes,
                "shard {i} discovered a different shape grid than shard 0 \
                 ({} vs {} shapes) — shards must serve one model",
                h.shapes.len(),
                handles[0].shapes.len(),
            );
        }
        Ok(ShardSet { handles, placement })
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    /// Always false: construction requires ≥ 1 shard.
    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// The executor handles, in shard order.
    pub fn handles(&self) -> &[ExecutorHandle] {
        &self.handles
    }

    /// The placement policy batches are routed with.
    pub fn placement(&self) -> PlacementPolicy {
        self.placement
    }

    /// The compiled shape grid (identical on every shard by
    /// construction).
    pub fn shapes(&self) -> &[ShapeKey] {
        &self.handles[0].shapes
    }

    /// Snapshot every shard's stats, in shard order.  Dead shards (the
    /// executor thread is gone) report default-zero stats instead of
    /// failing the whole rollup — reports must survive partial outages.
    pub fn stats(&self) -> Vec<ExecutorStats> {
        // Fan the Stats commands out first, then collect, so shards
        // snapshot concurrently instead of serializing behind each
        // other's tuning slices.
        let pending: Vec<_> = self
            .handles
            .iter()
            .map(|h| {
                let (tx, rx) = channel();
                h.tx.send(ExecutorCommand::Stats { reply: tx }).ok().map(|_| rx)
            })
            .collect();
        pending
            .into_iter()
            .map(|rx| rx.and_then(|rx| rx.recv().ok()).unwrap_or_default())
            .collect()
    }

    /// Drain every shard's background tuning queue (all shards tune in
    /// parallel; this blocks until the slowest finishes).
    pub fn finish_tuning(&self) -> Result<()> {
        let mut pending = Vec::with_capacity(self.handles.len());
        for h in &self.handles {
            let (tx, rx) = channel();
            h.tx.send(ExecutorCommand::FinishTuning { reply: tx })
                .map_err(|_| anyhow::anyhow!("executor gone"))?;
            pending.push(rx);
        }
        for rx in pending {
            rx.recv()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn batch(bucket: usize, batch_shape: usize) -> Batch {
        Batch {
            bucket,
            seq_len: 128 << bucket,
            batch_shape,
            requests: Vec::new(),
            formed_at: Instant::now(),
        }
    }

    #[test]
    fn placement_parses_and_names() {
        assert_eq!("bucket".parse::<PlacementPolicy>().unwrap(), PlacementPolicy::BucketAffinity);
        assert_eq!(
            "least-loaded".parse::<PlacementPolicy>().unwrap(),
            PlacementPolicy::LeastLoaded
        );
        assert!("nope".parse::<PlacementPolicy>().is_err());
        assert_eq!(PlacementPolicy::default(), PlacementPolicy::BucketAffinity);
    }

    #[test]
    fn bucket_affinity_is_sticky_and_walks_past_dead_shards() {
        let p = PlacementPolicy::BucketAffinity;
        let outstanding = [0usize; 4];
        let alive = [false; 4];
        let b = batch(1, 4);
        let home = p.place(&b, &outstanding, &alive).unwrap();
        // Sticky: the same key always lands on the same shard.
        assert_eq!(p.place(&b, &[9, 9, 9, 9], &alive), Some(home));
        // Dead home: next live shard, wrapping.
        let mut dead = [false; 4];
        dead[home] = true;
        let fallback = p.place(&b, &outstanding, &dead).unwrap();
        assert_eq!(fallback, (home + 1) % 4);
        // All dead: nowhere to place.
        assert_eq!(p.place(&b, &outstanding, &[true; 4]), None);
    }

    #[test]
    fn bucket_affinity_spreads_the_shape_grid() {
        // The full (bucket, batch_shape) grid must not starve shards:
        // with 12 distinct keys over 4 shards, at least 3 shards get
        // traffic under FNV hashing.
        let p = PlacementPolicy::BucketAffinity;
        let mut hit = [false; 4];
        for bucket in 0..3 {
            for shape in [1usize, 2, 4, 8] {
                if let Some(s) = p.place(&batch(bucket, shape), &[0; 4], &[false; 4]) {
                    hit[s] = true;
                }
            }
        }
        assert!(hit.iter().filter(|&&h| h).count() >= 3, "hit map: {hit:?}");
    }

    #[test]
    fn least_loaded_takes_min_with_lowest_index_ties() {
        let p = PlacementPolicy::LeastLoaded;
        let b = batch(0, 1);
        assert_eq!(p.place(&b, &[2, 1, 1, 3], &[false; 4]), Some(1));
        // Tie across all: lowest index.
        assert_eq!(p.place(&b, &[5, 5, 5, 5], &[false; 4]), Some(0));
        // The min shard being dead: next-best live shard.
        assert_eq!(p.place(&b, &[2, 1, 1, 3], &[false, true, false, false]), Some(2));
        assert_eq!(p.place(&b, &[0, 0], &[true, true]), None);
    }
}
